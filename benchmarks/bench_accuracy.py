"""Paper Fig. 2a/2b: accuracy of CADDeLaG vs eps_RP, chain length d, and
Richardson iterations q.

Metric (paper section 4.2.2): relative error of the distributed computation
against the exact eigendecomposition, reported as the excess over a
high-precision reference run of the same solver ("baseline error"):

    rel_excess = (CADDeLaG_err - baseline_err) / baseline_err

where *_err = median_ij |c_approx(i,j) - c_exact(i,j)| / c_exact(i,j).
The paper's headline observations reproduced here:
  - with eps_RP = 1e-2 the error never drops below a floor regardless of d, q
  - with eps_RP = 1e-3 even lax d, q reach small error (embedding dimension
    k_RP = ceil(log(n/eps)) dominates accuracy)
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import CommuteConfig, commute_time_embedding, exact_commute_distances, trivial_context
from repro.core.embedding import commute_distance_block
from repro.graphs import gmm_graph_sequence


def _err(ctx, a, exact, cfg) -> float:
    emb = commute_time_embedding(ctx, a, cfg)
    n = a.shape[0]
    idx = jnp.arange(n)
    approx = np.asarray(commute_distance_block(emb, idx, idx))
    mask = ~np.eye(n, dtype=bool)
    rel = np.abs(approx - exact)[mask] / np.maximum(exact[mask], 1e-9)
    return float(np.median(rel))


def run(n: int = 512, seed: int = 0, out=print):
    ctx = trivial_context()
    seq = gmm_graph_sequence(ctx, n=n, seed=seed)
    a = seq.a1
    exact = np.asarray(exact_commute_distances(np.asarray(a)))

    t0 = time.perf_counter()
    base_cfg = CommuteConfig(eps_rp=1e-4, d=12, q=20, schedule="xla")
    base_err = _err(ctx, a, exact, base_cfg)
    out(f"bench_accuracy,baseline_err,{base_err:.4f}")

    rows = []
    # paper defaults: eps=1e-2, d=3, q=10; sweep each axis
    for eps in (1e-1, 1e-2, 1e-3):
        e = _err(ctx, a, exact, CommuteConfig(eps_rp=eps, d=6, q=10, schedule="xla"))
        rows.append(("eps", eps, e))
    for d in (2, 3, 6, 9):
        e = _err(ctx, a, exact, CommuteConfig(eps_rp=1e-3, d=d, q=10, schedule="xla"))
        rows.append(("d", d, e))
    for q in (2, 5, 10, 15):
        e = _err(ctx, a, exact, CommuteConfig(eps_rp=1e-3, d=6, q=q, schedule="xla"))
        rows.append(("q", q, e))
    dt = time.perf_counter() - t0

    for knob, val, e in rows:
        excess = (e - base_err) / max(base_err, 1e-9)
        out(f"bench_accuracy,{knob}={val},err={e:.4f},rel_excess={excess:+.3f}")

    # paper Fig 2a claim: eps=1e-2 floors; Fig 2b: eps=1e-3 + lax d/q is fine
    eps2 = dict((f"{k}={v}", e) for k, v, e in rows)
    out(f"bench_accuracy,total_s,{dt:.1f}")
    return rows


if __name__ == "__main__":
    run()
