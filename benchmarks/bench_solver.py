"""Solver methods head-to-head: iterations-to-tolerance and scratch bytes,
richardson vs chebyshev vs cg, resident vs out-of-core, 1x1 vs 2x2 mesh.

The solve phase is the dominant *recurring* cost of a snapshot sequence once
the chain is built -- and out-of-core, every solver iteration is a streamed
pass over the P2 scratch, so iterations ARE bytes.  This benchmark runs both
methods to the same relative-residual tolerance on the same operator and
reports, per (mesh, storage, method) cell: iterations, final residual, solve
seconds, and `stream_stats().bytes_read` during the solve.  The fixed-q
Richardson baseline (q = the adaptive run's iteration count) pins accuracy:
every method's solution must stay allclose (rtol <= 1e-4) to it.

Verdict (the PR-5 acceptance bar): on the out-of-core solve, Chebyshev must
cut BOTH the iteration count and the scratch `bytes_read` by >= 1.5x at equal
accuracy.

  PYTHONPATH=src python benchmarks/bench_solver.py --n 96 --d 4 --tol 1e-5 \
      --out benchmarks/bench_solver.json
"""

from __future__ import annotations

import os

# The 2x2 mesh needs fake CPU devices BEFORE jax initializes (no-op when the
# importing process already configured XLA_FLAGS, e.g. under pytest).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import (
    SolverSpec,
    chain_product,
    estimate_solution,
    make_context,
    reset_stream_stats,
    solve,
    stream_stats,
    trivial_context,
)
from repro.core.embedding import edge_projection
from repro.graphs import gmm_points, similarity_graph
from repro.store import TileStore

import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from roofline import streamed_solve_flops, streamed_solve_roofline  # noqa: E402

METHODS = ("richardson", "chebyshev", "cg")


def _contexts(n: int):
    """(label, ctx) for the 1x1 mesh and -- devices permitting -- the 2x2."""
    from jax.sharding import Mesh

    out = [("1x1", trivial_context())]
    devs = jax.devices()
    if len(devs) >= 4 and n % 2 == 0:
        out.append(("2x2", make_context(Mesh(np.array(devs[:4]).reshape(2, 2),
                                             ("data", "model")))))
    return out


def run(n=96, d=4, k=8, tol=1e-5, grid=8, seed=0, out_path=None, out=print):
    pts, _ = gmm_points(n, seed)
    rows, verdicts = [], []
    out(f"[bench_solver] n={n} d={d} k_RP={k} tol={tol:.0e} grid={grid}")
    out("[bench_solver]  mesh storage   method     | iters res      solve_s "
        "| read_MB | vs fixed-q")
    for mesh_label, ctx in _contexts(n):
        a_np = np.asarray(similarity_graph(ctx, pts))
        store = TileStore.create(None, n=n, grid=grid)
        h = store.put_snapshot("a", a_np)
        for storage in ("resident", "oocore"):
            src = ctx.put_matrix(a_np) if storage == "resident" else h
            op = chain_product(ctx, src, d, schedule="xla",
                               oocore=storage == "oocore")
            y = edge_projection(ctx, src, seed, k)
            cell = {}
            for method in METHODS:
                reset_stream_stats()
                t0 = time.perf_counter()
                x, rep = solve(ctx, op, y, SolverSpec(method=method, tolerance=tol))
                jax.block_until_ready(x)
                dt = time.perf_counter() - t0
                st = stream_stats()
                cell[method] = (np.asarray(x), rep, dt, st.bytes_read, st.bytes_h2d)
            # Accuracy pin: fixed-q Richardson at the adaptive run's count.
            q_fix = cell["richardson"][1].iterations + 1
            ref = np.asarray(estimate_solution(ctx, op, y, q_fix))
            for method in METHODS:
                x, rep, dt, bread, bh2d = cell[method]
                close = bool(np.allclose(x, ref, rtol=1e-4, atol=1e-3))
                row = {
                    "mesh": mesh_label, "storage": storage, "method": method,
                    "iterations": rep.iterations, "residual": rep.residual,
                    "converged": rep.converged, "rho": rep.rho,
                    "solve_s": dt, "bytes_read": bread,
                    "fixed_q_baseline": q_fix, "allclose_vs_fixed_q": close,
                }
                frac = ""
                if storage == "oocore":
                    roof = streamed_solve_roofline(
                        bytes_read=bread, bytes_h2d=bh2d,
                        flops=streamed_solve_flops(n, k, rep.iterations),
                        seconds=dt,
                    )
                    row["roofline"] = roof
                    frac = (f" roofline={roof['roofline_frac']:.2e} "
                            f"({roof['bound']}-bound)")
                rows.append(row)
                out(f"[bench_solver]  {mesh_label:>4s} {storage:8s} {method:10s} | "
                    f"{rep.iterations:5d} {rep.residual:8.1e} {dt:7.2f} | "
                    f"{bread / 1e6:7.2f} | allclose={close}{frac}")
            r_rep, c_rep = cell["richardson"][1], cell["chebyshev"][1]
            iters_ratio = r_rep.iterations / max(c_rep.iterations, 1)
            if storage == "oocore":
                bytes_ratio = cell["richardson"][3] / max(cell["chebyshev"][3], 1)
                ok = iters_ratio >= 1.5 and bytes_ratio >= 1.5 and all(
                    np.allclose(cell[m][0], ref, rtol=1e-4, atol=1e-3)
                    for m in METHODS
                )
                verdicts.append({
                    "mesh": mesh_label, "iters_ratio": iters_ratio,
                    "bytes_ratio": bytes_ratio, "target": 1.5, "pass": ok,
                })
                out(f"[bench_solver]  {mesh_label} oocore: chebyshev saves "
                    f"{iters_ratio:.1f}x iterations, {bytes_ratio:.1f}x scratch "
                    f"reads -> {'PASS' if ok else 'FAIL'} (>= 1.5x)")
            op.release_scratch()

    result = {
        "bench": "solver", "n": n, "d": d, "k_rp": k, "tol": tol, "grid": grid,
        "rows": rows, "verdicts": verdicts,
        "all_pass": all(v["pass"] for v in verdicts) if verdicts else False,
    }
    if out_path:
        Path(out_path).write_text(json.dumps(result, indent=2))
        out(f"[bench_solver] wrote {out_path}")
    return result


def trajectory(out_path, out=print):
    """Canonical perf-trajectory artifact (``BENCH_solver.json``).

    One fixed configuration -- n=96, d=4, out-of-core chebyshev through the
    fused kernel path on a bf16 scratch -- with a stable schema, so the weekly
    CI artifact is directly diffable across PRs: byte counters, solve seconds,
    iterations and the fraction-of-roofline all trend, none get renamed.
    """
    from repro.obs.metrics import registry as _obs_registry

    n, d, k, tol, grid = 96, 4, 8, 1e-5, 8
    ctx = trivial_context()
    pts, _ = gmm_points(n, 0)
    a_np = np.asarray(similarity_graph(ctx, pts))
    store = TileStore.create(None, n=n, grid=grid)
    h = store.put_snapshot("a", a_np)

    reset_stream_stats()
    m0 = _obs_registry().snapshot()
    t0 = time.perf_counter()
    op = chain_product(ctx, h, d, schedule="xla", oocore=True,
                       tile_codec="bf16", use_gemm_kernel=True)
    jax.block_until_ready(op.deg)
    build_s = time.perf_counter() - t0
    bst = stream_stats()
    build = {"seconds": build_s, "bytes_read": bst.bytes_read,
             "bytes_decoded": bst.bytes_decoded, "bytes_h2d": bst.bytes_h2d,
             "bytes_h2d_saved": bst.bytes_h2d_saved, "panels": bst.panels}

    y = edge_projection(ctx, h, 0, k)
    reset_stream_stats()
    t0 = time.perf_counter()
    x, rep = solve(ctx, op, y, SolverSpec(method="chebyshev", tolerance=tol))
    jax.block_until_ready(x)
    solve_s = time.perf_counter() - t0
    sst = stream_stats()
    op.release_scratch()
    roof = streamed_solve_roofline(
        bytes_read=sst.bytes_read, bytes_h2d=sst.bytes_h2d,
        flops=streamed_solve_flops(n, k, rep.iterations), seconds=solve_s,
    )
    result = {
        "bench": "solver_trajectory", "schema": 1,
        "config": {"n": n, "d": d, "k_rp": k, "tol": tol, "grid": grid,
                   "codec": "bf16", "use_gemm_kernel": True,
                   "method": "chebyshev"},
        "build": build,
        "solve": {"seconds": solve_s, "iterations": rep.iterations,
                  "residual": rep.residual, "converged": rep.converged,
                  "bytes_read": sst.bytes_read,
                  "bytes_decoded": sst.bytes_decoded,
                  "bytes_h2d": sst.bytes_h2d,
                  "bytes_h2d_saved": sst.bytes_h2d_saved,
                  "panels": sst.panels},
        "roofline_frac": roof["roofline_frac"],
        "roofline_bound": roof["bound"],
        "roofline": roof,
        # Registry counter deltas over the whole bench (repro.obs.metrics):
        # phase/pipeline/cache/solver telemetry.  stream.* is excluded -- the
        # mid-bench reset_stream_stats() breaks delta monotonicity for it,
        # and the byte counters already live in the build/solve blocks.
        "metrics": {
            k_: v for k_, v in _obs_registry().delta(m0).items()
            if not k_.startswith("stream.")
        },
        "residuals": [float(r) for r in rep.residuals],
    }
    Path(out_path).write_text(json.dumps(result, indent=2))
    out(f"[bench_solver] trajectory: {rep.iterations} its in {solve_s:.2f}s, "
        f"{sst.bytes_h2d / 1e6:.1f} MB H2D "
        f"({sst.bytes_h2d_saved / 1e6:.1f} MB saved), roofline "
        f"{roof['roofline_frac']:.2e} ({roof['bound']}-bound); wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=96)
    ap.add_argument("--d", type=int, default=4, help="chain length (smaller d "
                    "-> larger rho -> more iterations to accelerate)")
    ap.add_argument("--k", type=int, default=8, help="right-hand sides (k_RP)")
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--grid", type=int, default=8, help="store tiles per side")
    ap.add_argument("--out", default=None, help="write results JSON here")
    ap.add_argument("--trajectory", default=None, metavar="PATH",
                    help="write the canonical fixed-config perf-trajectory "
                         "artifact (BENCH_solver.json) and exit")
    args = ap.parse_args()
    if args.trajectory:
        trajectory(args.trajectory)
        return
    run(n=args.n, d=args.d, k=args.k, tol=args.tol, grid=args.grid,
        out_path=args.out)


if __name__ == "__main__":
    main()
