"""Out-of-core chain build: streamed (store-backed S/T/P) vs resident, the
max-n-under-budget table for the chain working set, and the panel-I/O sweep
(prefetch depth x tile codec x solver batch) with real bytes-moved columns.

The chain product is the O(n^3) hot spot AND (after the PR-2 snapshot store
removed the adjacency term) the remaining HBM bound: a resident build holds
~5 n^2 fp32 matrices (S, T, P, P1, P2).  The out-of-core build spills them
through a TileStore scratch and keeps only O(n * panel) on device; this
benchmark measures both paths, verifies the scores stay allclose, and emits
the max n that fits a given device budget for each mode as JSON.  The sweep
(``--sweep``) exercises the unified panel pipeline's knobs and reports
scratch reads (pre-codec), decoded bytes, and H2D traffic per combination,
so disk-traffic regressions across PRs are visible in the weekly artifact.

  PYTHONPATH=src python benchmarks/bench_oochain.py --n 256 --d 4 \
      --budget-mb 1.0 --sweep --out benchmarks/bench_oochain.json
"""

from __future__ import annotations

import argparse
import json
import math
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import (
    CommuteConfig,
    chain_product,
    detect_anomalies,
    estimate_solution,
    reset_stream_stats,
    solve,
    stream_stats,
    trivial_context,
)
from repro.core.embedding import edge_projection
from repro.store import TileStore
from repro.store.tilestore import _zstd_backend

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from roofline import streamed_solve_flops, streamed_solve_roofline  # noqa: E402


def _sym(n: int, seed: int) -> np.ndarray:
    a = np.abs(np.random.default_rng(seed).normal(size=(n, n))).astype(np.float32)
    a = (a + a.T) / 2.0
    np.fill_diagonal(a, 0.0)
    return a


def run(n=256, d=4, q=4, grid=None, budget_mb=1.0, do_sweep=False, out_path=None,
        out=print):
    ctx = trivial_context()
    budget = int(budget_mb * 1e6)
    a1, a2 = _sym(n, 0), _sym(n, 1)
    store = TileStore.create(None, n=n, grid=grid or 8)
    h1, h2 = store.put_snapshot("t0", a1), store.put_snapshot("t1", a2)
    work = TileStore.create(None, n=n, grid=grid or 8)
    ph = store.tile_rows
    cfg = CommuteConfig(eps_rp=1e-2, d=d, q=q, schedule="xla")
    cfg_oo = CommuteConfig(eps_rp=1e-2, d=d, q=q, schedule="xla", oocore=True)

    # -- resident chain build (warm both once for compile parity) ------------
    chain_product(ctx, ctx.put_matrix(a1), d, schedule="xla")
    t0 = time.perf_counter()
    op_r = chain_product(ctx, ctx.put_matrix(a1), d, schedule="xla")
    jax.block_until_ready(op_r.p2)
    resident_s = time.perf_counter() - t0
    resident_peak = 5 * n * n * 4  # S, T, P, P1, P2 fp32

    # -- out-of-core chain build --------------------------------------------
    chain_product(ctx, h1, d, schedule="xla", oocore=True,
                  oocore_work=work, oocore_panel_rows=ph)
    reset_stream_stats()
    t0 = time.perf_counter()
    op_o = chain_product(ctx, h1, d, schedule="xla", oocore=True,
                         oocore_work=work, oocore_panel_rows=ph)
    oocore_s = time.perf_counter() - t0
    st = stream_stats()

    np.testing.assert_allclose(op_o.p2.to_numpy(), np.asarray(op_r.p2),
                               rtol=1e-3, atol=1e-3)
    res_r = detect_anomalies(ctx, ctx.put_matrix(a1), ctx.put_matrix(a2), cfg, top_k=10)
    res_o = detect_anomalies(ctx, h1, h2, cfg_oo, top_k=10)
    close = bool(np.allclose(np.asarray(res_o.scores), np.asarray(res_r.scores),
                             rtol=1e-4, atol=1e-3))

    out(f"[bench_oochain] n={n} d={d} panel={ph} rows "
        f"({n * n * 4 / 1e6:.2f} MB/matrix, resident chain set "
        f"{resident_peak / 1e6:.2f} MB)")
    out(f"[bench_oochain] resident build: {resident_s:.2f}s, "
        f"peak chain residency {resident_peak / 1e6:.2f} MB "
        f"-> {'WITHIN' if resident_peak <= budget else 'OVER'} "
        f"{budget / 1e6:.2f} MB budget")
    out(f"[bench_oochain] oocore build:   {oocore_s:.2f}s, "
        f"peak device panel residency {st.peak_live_bytes / 1e6:.2f} MB "
        f"({st.panels} panels, {st.bytes_read / 1e6:.1f} MB scratch reads, "
        f"{st.bytes_decoded / 1e6:.1f} MB decoded, {st.bytes_h2d / 1e6:.1f} MB H2D) "
        f"-> {'WITHIN' if st.peak_live_bytes <= budget else 'OVER'} budget")
    out(f"[bench_oochain] end-to-end scores allclose: {close}")

    # -- max n within the device budget, per mode ----------------------------
    # resident: 5 n^2 * 4 bytes.  oocore with a g x g scratch grid: one
    # accumulator panel + one streamed panel + one block ~= 3 * (n/g) * n * 4.
    n_res = int(math.isqrt(budget // 20))
    table = []
    for g in (4, 8, 16, 32):
        n_oo = int(math.isqrt(budget * g // 12))
        table.append({"grid": g, "max_n_oocore": n_oo})
        out(f"[bench_oochain] budget {budget / 1e6:.2f} MB: max n resident ~{n_res}, "
            f"oocore grid={g} ~{n_oo} ({n_oo / max(n_res, 1):.1f}x)")

    sweep_rows = sweep(n=n, d=d, q=q, grid=grid, budget=budget, out=out) if do_sweep else None

    result = {
        "bench": "oochain",
        "n": n, "d": d, "q": q, "panel_rows": ph,
        "budget_mb": budget / 1e6,
        "resident_s": resident_s,
        "oocore_s": oocore_s,
        "resident_peak_mb": resident_peak / 1e6,
        "oocore_peak_mb": st.peak_live_bytes / 1e6,
        "oocore_panels": st.panels,
        "oocore_h2d_mb": st.bytes_h2d / 1e6,
        "oocore_read_mb": st.bytes_read / 1e6,
        "oocore_decoded_mb": st.bytes_decoded / 1e6,
        "resident_within_budget": resident_peak <= budget,
        "oocore_within_budget": st.peak_live_bytes <= budget,
        "scores_allclose": close,
        "max_n_resident": n_res,
        "max_n_oocore": table,
        "sweep": sweep_rows,
    }
    if out_path:
        Path(out_path).write_text(json.dumps(result, indent=2))
        out(f"[bench_oochain] wrote {out_path}")
    return result


def sweep(n=128, d=3, q=8, grid=None, budget=int(1e6), out=print):
    """Panel-I/O knob sweep: prefetch depth x tile codec x solver batch.

    One out-of-core build + Richardson solve per combination, with the
    build/solve phases' byte counters split out -- the bytes-moved columns
    are what the codec and the iteration batching are each supposed to bend
    (codec: bytes_read < bytes_decoded; solver_batch: solve-phase reads drop
    ~batch x), so a combination that stops bending them is a regression.
    """
    ctx = trivial_context()
    g = grid or 8
    a = _sym(n, 0)
    store = TileStore.create(None, n=n, grid=g)
    h = store.put_snapshot("t0", a)
    # Combination-invariant RHS, computed once OUTSIDE the sweep: its panel
    # traffic belongs to neither the build nor the solve phase and must not
    # pollute the per-combination counters or budget verdicts.
    y = edge_projection(ctx, h, 0, 8)
    ref = None

    codecs = ["raw", "bf16"] + (["zstd"] if _zstd_backend() is not None else [])
    if _zstd_backend() is None:
        out("[bench_oochain] sweep: no zstd backend installed; sweeping raw/bf16")
    rows = []
    out(f"[bench_oochain] sweep n={n} d={d} q={q} grid={g} "
        f"(budget {budget / 1e6:.2f} MB)")
    out("[bench_oochain]  depth codec batch | build_s solve_s | "
        "bread_MB sread_MB dec_MB h2d_MB | peak_MB verdict close")
    for codec in codecs:
        for depth in (1, 2, 4):
            for batch in (1, 4):
                work = TileStore.create(None, n=n, grid=g, codec=codec)
                reset_stream_stats()
                t0 = time.perf_counter()
                op = chain_product(ctx, h, d, oocore=True, oocore_work=work,
                                   prefetch_depth=depth)
                jax.block_until_ready(op.deg)
                build_s = time.perf_counter() - t0
                bst = stream_stats()
                build_read, build_dec, build_h2d = (
                    bst.bytes_read, bst.bytes_decoded, bst.bytes_h2d)

                reset_stream_stats()
                t0 = time.perf_counter()
                z = estimate_solution(ctx, op, y, q, solver_batch=batch,
                                      prefetch_depth=depth)
                jax.block_until_ready(z)
                solve_s = time.perf_counter() - t0
                sst = stream_stats()
                op.release_scratch()

                if ref is None:
                    ref = np.asarray(z)  # depth/batch never change numerics
                tol = 1e-4 if codec != "bf16" else 5e-2
                close = bool(np.allclose(np.asarray(z), ref, rtol=tol, atol=tol))
                peak = max(bst.peak_live_bytes, sst.peak_live_bytes)
                verdict = "WITHIN" if peak <= budget else "OVER"
                row = {
                    "prefetch_depth": depth, "codec": work.manifest.codec,
                    "solver_batch": batch,
                    "build_s": build_s, "solve_s": solve_s,
                    "build_read_mb": build_read / 1e6,
                    "build_decoded_mb": build_dec / 1e6,
                    "build_h2d_mb": build_h2d / 1e6,
                    "solve_read_mb": sst.bytes_read / 1e6,
                    "solve_decoded_mb": sst.bytes_decoded / 1e6,
                    "solve_h2d_mb": sst.bytes_h2d / 1e6,
                    "bytes_moved_mb": (build_read + sst.bytes_read) / 1e6,
                    "peak_mb": peak / 1e6,
                    "within_budget": peak <= budget,
                    "solution_close": close,
                }
                rows.append(row)
                out(f"[bench_oochain]  {depth:5d} {codec:>5s} {batch:5d} | "
                    f"{build_s:7.2f} {solve_s:7.2f} | "
                    f"{build_read / 1e6:8.2f} {sst.bytes_read / 1e6:8.2f} "
                    f"{(build_dec + sst.bytes_decoded) / 1e6:6.1f} "
                    f"{(build_h2d + sst.bytes_h2d) / 1e6:6.1f} | "
                    f"{peak / 1e6:7.2f} {verdict:>6s} {close}")
    return rows


def trajectory(out_path, out=print):
    """Canonical perf-trajectory artifact (``BENCH_oochain.json``).

    One fixed configuration -- n=128, d=3, q=6, grid 8, bf16 scratch through
    the fused kernel path -- with a stable schema (byte counters, phase
    seconds, iterations, fraction-of-roofline), so the weekly CI artifact
    trends across PRs without renames.
    """
    from repro.obs.metrics import registry as _obs_registry

    n, d, q, k, g = 128, 3, 6, 6, 8
    ctx = trivial_context()
    a = _sym(n, 0)
    store = TileStore.create(None, n=n, grid=g)
    h = store.put_snapshot("t0", a)

    reset_stream_stats()
    m0 = _obs_registry().snapshot()
    t0 = time.perf_counter()
    op = chain_product(ctx, h, d, oocore=True, tile_codec="bf16",
                       use_gemm_kernel=True)
    jax.block_until_ready(op.deg)
    build_s = time.perf_counter() - t0
    bst = stream_stats()
    build = {"seconds": build_s, "bytes_read": bst.bytes_read,
             "bytes_decoded": bst.bytes_decoded, "bytes_h2d": bst.bytes_h2d,
             "bytes_h2d_saved": bst.bytes_h2d_saved, "panels": bst.panels,
             "peak_live_bytes": bst.peak_live_bytes}

    y = edge_projection(ctx, h, 0, k)
    reset_stream_stats()
    t0 = time.perf_counter()
    z, rep = solve(ctx, op, y, fixed_q=q)
    jax.block_until_ready(z)
    solve_s = time.perf_counter() - t0
    sst = stream_stats()
    op.release_scratch()
    roof = streamed_solve_roofline(
        bytes_read=sst.bytes_read, bytes_h2d=sst.bytes_h2d,
        flops=streamed_solve_flops(n, k, rep.iterations), seconds=solve_s,
    )
    result = {
        "bench": "oochain_trajectory", "schema": 1,
        "config": {"n": n, "d": d, "q": q, "k_rp": k, "grid": g,
                   "codec": "bf16", "use_gemm_kernel": True},
        "build": build,
        "solve": {"seconds": solve_s, "iterations": rep.iterations,
                  "residual": rep.residual,
                  "bytes_read": sst.bytes_read,
                  "bytes_decoded": sst.bytes_decoded,
                  "bytes_h2d": sst.bytes_h2d,
                  "bytes_h2d_saved": sst.bytes_h2d_saved,
                  "panels": sst.panels},
        "roofline_frac": roof["roofline_frac"],
        "roofline_bound": roof["bound"],
        "roofline": roof,
        # Registry counter deltas over the whole bench (repro.obs.metrics):
        # phase/pipeline/cache/solver telemetry.  stream.* is excluded -- the
        # mid-bench reset_stream_stats() breaks delta monotonicity for it,
        # and the byte counters already live in the build/solve blocks.
        "metrics": {
            k: v for k, v in _obs_registry().delta(m0).items()
            if not k.startswith("stream.")
        },
    }
    Path(out_path).write_text(json.dumps(result, indent=2))
    out(f"[bench_oochain] trajectory: build {build_s:.2f}s, solve "
        f"{solve_s:.2f}s/{rep.iterations} its, {sst.bytes_h2d / 1e6:.1f} MB "
        f"H2D ({sst.bytes_h2d_saved / 1e6:.1f} MB saved), roofline "
        f"{roof['roofline_frac']:.2e} ({roof['bound']}-bound); wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--grid", type=int, default=None, help="store/scratch tiles per side")
    ap.add_argument("--budget-mb", type=float, default=1.0)
    ap.add_argument("--sweep", action="store_true",
                    help="prefetch-depth x codec x solver-batch sweep with "
                         "bytes-moved columns")
    ap.add_argument("--out", default=None, help="write results JSON here")
    ap.add_argument("--trajectory", default=None, metavar="PATH",
                    help="write the canonical fixed-config perf-trajectory "
                         "artifact (BENCH_oochain.json) and exit")
    args = ap.parse_args()
    if args.trajectory:
        trajectory(args.trajectory)
        return
    run(n=args.n, d=args.d, q=args.q, grid=args.grid, budget_mb=args.budget_mb,
        do_sweep=args.sweep, out_path=args.out)


if __name__ == "__main__":
    main()
