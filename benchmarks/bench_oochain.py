"""Out-of-core chain build: streamed (store-backed S/T/P) vs resident, and
the max-n-under-budget table for the chain working set.

The chain product is the O(n^3) hot spot AND (after the PR-2 snapshot store
removed the adjacency term) the remaining HBM bound: a resident build holds
~5 n^2 fp32 matrices (S, T, P, P1, P2).  The out-of-core build spills them
through a TileStore scratch and keeps only O(n * panel) on device; this
benchmark measures both paths, verifies the scores stay allclose, and emits
the max n that fits a given device budget for each mode as JSON.

  PYTHONPATH=src python benchmarks/bench_oochain.py --n 256 --d 4 \
      --budget-mb 1.0 --out benchmarks/bench_oochain.json
"""

from __future__ import annotations

import argparse
import json
import math
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import (
    CommuteConfig,
    chain_product,
    detect_anomalies,
    reset_stream_stats,
    stream_stats,
    trivial_context,
)
from repro.store import TileStore


def _sym(n: int, seed: int) -> np.ndarray:
    a = np.abs(np.random.default_rng(seed).normal(size=(n, n))).astype(np.float32)
    a = (a + a.T) / 2.0
    np.fill_diagonal(a, 0.0)
    return a


def run(n=256, d=4, q=4, grid=None, budget_mb=1.0, out_path=None, out=print):
    ctx = trivial_context()
    budget = int(budget_mb * 1e6)
    a1, a2 = _sym(n, 0), _sym(n, 1)
    store = TileStore.create(None, n=n, grid=grid or 8)
    h1, h2 = store.put_snapshot("t0", a1), store.put_snapshot("t1", a2)
    work = TileStore.create(None, n=n, grid=grid or 8)
    ph = store.tile_rows
    cfg = CommuteConfig(eps_rp=1e-2, d=d, q=q, schedule="xla")
    cfg_oo = CommuteConfig(eps_rp=1e-2, d=d, q=q, schedule="xla", oocore=True)

    # -- resident chain build (warm both once for compile parity) ------------
    chain_product(ctx, ctx.put_matrix(a1), d, schedule="xla")
    t0 = time.perf_counter()
    op_r = chain_product(ctx, ctx.put_matrix(a1), d, schedule="xla")
    jax.block_until_ready(op_r.p2)
    resident_s = time.perf_counter() - t0
    resident_peak = 5 * n * n * 4  # S, T, P, P1, P2 fp32

    # -- out-of-core chain build --------------------------------------------
    chain_product(ctx, h1, d, schedule="xla", oocore=True,
                  oocore_work=work, oocore_panel_rows=ph)
    reset_stream_stats()
    t0 = time.perf_counter()
    op_o = chain_product(ctx, h1, d, schedule="xla", oocore=True,
                         oocore_work=work, oocore_panel_rows=ph)
    oocore_s = time.perf_counter() - t0
    st = stream_stats()

    np.testing.assert_allclose(op_o.p2.to_numpy(), np.asarray(op_r.p2),
                               rtol=1e-3, atol=1e-3)
    res_r = detect_anomalies(ctx, ctx.put_matrix(a1), ctx.put_matrix(a2), cfg, top_k=10)
    res_o = detect_anomalies(ctx, h1, h2, cfg_oo, top_k=10)
    close = bool(np.allclose(np.asarray(res_o.scores), np.asarray(res_r.scores),
                             rtol=1e-4, atol=1e-3))

    out(f"[bench_oochain] n={n} d={d} panel={ph} rows "
        f"({n * n * 4 / 1e6:.2f} MB/matrix, resident chain set "
        f"{resident_peak / 1e6:.2f} MB)")
    out(f"[bench_oochain] resident build: {resident_s:.2f}s, "
        f"peak chain residency {resident_peak / 1e6:.2f} MB "
        f"-> {'WITHIN' if resident_peak <= budget else 'OVER'} "
        f"{budget / 1e6:.2f} MB budget")
    out(f"[bench_oochain] oocore build:   {oocore_s:.2f}s, "
        f"peak device panel residency {st.peak_live_bytes / 1e6:.2f} MB "
        f"({st.panels} panels, {st.bytes_h2d / 1e6:.1f} MB H2D) "
        f"-> {'WITHIN' if st.peak_live_bytes <= budget else 'OVER'} budget")
    out(f"[bench_oochain] end-to-end scores allclose: {close}")

    # -- max n within the device budget, per mode ----------------------------
    # resident: 5 n^2 * 4 bytes.  oocore with a g x g scratch grid: one
    # accumulator panel + one streamed panel + one block ~= 3 * (n/g) * n * 4.
    n_res = int(math.isqrt(budget // 20))
    table = []
    for g in (4, 8, 16, 32):
        n_oo = int(math.isqrt(budget * g // 12))
        table.append({"grid": g, "max_n_oocore": n_oo})
        out(f"[bench_oochain] budget {budget / 1e6:.2f} MB: max n resident ~{n_res}, "
            f"oocore grid={g} ~{n_oo} ({n_oo / max(n_res, 1):.1f}x)")

    result = {
        "bench": "oochain",
        "n": n, "d": d, "q": q, "panel_rows": ph,
        "budget_mb": budget / 1e6,
        "resident_s": resident_s,
        "oocore_s": oocore_s,
        "resident_peak_mb": resident_peak / 1e6,
        "oocore_peak_mb": st.peak_live_bytes / 1e6,
        "oocore_panels": st.panels,
        "oocore_h2d_mb": st.bytes_h2d / 1e6,
        "resident_within_budget": resident_peak <= budget,
        "oocore_within_budget": st.peak_live_bytes <= budget,
        "scores_allclose": close,
        "max_n_resident": n_res,
        "max_n_oocore": table,
    }
    if out_path:
        Path(out_path).write_text(json.dumps(result, indent=2))
        out(f"[bench_oochain] wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--grid", type=int, default=None, help="store/scratch tiles per side")
    ap.add_argument("--budget-mb", type=float, default=1.0)
    ap.add_argument("--out", default=None, help="write results JSON here")
    args = ap.parse_args()
    run(n=args.n, d=args.d, q=args.q, grid=args.grid, budget_mb=args.budget_mb,
        out_path=args.out)


if __name__ == "__main__":
    main()
