"""Out-of-core scoring: streamed (store-backed) vs resident throughput,
and the max-n-before-OOM picture under a per-device adjacency residency budget.

The acceptance demo for the snapshot store: a T-snapshot sequence whose
*total adjacency bytes exceed the configured per-device residency budget* is
written to disk tile-by-tile (the n x n snapshots are never materialized on
the host either) and scored end-to-end by the streaming tile executor, whose
measured peak adjacency residency stays within the budget.  The resident
baseline must hold two full snapshots and busts the same budget at much
smaller n.

The budget governs *adjacency* residency -- the term the store eliminates.
The chain matrices (S, P, P1, P2) remain device-resident either way; that is
the next scale axis (see ROADMAP "Open items").

  PYTHONPATH=src python benchmarks/bench_store.py --n 512 --t-steps 4 \
      --grid 8 --budget-mb 1.0 --out benchmarks/bench_store.json
"""

from __future__ import annotations

import argparse
import json
import math
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import (
    CommuteConfig,
    SequenceDetector,
    reset_stream_stats,
    stream_stats,
    trivial_context,
)
from repro.graphs import gmm_store_sequence
from repro.store import TileStore


def run(n=512, t_steps=4, grid=8, budget_mb=1.0, d=4, q=6, eps=1e-2,
        store_dir=None, out_path=None, out=print):
    if t_steps < 2:
        raise ValueError(f"need at least 2 snapshots to score a transition, got t_steps={t_steps}")
    ctx = trivial_context()
    cfg = CommuteConfig(eps_rp=eps, d=d, q=q, schedule="xla")
    budget = int(budget_mb * 1e6)

    tmp = None
    if store_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="caddelag_store_")
        store_dir = tmp.name

    # -- write the sequence tile-by-tile (fully out-of-core) ----------------
    t0 = time.perf_counter()
    store = TileStore.create(store_dir, n=n, grid=grid,
                             meta={"dataset": "gmm-store", "n": n, "seed": 0})
    ids = gmm_store_sequence(store, t_steps, seed=0)
    write_s = time.perf_counter() - t0
    total_bytes = t_steps * store.snapshot_nbytes
    panel_bytes = store.tile_rows * n * 4

    # -- streamed pass: adjacencies never fully device-resident -------------
    reset_stream_stats()
    det = SequenceDetector(ctx, cfg, top_k=10)
    t0 = time.perf_counter()
    res_s = det.run(store.snapshot(sid) for sid in ids)
    jax.block_until_ready(res_s.transitions[-1].scores)
    stream_s = time.perf_counter() - t0
    st = stream_stats()

    # -- resident pass: each snapshot loaded whole (the old path) -----------
    det = SequenceDetector(ctx, cfg, top_k=10)
    t0 = time.perf_counter()
    res_r = det.run(ctx.put_matrix(store.snapshot(sid).to_numpy()) for sid in ids)
    jax.block_until_ready(res_r.transitions[-1].scores)
    resident_s = time.perf_counter() - t0
    resident_peak = 2 * store.snapshot_nbytes  # engine keeps two endpoints

    bitwise = all(
        np.array_equal(np.asarray(a.scores), np.asarray(b.scores))
        for a, b in zip(res_s.transitions, res_r.transitions)
    )

    out(f"[bench_store] n={n} T={t_steps} grid={grid}x{grid} "
        f"({store.snapshot_nbytes / 1e6:.1f} MB/snapshot, {total_bytes / 1e6:.1f} MB total, "
        f"written in {write_s:.1f}s)")
    out(f"[bench_store] budget {budget / 1e6:.2f} MB: total/budget = {total_bytes / budget:.1f}x "
        f"{'(exceeds budget -- the out-of-core case)' if total_bytes > budget else ''}")
    out(f"[bench_store] streamed: {stream_s:.1f}s "
        f"({(t_steps - 1) and stream_s / (t_steps - 1):.2f}s/transition), "
        f"peak adjacency residency {st.peak_live_bytes / 1e6:.2f} MB "
        f"({st.panels} panels, {st.bytes_h2d / 1e6:.1f} MB H2D) "
        f"-> {'WITHIN' if st.peak_live_bytes <= budget else 'OVER'} budget")
    out(f"[bench_store] resident: {resident_s:.1f}s, "
        f"peak adjacency residency {resident_peak / 1e6:.2f} MB "
        f"-> {'WITHIN' if resident_peak <= budget else 'OVER'} budget")
    out(f"[bench_store] streamed == resident scores (bitwise): {bitwise}")

    # -- max-n before the budget OOMs the adjacency working set -------------
    # resident: two full snapshots, 2 * n^2 * 4 bytes.
    # streamed: four in-flight panels (2 operands x double buffer),
    #           4 * (n/grid) * n * 4 bytes.
    n_res = int(math.isqrt(budget // 8))
    n_str = int(math.isqrt(budget * grid // 16))
    out(f"[bench_store] max n within {budget / 1e6:.2f} MB adjacency budget: "
        f"resident ~{n_res}, streamed (grid={grid}) ~{n_str} "
        f"({n_str / max(n_res, 1):.1f}x)")

    result = {
        "bench": "store",
        "n": n, "t_steps": t_steps, "grid": grid,
        "snapshot_mb": store.snapshot_nbytes / 1e6,
        "total_mb": total_bytes / 1e6,
        "budget_mb": budget / 1e6,
        "total_exceeds_budget": total_bytes > budget,
        "write_s": write_s,
        "streamed_s": stream_s,
        "resident_s": resident_s,
        "streamed_peak_mb": st.peak_live_bytes / 1e6,
        "streamed_panels": st.panels,
        "streamed_h2d_mb": st.bytes_h2d / 1e6,
        "streamed_within_budget": st.peak_live_bytes <= budget,
        "resident_peak_mb": resident_peak / 1e6,
        "resident_within_budget": resident_peak <= budget,
        "panel_mb": panel_bytes / 1e6,
        "bitwise_equal": bitwise,
        "max_n_resident": n_res,
        "max_n_streamed": n_str,
    }
    if out_path:
        Path(out_path).write_text(json.dumps(result, indent=2))
        out(f"[bench_store] wrote {out_path}")
    if tmp is not None:
        tmp.cleanup()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--t-steps", type=int, default=4)
    ap.add_argument("--grid", type=int, default=8)
    ap.add_argument("--budget-mb", type=float, default=1.0)
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--q", type=int, default=6)
    ap.add_argument("--eps", type=float, default=1e-2)
    ap.add_argument("--store-dir", default=None, help="persist the store (default: temp dir)")
    ap.add_argument("--out", default=None, help="write results JSON here")
    args = ap.parse_args()
    run(n=args.n, t_steps=args.t_steps, grid=args.grid, budget_mb=args.budget_mb,
        d=args.d, q=args.q, eps=args.eps, store_dir=args.store_dir, out_path=args.out)


if __name__ == "__main__":
    main()
