"""Paper section 3.2 / Fig. 1: shuffle-free block matmul vs the naive path.

The paper's contribution: Spark's BlockMatrix.multiply replicates blocks
through the shuffle (O(n^3/p) shuffle bytes); their write-once/read-many
scheme moves O(n^2).  TPU mapping measured here, per schedule, by compiling
C = A @ B on a fake 16-device mesh and counting *collective bytes* in the
post-SPMD HLO (the ICI traffic that the roofline's collective term prices):

  xla    -- XLA SPMD default: all-gathers a full operand panel (the moral
            equivalent of the shuffle replication)
  summa  -- explicit row/column panels under shard_map
  cannon -- systolic nearest-neighbor ring: O(n^2/P) resident, only
            collective-permute traffic, overlappable with the local GEMM

Also measures wall-time on a real 4-device CPU mesh for the same shapes.
"""

from __future__ import annotations

import time

import numpy as np


def run(n: int = 1024, out=print):
    # collective-bytes comparison needs many fake devices -> subprocess
    import json
    import subprocess
    import sys

    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh
from repro.core import make_context, matmul
from repro.launch import hlo_analysis as ha

mesh = jax.make_mesh((4, 4), ("data", "model"))
ctx = make_context(mesh)
res = {{}}
for sched in ("xla", "summa", "cannon"):
    f = jax.jit(lambda a, b: matmul(ctx, a, b, schedule=sched))
    sds = jax.ShapeDtypeStruct(({n}, {n}), jnp.float32,
                               sharding=jax.sharding.NamedSharding(mesh, ctx.matrix_spec))
    c = f.lower(sds, sds).compile()
    a = ha.analyze(c.as_text())
    res[sched] = {{"coll_bytes": a["collective_total_bytes"],
                   "by_type": {{k: v for k, v in a["collective_bytes"].items() if v}},
                   "dot_flops": a["dot_flops"]}}
print(json.dumps(res))
"""
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    if proc.returncode == 0:
        res = json.loads(proc.stdout.strip().splitlines()[-1])
        base = res["xla"]["coll_bytes"]
        for sched, r in res.items():
            ratio = base / max(r["coll_bytes"], 1)
            out(
                f"bench_matmul,sched={sched},coll_bytes={r['coll_bytes']:.3e},"
                f"vs_xla={ratio:.2f}x,types={r['by_type']}"
            )
    else:
        out(f"bench_matmul,subprocess_error,{proc.stderr[-200:]}")

    # wall-time on the real 4-device mesh
    import jax
    import jax.numpy as jnp

    if len(jax.devices()) >= 4:
        from jax.sharding import Mesh

        from repro.core import make_context, matmul

        devs = np.array(jax.devices()[:4]).reshape(2, 2)
        ctx = make_context(Mesh(devs, ("data", "model")))
        rng = np.random.default_rng(0)
        a = ctx.put_matrix(rng.normal(size=(n, n)).astype(np.float32))
        b = ctx.put_matrix(rng.normal(size=(n, n)).astype(np.float32))
        for sched in ("xla", "summa", "cannon"):
            f = jax.jit(lambda x, y, s=sched: matmul(ctx, x, y, schedule=s))
            f(a, b).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(3):
                f(a, b).block_until_ready()
            dt = (time.perf_counter() - t0) / 3
            out(f"bench_matmul,sched={sched},n={n},us_per_call={dt*1e6:.0f}")


if __name__ == "__main__":
    run()
