"""Benchmark harness: one benchmark per paper table/figure.

  bench_accuracy   -- Fig 2a/2b: relative error vs eps_RP, d, q
  bench_scaling    -- Fig 3a/3b: runtime vs n, runtime vs workers (derived)
  bench_blocksize  -- Fig 3c: runtime vs block (tile) size
  bench_matmul     -- section 3.2 / Fig 1: shuffle-free vs naive collective bytes
  bench_sequence   -- sequence engine: chain-operator reuse vs pairwise rebuilds
  roofline         -- per (arch x shape x mesh) roofline terms from the dry-run

Prints ``name,metric,value`` CSV lines.  ``python -m benchmarks.run [--fast]``
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sizes")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    args = ap.parse_args()

    from benchmarks import (
        bench_accuracy,
        bench_blocksize,
        bench_matmul,
        bench_scaling,
        bench_sequence,
        roofline,
    )

    benches = {
        "accuracy": lambda: bench_accuracy.run(n=256 if args.fast else 512),
        "scaling": lambda: bench_scaling.run(sizes=(96, 128, 192) if args.fast else (128, 256, 512)),
        "blocksize": lambda: bench_blocksize.run(n=256 if args.fast else 512),
        "matmul": lambda: bench_matmul.run(n=512 if args.fast else 1024),
        "sequence": lambda: bench_sequence.run(n=128 if args.fast else 256, t_steps=4),
        "roofline": lambda: roofline.run(),
    }
    chosen = args.only.split(",") if args.only else list(benches)
    t0 = time.time()
    for name in chosen:
        print(f"# === {name} ===", flush=True)
        try:
            benches[name]()
        except Exception as e:  # keep the harness going; record the failure
            print(f"{name},ERROR,{type(e).__name__}:{e}", file=sys.stderr)
            print(f"{name},error,{type(e).__name__}")
    print(f"# total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
