"""Query-path benchmarks: artifact-read latency, residency, and scorer AUC.

``speedup`` -- the ISSUE 10 acceptance bar: answering "top-k most anomalous
nodes now" from a persisted :class:`~repro.store.embstore.EmbeddingStore`
artifact must be >= 10x faster (n >= 512) than re-deriving the same answer
through the write path (chain build + edge projection + solve).  The read
path streams the (n, k_RP) sketch in row panels through the fused
distance/top-k kernel -- O(n k_RP) work against the write path's O(n^3)
GEMMs -- so the gap should widen with n.  Both paths run after untimed
warm-up (shared compile cache); asserted, not just reported.

Also asserted here: the query is *panel-bounded* -- the streaming
executors' ``peak_live_bytes`` gauge stays within 2 staged panels of the
one streamed operand (prefetch depth x one Z panel), independent of n.

``auc`` -- scorer quality on the labeled degenerate-regime fixture
(:func:`repro.graphs.gmm_snapshot_sequence` with ``anomaly_nodes`` +
``dim_nodes``): a planted satellite clump (structural anomalies, labeled 1)
plus degree-dimmed distractors at normal positions (labeled 0).  The
sketch-based scorers must land within 0.02 ROC-AUC of the O(n^3)
eigendecomposition oracle (:func:`exact_commute_distances`), and the von
Luxburg corrected scorer must do no worse than the raw one on this fixture
-- raw commute distance rewards the distractors' 1/deg term, the corrected
score subtracts exactly that.

``trajectory`` -- the weekly ``BENCH_query.json`` artifact: both sections
under a stable schema, diffable week over week.

  PYTHONPATH=src python benchmarks/bench_query.py
  PYTHONPATH=src python benchmarks/bench_query.py --trajectory BENCH_query.json
"""

from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import CommuteConfig, SequenceDetector, trivial_context
from repro.core.embedding import commute_time_embedding, exact_commute_distances
from repro.core.query import rank_auc, top_anomalies_from_store
from repro.core.tiles import reset_stream_stats, stream_stats
from repro.graphs import gmm_snapshot_sequence
from repro.store.embstore import EmbeddingStore


def _write_path_score(ctx, a, cfg, top_k):
    """The full re-derivation a query replaces: chain + project + solve +
    centroid score.  Returns the top-k node ids (for sanity checks)."""
    emb = commute_time_embedding(ctx, a, cfg)
    z = np.asarray(emb.z, np.float64)
    scores = float(emb.vol) * ((z - z.mean(0)) ** 2).sum(1)
    return np.argsort(-scores)[:top_k]


def speedup(n=512, top_k=10, codec="raw", repeats=5, out=print):
    """Artifact query vs full-pipeline re-score at the same n; >= 10x bar."""
    ctx = trivial_context()
    cfg = CommuteConfig(eps_rp=1e-2, d=6, q=8, schedule="xla")
    seq = gmm_snapshot_sequence(ctx, n, 2, seed=0, inject_p=0.02)
    snaps = list(seq.snapshots())
    a = snaps[-1]

    with tempfile.TemporaryDirectory() as root:
        store = EmbeddingStore.create(
            root, n=n, k=cfg.k_rp(n), codec=codec, seed=cfg.seed
        )
        det = SequenceDetector(ctx, cfg, emb_store=store)
        for s in snaps:
            det.push(s)  # write path: artifacts published as a side effect

        # untimed warm-up on both sides (shared XLA / Pallas compile cache)
        top_anomalies_from_store(store, top_k)
        _write_path_score(ctx, a, cfg, top_k)

        reset_stream_stats()
        q_times, res = [], None
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = top_anomalies_from_store(store, top_k)
            q_times.append(time.perf_counter() - t0)
        st = stream_stats()
        panel_bytes = store.manifest.panel_rows * store.manifest.k * (
            2 if codec == "bf16" else 4
        )
        peak = st.peak_live_bytes

        r_times = []
        for _ in range(max(2, repeats // 2)):
            t0 = time.perf_counter()
            rebuilt = _write_path_score(ctx, a, cfg, top_k)
            r_times.append(time.perf_counter() - t0)

        q_ms, r_ms = 1e3 * min(q_times), 1e3 * min(r_times)
        ratio = r_ms / q_ms
        overlap = len(set(res.idx.tolist()) & set(rebuilt.tolist()))
        out(
            f"[bench_query] n={n} codec={codec}: query {q_ms:.1f} ms vs "
            f"re-score {r_ms:.1f} ms -> {ratio:.1f}x "
            f"(panels={res.panels} bytes_read={res.bytes_read} "
            f"top-{top_k} overlap {overlap}/{top_k})"
        )
        out(
            f"[bench_query] residency: peak_live_bytes={peak} "
            f"<= 2 x panel ({2 * panel_bytes}) -> "
            f"{'OK' if peak <= 2 * panel_bytes else 'OVER'}"
        )
        assert n < 512 or ratio >= 10.0, (
            f"query path only {ratio:.1f}x faster than re-score at n={n} "
            f"(bar: 10x at n >= 512)"
        )
        assert peak <= 2 * panel_bytes, (
            f"query not panel-bounded: peak_live_bytes={peak} > "
            f"2 x panel_bytes={2 * panel_bytes}"
        )
        return {
            "n": n,
            "codec": codec,
            "query_ms": q_ms,
            "rescore_ms": r_ms,
            "ratio": ratio,
            "panels": res.panels,
            "bytes_read": res.bytes_read,
            "peak_live_bytes": peak,
            "panel_bytes": panel_bytes,
            "topk_overlap": overlap,
            "pass": bool((n < 512 or ratio >= 10.0) and peak <= 2 * panel_bytes),
        }


def auc(n=256, n_anom=8, n_dim=24, out=print):
    """Scorer ROC-AUC vs the exact oracle on the degenerate-regime fixture."""
    ctx = trivial_context()
    cfg = CommuteConfig(k_override=64, d=8, q=12, seed=0)
    seq = gmm_snapshot_sequence(
        ctx, n, 2, seed=0, anomaly_nodes=n_anom, dim_nodes=n_dim,
        inject_steps=set(),
    )
    labels = seq.labels
    a0 = None
    with tempfile.TemporaryDirectory() as root:
        store = EmbeddingStore.create(root, n=n, k=64, seed=0)
        det = SequenceDetector(ctx, cfg, emb_store=store)
        for t, s in enumerate(seq.snapshots()):
            if t == 0:
                a0 = np.asarray(s, np.float64)
            det.push(s)

        c = np.asarray(exact_commute_distances(a0), np.float64)
        deg = a0.sum(1)
        vol = deg.sum()
        exact_raw = c.mean(1)
        exact_corr = (c / vol - (1 / deg)[:, None] - (1 / deg)[None, :]).mean(1)

        handle = store.embedding("t0000")
        s_raw = np.empty(n)
        s_corr = np.empty(n)
        r = top_anomalies_from_store(handle, n)
        s_raw[r.idx] = r.val
        r = top_anomalies_from_store(handle, n, corrected=True)
        s_corr[r.idx] = r.val

    res = {
        "n": n,
        "anomaly_nodes": n_anom,
        "dim_nodes": n_dim,
        "auc_exact_raw": rank_auc(labels, exact_raw),
        "auc_exact_corrected": rank_auc(labels, exact_corr),
        "auc_approx_raw": rank_auc(labels, s_raw),
        "auc_approx_corrected": rank_auc(labels, s_corr),
    }
    gap_raw = abs(res["auc_approx_raw"] - res["auc_exact_raw"])
    gap_corr = abs(res["auc_approx_corrected"] - res["auc_exact_corrected"])
    corr_wins = res["auc_approx_corrected"] >= res["auc_approx_raw"]
    out(
        f"[bench_query] auc n={n} (+{n_anom} planted, {n_dim} dimmed): "
        f"raw exact {res['auc_exact_raw']:.3f} approx "
        f"{res['auc_approx_raw']:.3f}; corrected exact "
        f"{res['auc_exact_corrected']:.3f} approx "
        f"{res['auc_approx_corrected']:.3f}"
    )
    assert gap_raw <= 0.02 and gap_corr <= 0.02, (
        f"approximate scorer drifted from the exact oracle: "
        f"raw gap {gap_raw:.3f}, corrected gap {gap_corr:.3f} (bar: 0.02)"
    )
    assert corr_wins, (
        f"corrected scorer below raw on the degenerate fixture: "
        f"{res['auc_approx_corrected']:.3f} < {res['auc_approx_raw']:.3f}"
    )
    res["pass"] = bool(gap_raw <= 0.02 and gap_corr <= 0.02 and corr_wins)
    return res


def trajectory(out_path, out=print):
    """Canonical perf-trajectory artifact (``BENCH_query.json``), schema 1:
    the >= 10x latency section (raw and bf16 artifacts) plus the scorer-AUC
    section, so both query-latency and scorer-quality regressions show up in
    the weekly artifact diff."""
    sp = {c: speedup(codec=c, out=out) for c in ("raw", "bf16")}
    auc_res = auc(out=out)
    result = {
        "bench": "query_trajectory",
        "schema": 1,
        "speedup": sp,
        "auc": auc_res,
        "all_pass": all(s["pass"] for s in sp.values()) and auc_res["pass"],
    }
    Path(out_path).write_text(json.dumps(result, indent=2))
    out(f"[bench_query] trajectory: all_pass={result['all_pass']}; wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--top-k", type=int, default=10)
    ap.add_argument("--codec", default="raw", choices=("raw", "bf16"))
    ap.add_argument("--speedup", action="store_true",
                    help="only the >= 10x latency + residency section")
    ap.add_argument("--auc", action="store_true",
                    help="only the scorer ROC-AUC section")
    ap.add_argument("--trajectory", default=None, metavar="PATH",
                    help="write the BENCH_query.json artifact and exit")
    args = ap.parse_args()
    if args.trajectory:
        trajectory(args.trajectory)
        return
    if args.speedup or not args.auc:
        speedup(n=args.n, top_k=args.top_k, codec=args.codec)
    if args.auc or not args.speedup:
        auc()


if __name__ == "__main__":
    main()
