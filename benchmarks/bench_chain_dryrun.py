"""Paper-technique roofline: the chain product at production scale.

Lowers ChainProduct (Algorithm 2, d levels of distributed n x n GEMMs) on
the 16x16 production mesh for each matmul schedule and reports the
trip-count-corrected per-device FLOPs + collective bytes:

  xla    -- XLA SPMD default (all-gather panels): the Spark BlockMatrix
            "shuffle" analogue == the paper's BASELINE
  summa  -- explicit panels (paper-faithful write-once/read-many: every
            block read exactly where needed, no replication through an
            opaque shuffle)
  cannon -- systolic nearest-neighbor rings (BEYOND-paper: O(n^2/P)
            residency, permute traffic only, overlappable with the GEMM)

This is the experiment behind EXPERIMENTS.md section Perf (CADDeLaG cell).
Run inside the dry-run env (512 host devices):

  XLA_FLAGS=--xla_force_host_platform_device_count=512 \
      PYTHONPATH=src python -m benchmarks.bench_chain_dryrun [--n 65536]
"""

from __future__ import annotations

import argparse
import json
import os


def run(n: int = 65536, d_len: int = 6, out=print):
    import subprocess
    import sys

    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding
from repro.core import make_context, chain_product
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()
ctx = make_context(mesh)
res = {{}}
for sched in ("xla", "summa", "cannon"):
    fn = jax.jit(lambda a: chain_product(ctx, a, {d_len}, schedule=sched, fuse_l=True))
    sds = jax.ShapeDtypeStruct(({n}, {n}), jnp.float32,
                               sharding=NamedSharding(mesh, ctx.matrix_spec))
    c = fn.lower(sds).compile()
    a = ha.analyze(c.as_text())
    mem = c.memory_analysis()
    res[sched] = {{
        "dot_flops": a["dot_flops"],
        "coll_bytes": a["collective_total_bytes"],
        "by_type": {{k: v for k, v in a["collective_bytes"].items() if v}},
        "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
    }}
print(json.dumps(res))
"""
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, timeout=3000,
    )
    if proc.returncode != 0:
        out(f"bench_chain_dryrun,error,{proc.stderr[-300:]}")
        return None
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    peak, ici = 197e12, 50e9
    for sched, r in res.items():
        t_comp = r["dot_flops"] / peak
        t_coll = r["coll_bytes"] / ici
        out(
            f"bench_chain_dryrun,n={n},d={d_len},sched={sched},"
            f"t_comp_ms={t_comp*1e3:.0f},t_coll_ms={t_coll*1e3:.0f},"
            f"temp_gb={r['temp_gb']:.1f},types={r['by_type']}"
        )
    os.makedirs("experiments/perf", exist_ok=True)
    with open(f"experiments/perf/chain_schedules_n{n}.json", "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--d", type=int, default=6)
    args = ap.parse_args()
    run(n=args.n, d_len=args.d)
