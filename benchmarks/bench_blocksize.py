"""Paper Fig. 3c: runtime vs block size.

The paper tunes the Spark block size p (their optimum: smallest block the
resource budget allows; too large hurts I/O overlap).  The TPU analogue is
the Pallas tile shape (bm, bk, bn): VMEM residency and MXU utilization vs
HBM streaming granularity.  On CPU (interpret mode) we measure the kernel
wall-time trend and ALSO report the structural metric that matters on TPU:
VMEM bytes per tile (must fit ~16 MiB with double buffering).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def run(n: int = 512, out=print):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))

    for tile in (32, 64, 128, 256):
        fn = lambda: ops.block_matmul(a, b, bm=tile, bk=tile, bn=tile)
        o = fn()
        o.block_until_ready()  # compile
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            fn().block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        # VMEM model: A + B tiles (fp32 here; bf16 on TPU) + fp32 acc + out
        vmem = (tile * tile * 4) * 2 + tile * tile * 4 * 2
        grid = (n // tile) ** 3
        out(
            f"bench_blocksize,tile={tile},us_per_call={dt*1e6:.0f},"
            f"vmem_kib_per_tile={vmem//1024},grid_cells={grid}"
        )
    out("bench_blocksize,note,TPU target: largest MXU-aligned tile whose "
        "working set fits VMEM with double buffering (256 for bf16)")


if __name__ == "__main__":
    run()
