"""Roofline report: reads experiments/dryrun/*.json, emits the three-term
table per (arch x shape x mesh).

    compute    = dot_flops_per_device / peak_flops          [s]
    memory     = hbm_bytes_per_device / hbm_bw              [s]
    collective = collective_bytes_per_device / ici_bw       [s]

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  dot_flops and collective bytes are trip-count-corrected from the
compiled HLO (launch/hlo_analysis.py); HLO "bytes accessed" is XLA's
uncorrected estimate, so the memory term uses max(raw, params+activations
model) -- see EXPERIMENTS.md for the derivation per cell.

MODEL_FLOPS uses 6*N*D (dense) / 6*N_active*D (MoE) for train,
2*N(_active)*D for inference; the ratio MODEL/HLO flags remat/redundancy.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link
DISK_BW = 2.0e9  # bytes/s sustained scratch-store read (NVMe-class)
H2D_BW = 32e9  # bytes/s host->device staging (PCIe gen4 x16-class)

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


# ---------------------------------------------------------------------------
# streamed-solve roofline: the out-of-core solve is bound by whichever of
# disk read, H2D staging, or MXU FLOPs saturates first -- all three are
# measured (stream_stats byte counters) or derivable (iteration count), so
# bench_oochain / bench_solver can report measured-vs-bound directly.
# ---------------------------------------------------------------------------


def streamed_solve_flops(n: int, k: int, iterations: int) -> float:
    """Dense FLOPs of a streamed solve: one (n x n) @ (n x k) mat-vec per
    iteration plus the chi build (P1 @ b), 2nk per MAC row."""
    return 2.0 * n * n * k * (iterations + 1)


def streamed_solve_roofline(
    *,
    bytes_read: float,
    bytes_h2d: float,
    flops: float,
    seconds: float,
    disk_bw: float = DISK_BW,
    h2d_bw: float = H2D_BW,
    peak_flops: float = PEAK_FLOPS,
) -> dict:
    """Three-term bound for a streamed solve, from measured traffic.

    ``bound_s = max(read/disk_bw, h2d/h2d_bw, flops/peak)`` is the fastest
    the solve could have gone on the modeled hardware; ``roofline_frac =
    bound_s / seconds`` is the fraction of that bound actually achieved
    (CPU-interpret runs will sit far below 1 -- the *trajectory* of the
    fraction and of the byte terms across PRs is the signal, the absolute
    value only means something on real accelerator + NVMe tiers).
    """
    t_disk = bytes_read / disk_bw
    t_h2d = bytes_h2d / h2d_bw
    t_flop = flops / peak_flops
    bound_s, bound = max(
        (t_disk, "disk"), (t_h2d, "h2d"), (t_flop, "compute")
    )
    return {
        "t_disk_s": t_disk,
        "t_h2d_s": t_h2d,
        "t_compute_s": t_flop,
        "bound": bound,
        "bound_s": bound_s,
        "measured_s": seconds,
        "roofline_frac": bound_s / seconds if seconds > 0 else 0.0,
    }

# active params for MoE archs (top-k experts + shared + attention + embed)
ACTIVE_PARAMS = {
    "llama4-maverick-400b-a17b": 17.2e9,
    "granite-moe-3b-a800m": 0.94e9,  # 8/40 experts + attn + embed
}


def load_records(dryrun_dir=None):
    d = dryrun_dir or DRYRUN_DIR
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def roofline_row(r: dict) -> dict:
    chips = r["chips"]
    ana = r["hlo_analysis"]
    flops_dev = ana["dot_flops"]  # already per-device (post-SPMD module)
    coll_dev = ana["collective_total_bytes"]
    raw_bytes = r["cost_analysis_raw"].get("bytes_accessed", 0.0)

    n = r["n_params"]
    n_active = ACTIVE_PARAMS.get(r["arch"], n)
    tokens = r["global_batch"] * (r["seq_len"] if r["kind"] == "train" else 1)
    if r["kind"] == "train":
        model_flops = 6.0 * n_active * r["global_batch"] * r["seq_len"]
    elif r["kind"] == "prefill":
        model_flops = 2.0 * n_active * r["global_batch"] * r["seq_len"]
    else:  # decode: one token per sequence
        model_flops = 2.0 * n_active * r["global_batch"]

    # memory term: HLO bytes-accessed is while-body-once; floor it with the
    # structural minimum (params read once + grads/opt write for train)
    param_bytes = n * (2 if "bf16" in str(r.get("arch")) else 4)  # coarse
    mem_bytes = max(raw_bytes, param_bytes / chips)

    t_comp = flops_dev / PEAK_FLOPS
    t_mem = mem_bytes / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))[1]
    useful = model_flops / chips / max(flops_dev, 1.0)
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "mesh": "x".join(str(v) for v in r["mesh"].values()),
        "chips": chips,
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_dev": flops_dev,
        "useful_ratio": useful,
        "roofline_frac": useful * min(1.0, t_comp / max(t_comp, t_mem, t_coll)),
    }


def run(out=print, dryrun_dir=None):
    recs = load_records(dryrun_dir)
    if not recs:
        out("bench_roofline,no_dryrun_records,run launch/dryrun.py first")
        return []
    rows = [roofline_row(r) for r in recs]
    out("bench_roofline,arch,shape,mesh,t_comp_ms,t_mem_ms,t_coll_ms,dominant,useful_ratio")
    for w in rows:
        out(
            f"bench_roofline,{w['arch']},{w['shape']},{w['mesh']},"
            f"{w['t_compute_s']*1e3:.2f},{w['t_memory_s']*1e3:.2f},"
            f"{w['t_collective_s']*1e3:.2f},{w['dominant']},{w['useful_ratio']:.3f}"
        )
    return rows


if __name__ == "__main__":
    run()
