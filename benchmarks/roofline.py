"""Roofline report: reads experiments/dryrun/*.json, emits the three-term
table per (arch x shape x mesh).

    compute    = dot_flops_per_device / peak_flops          [s]
    memory     = hbm_bytes_per_device / hbm_bw              [s]
    collective = collective_bytes_per_device / ici_bw       [s]

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  dot_flops and collective bytes are trip-count-corrected from the
compiled HLO (launch/hlo_analysis.py); HLO "bytes accessed" is XLA's
uncorrected estimate, so the memory term uses max(raw, params+activations
model) -- see EXPERIMENTS.md for the derivation per cell.

MODEL_FLOPS uses 6*N*D (dense) / 6*N_active*D (MoE) for train,
2*N(_active)*D for inference; the ratio MODEL/HLO flags remat/redundancy.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

# The streamed-solve roofline model moved to repro.obs.roofline (so run
# reports can attribute a roofline fraction without importing the benchmarks
# tree); re-exported here for the benches' historical `from roofline import`.
from repro.obs.roofline import (  # noqa: E402,F401
    DISK_BW,
    H2D_BW,
    streamed_solve_flops,
    streamed_solve_roofline,
)

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

# active params for MoE archs (top-k experts + shared + attention + embed)
ACTIVE_PARAMS = {
    "llama4-maverick-400b-a17b": 17.2e9,
    "granite-moe-3b-a800m": 0.94e9,  # 8/40 experts + attn + embed
}


def load_records(dryrun_dir=None):
    d = dryrun_dir or DRYRUN_DIR
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def roofline_row(r: dict) -> dict:
    chips = r["chips"]
    ana = r["hlo_analysis"]
    flops_dev = ana["dot_flops"]  # already per-device (post-SPMD module)
    coll_dev = ana["collective_total_bytes"]
    raw_bytes = r["cost_analysis_raw"].get("bytes_accessed", 0.0)

    n = r["n_params"]
    n_active = ACTIVE_PARAMS.get(r["arch"], n)
    tokens = r["global_batch"] * (r["seq_len"] if r["kind"] == "train" else 1)
    if r["kind"] == "train":
        model_flops = 6.0 * n_active * r["global_batch"] * r["seq_len"]
    elif r["kind"] == "prefill":
        model_flops = 2.0 * n_active * r["global_batch"] * r["seq_len"]
    else:  # decode: one token per sequence
        model_flops = 2.0 * n_active * r["global_batch"]

    # memory term: HLO bytes-accessed is while-body-once; floor it with the
    # structural minimum (params read once + grads/opt write for train)
    param_bytes = n * (2 if "bf16" in str(r.get("arch")) else 4)  # coarse
    mem_bytes = max(raw_bytes, param_bytes / chips)

    t_comp = flops_dev / PEAK_FLOPS
    t_mem = mem_bytes / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))[1]
    useful = model_flops / chips / max(flops_dev, 1.0)
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "mesh": "x".join(str(v) for v in r["mesh"].values()),
        "chips": chips,
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_dev": flops_dev,
        "useful_ratio": useful,
        "roofline_frac": useful * min(1.0, t_comp / max(t_comp, t_mem, t_coll)),
    }


def run(out=print, dryrun_dir=None):
    recs = load_records(dryrun_dir)
    if not recs:
        out("bench_roofline,no_dryrun_records,run launch/dryrun.py first")
        return []
    rows = [roofline_row(r) for r in recs]
    out("bench_roofline,arch,shape,mesh,t_comp_ms,t_mem_ms,t_coll_ms,dominant,useful_ratio")
    for w in rows:
        out(
            f"bench_roofline,{w['arch']},{w['shape']},{w['mesh']},"
            f"{w['t_compute_s']*1e3:.2f},{w['t_memory_s']*1e3:.2f},"
            f"{w['t_collective_s']*1e3:.2f},{w['dominant']},{w['useful_ratio']:.3f}"
        )
    return rows


if __name__ == "__main__":
    run()
