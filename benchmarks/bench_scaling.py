"""Paper Fig. 3a/3b: runtime scaling with problem size and cluster size.

Fig 3a (runtime vs n): measured wall-clock of the full CADDeLaG pipeline on
this CPU for small n, plus the paper's O(d * n^1.5+zeta) model extrapolation
(with zeta calibrated from the measured points) out to the paper's 500k-node
runs -- the measured column validates the slope, the derived column is the
cluster prediction.

Fig 3b (runtime vs workers): CPU containers cannot vary physical workers, so
this is DERIVED from the roofline model: t(W) = compute/(W*peak) + coll(W)/bw
with the collective term growing as the mesh shrinks -- reproducing the
paper's three-phase curve (exponential improvement -> saturation).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import CommuteConfig, detect_anomalies, trivial_context
from repro.graphs import gmm_graph_sequence


def run(sizes=(128, 256, 512), out=print):
    ctx = trivial_context()
    cfg = CommuteConfig(eps_rp=1e-2, d=4, q=6, schedule="xla")
    times = []
    for n in sizes:
        seq = gmm_graph_sequence(ctx, n=n, seed=0)
        t0 = time.perf_counter()
        res = detect_anomalies(ctx, seq.a1, seq.a2, cfg, top_k=10)
        res.scores.block_until_ready()
        dt = time.perf_counter() - t0
        times.append(dt)
        out(f"bench_scaling,n={n},measured_s={dt:.2f}")

    # calibrate t = c * d * n^p on the measured points (paper: p = 1.5+zeta)
    ns = np.log(np.asarray(sizes, np.float64))
    ts = np.log(np.asarray(times, np.float64))
    p, logc = np.polyfit(ns, ts, 1)
    out(f"bench_scaling,fit_exponent,{p:.2f}")
    for n in (100_000, 200_000, 500_000):
        t_pred = float(np.exp(logc) * n**p)
        # derived single-node seconds; a W-worker cluster divides the
        # dominant O(n^3)-ish term by W (paper Fig 3a shows 200 workers)
        out(f"bench_scaling,n={n},derived_single_s={t_pred:.0f},derived_200worker_s={t_pred/200:.0f}")

    # Fig 3b: derived runtime vs workers for n=100k (roofline model)
    n = 100_000
    d_len = 4
    flops = 2.0 * d_len * 2 * n**3  # chain GEMMs
    bytes_coll = 8.0 * n * n * d_len  # one operand pass per level (cannon)
    peak, bw = 197e12 * 0.4, 50e9  # 40% MFU assumption, ICI
    prev = None
    for w in (8, 32, 70, 120, 200, 256, 512):
        t = flops / (w * peak) + bytes_coll / (w * bw) + 0.5  # + fixed overhead
        speedup = "" if prev is None else f",speedup={prev / t:.2f}x"
        out(f"bench_scaling,n=100k,workers={w},derived_s={t:.1f}{speedup}")
        prev = t
    return times


if __name__ == "__main__":
    run()
