"""Sequence-engine amortization: per-transition wall-clock with vs. without
chain-operator reuse.

A T-snapshot sequence scored pairwise with ``detect_anomalies`` builds
2(T-1) chain operators (each O(n^3)-GEMM); the ``SequenceDetector`` builds T
and carries each snapshot's embedding into the next transition, so the total
should trend toward the (2(T-1))/T chain-build ratio (minus the non-chain
work: edge projection, Richardson solve, fused scoring).

Both passes run after an untimed warm-up transition (shared XLA compile
cache), over pre-built snapshots, and are charged end-to-end -- the engine
total includes snapshot 0's embedding, the naive total every rebuild.
"""

from __future__ import annotations

import time

import jax

from repro.core import (
    CommuteConfig,
    SequenceDetector,
    chain_build_count,
    detect_anomalies,
    trivial_context,
)
from repro.graphs import gmm_snapshot_sequence


def run(n=256, t_steps=4, out=print):
    ctx = trivial_context()
    cfg = CommuteConfig(eps_rp=1e-2, d=6, q=8, schedule="xla")
    snaps = list(gmm_snapshot_sequence(ctx, n, t_steps, seed=0, inject_p=0.02).snapshots())

    # untimed warm-up: same functions and shapes as both timed passes, so
    # neither pass pays XLA compilation.
    warm = detect_anomalies(ctx, snaps[0], snaps[1], cfg, top_k=10)
    warm.scores.block_until_ready()

    # -- without reuse: fresh detect_anomalies per transition ---------------
    builds0 = chain_build_count()
    naive_times = []
    for prev, cur in zip(snaps, snaps[1:]):
        t0 = time.perf_counter()
        res = detect_anomalies(ctx, prev, cur, cfg, top_k=10)
        res.scores.block_until_ready()
        naive_times.append(time.perf_counter() - t0)
    naive_builds = chain_build_count() - builds0

    # -- with reuse: the sequence engine ------------------------------------
    builds0 = chain_build_count()
    det = SequenceDetector(ctx, cfg, top_k=10)
    t0 = time.perf_counter()
    seq_res = det.run(iter(snaps))
    jax.block_until_ready(seq_res.transitions[-1].scores)
    seq_total = time.perf_counter() - t0  # includes snapshot 0's embedding
    seq_builds = chain_build_count() - builds0

    naive_total = sum(naive_times)
    out(f"bench_sequence,n={n},t_steps={t_steps},transitions={t_steps - 1}")
    out(f"bench_sequence,naive_chain_builds={naive_builds},engine_chain_builds={seq_builds}")
    for t, (tn, ts) in enumerate(zip(naive_times, seq_res.transition_seconds)):
        out(f"bench_sequence,transition={t},naive_s={tn:.2f},engine_s={ts:.2f}")
    out(
        f"bench_sequence,naive_total_s={naive_total:.2f},engine_total_s={seq_total:.2f},"
        f"speedup={naive_total / max(seq_total, 1e-9):.2f}x"
    )
    return naive_total, seq_total


if __name__ == "__main__":
    run()
