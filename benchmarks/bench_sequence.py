"""Sequence-engine benchmarks: amortization, warm-start acceptance, and the
weekly perf-trajectory artifact.

``run`` -- per-transition wall-clock with vs. without chain-operator reuse.
A T-snapshot sequence scored pairwise with ``detect_anomalies`` builds
2(T-1) chain operators (each O(n^3)-GEMM); the ``SequenceDetector`` builds T
and carries each snapshot's embedding into the next transition, so the total
should trend toward the (2(T-1))/T chain-build ratio (minus the non-chain
work: edge projection, Richardson solve, fused scoring).

Both passes run after an untimed warm-up transition (shared XLA compile
cache), over pre-built snapshots, and are charged end-to-end -- the engine
total includes snapshot 0's embedding, the naive total every rebuild.

``warmstart`` -- the ISSUE 8 acceptance bar: on a slowly-drifting sequence,
warm-started tolerance-targeted solves (richardson, chebyshev, cg) take
>= 2x fewer iterations than cold from transition 2 onward, with scores
allclose (rtol 1e-4, atol 1e-4 of the commute-distance scale).  Asserted,
not just reported.

``trajectory`` -- the canonical ``BENCH_sequence.json`` artifact: the
warmstart grid under a stable schema (per-method cold/warm iteration
trajectories, ratios, score deviation), directly diffable week over week.

  PYTHONPATH=src python benchmarks/bench_sequence.py --warmstart
  PYTHONPATH=src python benchmarks/bench_sequence.py \
      --trajectory BENCH_sequence.json
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace
from pathlib import Path

import jax
import numpy as np

from repro.core import (
    CommuteConfig,
    SequenceDetector,
    chain_build_count,
    detect_anomalies,
    detect_sequence_anomalies,
    trivial_context,
)
from repro.core.embedding import commute_time_embedding
from repro.graphs import gmm_snapshot_sequence


def run(n=256, t_steps=4, out=print):
    ctx = trivial_context()
    cfg = CommuteConfig(eps_rp=1e-2, d=6, q=8, schedule="xla")
    snaps = list(gmm_snapshot_sequence(ctx, n, t_steps, seed=0, inject_p=0.02).snapshots())

    # untimed warm-up: same functions and shapes as both timed passes, so
    # neither pass pays XLA compilation.
    warm = detect_anomalies(ctx, snaps[0], snaps[1], cfg, top_k=10)
    warm.scores.block_until_ready()

    # -- without reuse: fresh detect_anomalies per transition ---------------
    builds0 = chain_build_count()
    naive_times = []
    for prev, cur in zip(snaps, snaps[1:]):
        t0 = time.perf_counter()
        res = detect_anomalies(ctx, prev, cur, cfg, top_k=10)
        res.scores.block_until_ready()
        naive_times.append(time.perf_counter() - t0)
    naive_builds = chain_build_count() - builds0

    # -- with reuse: the sequence engine ------------------------------------
    builds0 = chain_build_count()
    det = SequenceDetector(ctx, cfg, top_k=10)
    t0 = time.perf_counter()
    seq_res = det.run(iter(snaps))
    jax.block_until_ready(seq_res.transitions[-1].scores)
    seq_total = time.perf_counter() - t0  # includes snapshot 0's embedding
    seq_builds = chain_build_count() - builds0

    naive_total = sum(naive_times)
    out(f"bench_sequence,n={n},t_steps={t_steps},transitions={t_steps - 1}")
    out(f"bench_sequence,naive_chain_builds={naive_builds},engine_chain_builds={seq_builds}")
    for t, (tn, ts) in enumerate(zip(naive_times, seq_res.transition_seconds)):
        out(f"bench_sequence,transition={t},naive_s={tn:.2f},engine_s={ts:.2f}")
    out(
        f"bench_sequence,naive_total_s={naive_total:.2f},engine_total_s={seq_total:.2f},"
        f"speedup={naive_total / max(seq_total, 1e-9):.2f}x"
    )
    return naive_total, seq_total


WARM_METHODS = ("richardson", "chebyshev", "cg")


def warmstart(n=96, t_steps=4, tol=1e-5, noise=1e-4, seed=5, out=print):
    """Warm-start acceptance grid: cold vs warm per-transition iterations.

    The sequence drifts slowly (tiny ``noise``, no injections) -- the regime
    warm starting targets: the previous snapshot's solution lands within
    ~|dA| of the new one, so a tolerance-targeted solve finishes in a few
    steps where the cold solve pays the full contraction-rate bill.  The
    score comparison is anchored to the commute-distance scale
    ``V_G * E||z_i||^2`` (the unit scores are measured in): on a quiet
    sequence the scores themselves sit orders of magnitude below it.
    """
    ctx = trivial_context()
    base = CommuteConfig(
        eps_rp=1e-2, d=3, q=8, schedule="xla", k_override=6, solver_tol=tol
    )

    def snaps():
        return gmm_snapshot_sequence(
            ctx, n, t_steps, seed=seed, noise=noise, inject_steps=set()
        ).snapshots()

    emb = commute_time_embedding(ctx, next(snaps()), replace(base, solver="cg"))
    z = np.asarray(emb.z, np.float64)
    scale = float(emb.vol) * float((z * z).sum(1).mean())

    out(f"[bench_sequence] warmstart n={n} t_steps={t_steps} tol={tol:.0e} "
        f"noise={noise:.0e} commute_scale={scale:.3e}")
    out("[bench_sequence]  method     | cold its        warm its        | "
        "ratio(t>=2) | max|dscore|/scale")
    methods, all_pass = {}, True
    for method in WARM_METHODS:
        cold_cfg = replace(base, solver=method)
        warm_cfg = replace(cold_cfg, warm_start=True)
        cold = detect_sequence_anomalies(ctx, snaps(), cold_cfg, top_k=10)
        warm = detect_sequence_anomalies(ctx, snaps(), warm_cfg, top_k=10)
        cold_its = [r.solve_reports[1].iterations for r in cold.transitions]
        warm_its = [r.solve_reports[1].iterations for r in warm.transitions]
        dev = max(
            float(np.max(np.abs(np.asarray(w.scores) - np.asarray(c.scores))))
            for c, w in zip(cold.transitions, warm.transitions)
        ) / scale
        # "from transition 2 onward" (1-based): indices 1..T-2
        ratios = [c / max(w, 1) for c, w in zip(cold_its[1:], warm_its[1:])]
        converged = all(
            r.solve_reports[1].converged
            for res in (cold, warm) for r in res.transitions
        )
        ok = converged and dev <= 1e-4 and all(r >= 2.0 for r in ratios)
        all_pass = all_pass and ok
        methods[method] = {
            "cold_iterations": cold_its, "warm_iterations": warm_its,
            "ratios_from_transition_2": ratios,
            "cold_seconds": cold.transition_seconds,
            "warm_seconds": warm.transition_seconds,
            "score_dev_over_scale": dev, "converged": converged, "pass": ok,
        }
        out(f"[bench_sequence]  {method:10s} | {str(cold_its):15s} "
            f"{str(warm_its):15s} | {min(ratios):9.1f}x | {dev:.2e} "
            f"-> {'PASS' if ok else 'FAIL'}")
        assert converged, f"{method}: a sequence solve did not converge"
        assert dev <= 1e-4, (
            f"{method}: warm scores deviate {dev:.2e} x commute scale"
        )
        assert all(r >= 2.0 for r in ratios), (
            f"{method}: warm start saved < 2x iterations: "
            f"cold={cold_its} warm={warm_its}"
        )
    return {
        "config": {"n": n, "t_steps": t_steps, "tol": tol, "noise": noise,
                   "seed": seed, "d": 3, "k_rp": 6},
        "commute_scale": scale, "methods": methods, "all_pass": all_pass,
    }


def trajectory(out_path, out=print):
    """Canonical perf-trajectory artifact (``BENCH_sequence.json``).

    The warmstart grid under a stable schema: per-method cold/warm iteration
    trajectories, the >= 2x ratios, per-transition seconds and the score
    deviation, so warm-start regressions show up in the weekly artifact
    diff."""
    res = warmstart(out=out)
    result = {"bench": "sequence_trajectory", "schema": 1, **res}
    Path(out_path).write_text(json.dumps(result, indent=2))
    out(f"[bench_sequence] trajectory: all_pass={res['all_pass']}; "
        f"wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--t-steps", type=int, default=4)
    ap.add_argument("--warmstart", action="store_true",
                    help="run the warm-start acceptance grid (asserts the "
                         ">= 2x iteration bar) instead of the amortization "
                         "bench")
    ap.add_argument("--trajectory", default=None, metavar="PATH",
                    help="write the canonical warm-start perf-trajectory "
                         "artifact (BENCH_sequence.json) and exit")
    args = ap.parse_args()
    if args.trajectory:
        trajectory(args.trajectory)
    elif args.warmstart:
        warmstart()
    else:
        run(n=args.n, t_steps=args.t_steps)


if __name__ == "__main__":
    main()
