"""Sequence-engine benchmarks: amortization, warm-start acceptance, and the
weekly perf-trajectory artifact.

``run`` -- per-transition wall-clock with vs. without chain-operator reuse.
A T-snapshot sequence scored pairwise with ``detect_anomalies`` builds
2(T-1) chain operators (each O(n^3)-GEMM); the ``SequenceDetector`` builds T
and carries each snapshot's embedding into the next transition, so the total
should trend toward the (2(T-1))/T chain-build ratio (minus the non-chain
work: edge projection, Richardson solve, fused scoring).

Both passes run after an untimed warm-up transition (shared XLA compile
cache), over pre-built snapshots, and are charged end-to-end -- the engine
total includes snapshot 0's embedding, the naive total every rebuild.

``warmstart`` -- the ISSUE 8 acceptance bar: on a slowly-drifting sequence,
warm-started tolerance-targeted solves (richardson, chebyshev, cg) take
>= 2x fewer iterations than cold from transition 2 onward, with scores
allclose (rtol 1e-4, atol 1e-4 of the commute-distance scale).  Asserted,
not just reported.

``trajectory`` -- the canonical ``BENCH_sequence.json`` artifact: the
warmstart grid under a stable schema (per-method cold/warm iteration
trajectories, ratios, score deviation), directly diffable week over week.

  PYTHONPATH=src python benchmarks/bench_sequence.py --warmstart
  PYTHONPATH=src python benchmarks/bench_sequence.py \
      --trajectory BENCH_sequence.json
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace
from pathlib import Path

import jax
import numpy as np

from repro.core import (
    CommuteConfig,
    SequenceDetector,
    chain_build_count,
    detect_anomalies,
    detect_sequence_anomalies,
    trivial_context,
)
from repro.core.embedding import commute_time_embedding
from repro.graphs import gmm_snapshot_sequence


def run(n=256, t_steps=4, out=print):
    ctx = trivial_context()
    cfg = CommuteConfig(eps_rp=1e-2, d=6, q=8, schedule="xla")
    snaps = list(gmm_snapshot_sequence(ctx, n, t_steps, seed=0, inject_p=0.02).snapshots())

    # untimed warm-up: same functions and shapes as both timed passes, so
    # neither pass pays XLA compilation.
    warm = detect_anomalies(ctx, snaps[0], snaps[1], cfg, top_k=10)
    warm.scores.block_until_ready()

    # -- without reuse: fresh detect_anomalies per transition ---------------
    builds0 = chain_build_count()
    naive_times = []
    for prev, cur in zip(snaps, snaps[1:]):
        t0 = time.perf_counter()
        res = detect_anomalies(ctx, prev, cur, cfg, top_k=10)
        res.scores.block_until_ready()
        naive_times.append(time.perf_counter() - t0)
    naive_builds = chain_build_count() - builds0

    # -- with reuse: the sequence engine ------------------------------------
    builds0 = chain_build_count()
    det = SequenceDetector(ctx, cfg, top_k=10)
    t0 = time.perf_counter()
    seq_res = det.run(iter(snaps))
    jax.block_until_ready(seq_res.transitions[-1].scores)
    seq_total = time.perf_counter() - t0  # includes snapshot 0's embedding
    seq_builds = chain_build_count() - builds0

    naive_total = sum(naive_times)
    out(f"bench_sequence,n={n},t_steps={t_steps},transitions={t_steps - 1}")
    out(f"bench_sequence,naive_chain_builds={naive_builds},engine_chain_builds={seq_builds}")
    for t, (tn, ts) in enumerate(zip(naive_times, seq_res.transition_seconds)):
        out(f"bench_sequence,transition={t},naive_s={tn:.2f},engine_s={ts:.2f}")
    out(
        f"bench_sequence,naive_total_s={naive_total:.2f},engine_total_s={seq_total:.2f},"
        f"speedup={naive_total / max(seq_total, 1e-9):.2f}x"
    )
    return naive_total, seq_total


WARM_METHODS = ("richardson", "chebyshev", "cg")


def warmstart(n=96, t_steps=4, tol=1e-5, noise=1e-4, seed=5, out=print):
    """Warm-start acceptance grid: cold vs warm per-transition iterations.

    The sequence drifts slowly (tiny ``noise``, no injections) -- the regime
    warm starting targets: the previous snapshot's solution lands within
    ~|dA| of the new one, so a tolerance-targeted solve finishes in a few
    steps where the cold solve pays the full contraction-rate bill.  The
    score comparison is anchored to the commute-distance scale
    ``V_G * E||z_i||^2`` (the unit scores are measured in): on a quiet
    sequence the scores themselves sit orders of magnitude below it.
    """
    ctx = trivial_context()
    base = CommuteConfig(
        eps_rp=1e-2, d=3, q=8, schedule="xla", k_override=6, solver_tol=tol
    )

    def snaps():
        return gmm_snapshot_sequence(
            ctx, n, t_steps, seed=seed, noise=noise, inject_steps=set()
        ).snapshots()

    emb = commute_time_embedding(ctx, next(snaps()), replace(base, solver="cg"))
    z = np.asarray(emb.z, np.float64)
    scale = float(emb.vol) * float((z * z).sum(1).mean())

    out(f"[bench_sequence] warmstart n={n} t_steps={t_steps} tol={tol:.0e} "
        f"noise={noise:.0e} commute_scale={scale:.3e}")
    out("[bench_sequence]  method     | cold its        warm its        | "
        "ratio(t>=2) | max|dscore|/scale")
    methods, all_pass = {}, True
    for method in WARM_METHODS:
        cold_cfg = replace(base, solver=method)
        warm_cfg = replace(cold_cfg, warm_start=True)
        cold = detect_sequence_anomalies(ctx, snaps(), cold_cfg, top_k=10)
        warm = detect_sequence_anomalies(ctx, snaps(), warm_cfg, top_k=10)
        cold_its = [r.solve_reports[1].iterations for r in cold.transitions]
        warm_its = [r.solve_reports[1].iterations for r in warm.transitions]
        dev = max(
            float(np.max(np.abs(np.asarray(w.scores) - np.asarray(c.scores))))
            for c, w in zip(cold.transitions, warm.transitions)
        ) / scale
        # "from transition 2 onward" (1-based): indices 1..T-2
        ratios = [c / max(w, 1) for c, w in zip(cold_its[1:], warm_its[1:])]
        converged = all(
            r.solve_reports[1].converged
            for res in (cold, warm) for r in res.transitions
        )
        ok = converged and dev <= 1e-4 and all(r >= 2.0 for r in ratios)
        all_pass = all_pass and ok
        methods[method] = {
            "cold_iterations": cold_its, "warm_iterations": warm_its,
            "ratios_from_transition_2": ratios,
            "cold_seconds": cold.transition_seconds,
            "warm_seconds": warm.transition_seconds,
            # chain-build cost per transition (schema 2): phase seconds and
            # logical GEMM FLOPs from the per-push registry deltas
            "chain_seconds": [
                float(m.get("phase.chain.seconds", 0.0))
                for m in warm.transition_metrics
            ],
            "chain_gemm_flops": [
                float(m.get("chain.gemm_flops", 0.0))
                for m in warm.transition_metrics
            ],
            "score_dev_over_scale": dev, "converged": converged, "pass": ok,
        }
        out(f"[bench_sequence]  {method:10s} | {str(cold_its):15s} "
            f"{str(warm_its):15s} | {min(ratios):9.1f}x | {dev:.2e} "
            f"-> {'PASS' if ok else 'FAIL'}")
        assert converged, f"{method}: a sequence solve did not converge"
        assert dev <= 1e-4, (
            f"{method}: warm scores deviate {dev:.2e} x commute scale"
        )
        assert all(r >= 2.0 for r in ratios), (
            f"{method}: warm start saved < 2x iterations: "
            f"cold={cold_its} warm={warm_its}"
        )
    return {
        "config": {"n": n, "t_steps": t_steps, "tol": tol, "noise": noise,
                   "seed": seed, "d": 3, "k_rp": 6},
        "commute_scale": scale, "methods": methods, "all_pass": all_pass,
    }


def incremental(n=96, t_steps=5, tol=1e-6, seed=5, delta_rank=6,
                delta_budget=0.1, out=print):
    """ISSUE 9 acceptance bar: incremental delta-chain vs full rebuilds.

    A slowly-drifting n=96 sequence (3 nodes move per step, no injections --
    near-low-rank ``dS`` per transition) scored twice with identical solver
    settings: full rebuild every snapshot vs ``incremental_chain=True``.
    Asserted, not just reported:

    * every transition after the first is an incremental update (1 full
      rebuild total, T-1 updates, 0 drift fallbacks);
    * per incremental transition, chain-phase GEMM FLOPs and scratch bytes
      (registry counters ``chain.gemm_flops`` / ``chain.scratch_bytes``, read
      from ``SequenceResult.transition_metrics``) are >= 3x below the full
      rebuild's;
    * scores agree with the full-rebuild path to 1e-3 of the commute-distance
      scale ``V_G * E||z_i||^2`` (the unit scores are measured in; the rank-r
      correction leaves a truncation floor well below it, measured ~1e-4).
    """
    ctx = trivial_context()
    base = CommuteConfig(
        eps_rp=1e-2, d=3, q=8, schedule="xla", k_override=6,
        solver="cg", solver_tol=tol, warm_start=True,
    )
    inc_cfg = replace(base, incremental_chain=True, delta_rank=delta_rank,
                      delta_budget=delta_budget)

    def snaps():
        return gmm_snapshot_sequence(
            ctx, n, t_steps, seed=seed, noise=0.02, inject_steps=set(),
            drift_nodes=3,
        ).snapshots()

    emb = commute_time_embedding(ctx, next(snaps()), base)
    z = np.asarray(emb.z, np.float64)
    scale = float(emb.vol) * float((z * z).sum(1).mean())

    full = detect_sequence_anomalies(ctx, snaps(), base, top_k=10)
    inc = detect_sequence_anomalies(ctx, snaps(), inc_cfg, top_k=10)

    def chain_counter(metrics, name):
        return float(metrics.get(f"chain.{name}", 0.0))

    # Full-rebuild unit costs from the full run's first transition (every
    # transition rebuilds there, so any index works).
    full_m = full.transition_metrics[0]
    full_flops = chain_counter(full_m, "gemm_flops")
    full_scratch = chain_counter(full_m, "scratch_bytes")

    rebuilds = sum(
        chain_counter(m, "full_rebuilds") for m in inc.transition_metrics
    ) + chain_counter(inc.warmup_metrics or {}, "full_rebuilds")
    updates = sum(
        chain_counter(m, "incremental_updates") for m in inc.transition_metrics
    )
    fallbacks = sum(
        chain_counter(m, "drift_fallbacks") for m in inc.transition_metrics
    )

    dev = max(
        float(np.max(np.abs(np.asarray(i.scores, np.float64)
                            - np.asarray(f.scores, np.float64))))
        for f, i in zip(full.transitions, inc.transitions)
    ) / scale

    out(f"[bench_sequence] incremental n={n} t_steps={t_steps} "
        f"rank={delta_rank} budget={delta_budget} commute_scale={scale:.3e}")
    out(f"[bench_sequence]  rebuilds={int(rebuilds)} updates={int(updates)} "
        f"fallbacks={int(fallbacks)}  max|dscore|/scale={dev:.2e}")

    transitions, flops_ratios, scratch_ratios = [], [], []
    for t, m in enumerate(inc.transition_metrics):
        flops = chain_counter(m, "gemm_flops")
        scratch = chain_counter(m, "scratch_bytes")
        is_update = chain_counter(m, "incremental_updates") > 0
        rec = {
            "index": t,
            "incremental": bool(is_update),
            "chain_seconds": float(m.get("phase.chain.seconds", 0.0)),
            "chain_gemm_flops": flops,
            "chain_scratch_bytes": scratch,
            "flops_ratio_vs_full": full_flops / max(flops, 1.0),
            "scratch_ratio_vs_full": full_scratch / max(scratch, 1.0),
        }
        transitions.append(rec)
        if is_update:
            flops_ratios.append(rec["flops_ratio_vs_full"])
            scratch_ratios.append(rec["scratch_ratio_vs_full"])
        out(f"[bench_sequence]  transition {t}: "
            f"{'delta  ' if is_update else 'rebuild'} "
            f"chain {rec['chain_seconds']*1e3:7.1f} ms, "
            f"{flops/1e6:8.2f} MFLOP ({rec['flops_ratio_vs_full']:.2f}x less), "
            f"scratch {scratch/1e3:8.1f} kB "
            f"({rec['scratch_ratio_vs_full']:.2f}x less)")

    ok = (
        int(rebuilds) == 1
        and int(updates) == t_steps - 1
        and int(fallbacks) == 0
        and dev <= 1e-3
        and all(r >= 3.0 for r in flops_ratios)
        and all(r >= 3.0 for r in scratch_ratios)
    )
    out(f"[bench_sequence]  incremental acceptance: "
        f"{'PASS' if ok else 'FAIL'}")
    assert int(rebuilds) == 1 and int(updates) == t_steps - 1, (
        f"expected 1 rebuild + {t_steps - 1} updates, got "
        f"{int(rebuilds)} rebuilds / {int(updates)} updates"
    )
    assert int(fallbacks) == 0, f"unexpected drift fallbacks: {int(fallbacks)}"
    assert dev <= 1e-3, f"incremental scores deviate {dev:.2e} x commute scale"
    assert all(r >= 3.0 for r in flops_ratios), (
        f"chain GEMM FLOPs not >= 3x below full rebuild: {flops_ratios}"
    )
    assert all(r >= 3.0 for r in scratch_ratios), (
        f"chain scratch bytes not >= 3x below full rebuild: {scratch_ratios}"
    )
    return {
        "config": {"n": n, "t_steps": t_steps, "tol": tol, "seed": seed,
                   "delta_rank": delta_rank, "delta_budget": delta_budget,
                   "d": 3, "k_rp": 6},
        "commute_scale": scale,
        "full_rebuild_gemm_flops": full_flops,
        "full_rebuild_scratch_bytes": full_scratch,
        "rebuilds": int(rebuilds), "updates": int(updates),
        "fallbacks": int(fallbacks),
        "score_dev_over_scale": dev,
        "transitions": transitions,
        "pass": ok,
    }


def trajectory(out_path, out=print):
    """Canonical perf-trajectory artifact (``BENCH_sequence.json``).

    Schema 2: the warm-start grid (per-method cold/warm iteration
    trajectories, >= 2x ratios, per-transition seconds and score deviation,
    now with per-transition chain-build seconds / logical GEMM FLOPs columns
    from the metrics registry) plus the incremental delta-chain acceptance
    section, so both warm-start and incremental-chain regressions show up in
    the weekly artifact diff."""
    res = warmstart(out=out)
    inc_res = incremental(out=out)
    result = {
        "bench": "sequence_trajectory", "schema": 2, **res,
        "incremental": inc_res,
    }
    Path(out_path).write_text(json.dumps(result, indent=2))
    out(f"[bench_sequence] trajectory: all_pass="
        f"{res['all_pass'] and inc_res['pass']}; wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--t-steps", type=int, default=4)
    ap.add_argument("--warmstart", action="store_true",
                    help="run the warm-start acceptance grid (asserts the "
                         ">= 2x iteration bar) instead of the amortization "
                         "bench")
    ap.add_argument("--incremental", action="store_true",
                    help="run the incremental delta-chain acceptance bench "
                         "(asserts >= 3x chain FLOPs/scratch reduction and "
                         "1e-3-of-scale score agreement) instead of the "
                         "amortization bench")
    ap.add_argument("--trajectory", default=None, metavar="PATH",
                    help="write the canonical perf-trajectory artifact "
                         "(BENCH_sequence.json; warm-start grid + incremental "
                         "delta-chain section) and exit")
    args = ap.parse_args()
    if args.trajectory:
        trajectory(args.trajectory)
    elif args.warmstart:
        warmstart()
    elif args.incremental:
        incremental()
    else:
        run(n=args.n, t_steps=args.t_steps)


if __name__ == "__main__":
    main()
