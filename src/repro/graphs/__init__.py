from repro.graphs.synthetic import (
    climate_like_sequence,
    gmm_graph_sequence,
    gmm_points,
    similarity_graph,
)

__all__ = [
    "climate_like_sequence",
    "gmm_graph_sequence",
    "gmm_points",
    "similarity_graph",
]
