from repro.graphs.synthetic import (
    SnapshotSequence,
    climate_like_sequence,
    climate_snapshot_sequence,
    gaussian_kernel_graph,
    gmm_graph_sequence,
    gmm_points,
    gmm_snapshot_sequence,
    gmm_store_sequence,
    similarity_graph,
    store_snapshot_sequence,
)

__all__ = [
    "SnapshotSequence",
    "climate_like_sequence",
    "climate_snapshot_sequence",
    "gaussian_kernel_graph",
    "gmm_graph_sequence",
    "gmm_points",
    "gmm_snapshot_sequence",
    "gmm_store_sequence",
    "similarity_graph",
    "store_snapshot_sequence",
]
