from repro.graphs.synthetic import (
    SnapshotSequence,
    climate_like_sequence,
    climate_snapshot_sequence,
    gaussian_kernel_graph,
    gmm_graph_sequence,
    gmm_points,
    gmm_snapshot_sequence,
    similarity_graph,
)

__all__ = [
    "SnapshotSequence",
    "climate_like_sequence",
    "climate_snapshot_sequence",
    "gaussian_kernel_graph",
    "gmm_graph_sequence",
    "gmm_points",
    "gmm_snapshot_sequence",
    "similarity_graph",
]
