"""Synthetic graph-sequence generators (paper section 4.2.1).

The paper's quantitative benchmark: draw points from a 4-component 2-D
Gaussian mixture, build the fully-connected similarity graph
P(i, j) = exp(-d(i, j)), perturb into Q, and inject anomalies R --
5%-probability uniform edges; *inter-cluster* injected edges (and their
endpoints) are the ground-truth anomalies.  A_1 = P, A_2 = Q + (R + R^T)/2.

Also a climate-like generator: smooth random fields on a lat/lon grid with a
localized "event" perturbation, graph = exp(-||p_i - p_j||^2 / 2 sigma^2)
(paper section 4.2.1 Climate Data), so the climate example runs end-to-end
without shipping NCEP data.

Graphs are built *sharded* via ``build_from_nodes`` -- node features are the
only centralized object, the n x n matrix is born distributed.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distmatrix import DistContext, build_from_nodes


def gmm_points(n: int, seed: int = 0, spread: float = 4.0) -> tuple[np.ndarray, np.ndarray]:
    """n points from a 4-component 2-D GMM; returns (points, component_ids)."""
    rng = np.random.default_rng(seed)
    means = spread * np.array([[1, 1], [1, -1], [-1, 1], [-1, -1]], np.float64)
    comp = rng.integers(0, 4, size=n)
    pts = means[comp] + rng.normal(size=(n, 2))
    return pts.astype(np.float32), comp


def similarity_graph(
    ctx: DistContext, feats: jax.Array, *, bandwidth: float = 1.0, dtype=jnp.float32
) -> jax.Array:
    """A[i, j] = exp(-||x_i - x_j|| / bandwidth), zero diagonal, sharded."""

    def kern(xi, xj):
        d2 = jnp.sum((xi[:, None, :] - xj[None, :, :]) ** 2, -1)
        return jnp.exp(-jnp.sqrt(jnp.maximum(d2, 1e-12)) / bandwidth)

    return build_from_nodes(ctx, jnp.asarray(feats), kern, dtype=dtype)


def gaussian_kernel_graph(
    ctx: DistContext, feats: jax.Array, *, sigma: float, dtype=jnp.float32
) -> jax.Array:
    """A[i, j] = exp(-||p_i - p_j||^2 / (2 sigma^2)) -- the climate kernel."""

    def kern(xi, xj):
        d2 = jnp.sum((xi[:, None, :] - xj[None, :, :]) ** 2, -1)
        return jnp.exp(-d2 / (2.0 * sigma**2))

    return build_from_nodes(ctx, jnp.asarray(feats), kern, dtype=dtype)


@dataclass
class GMMSequence:
    a1: jax.Array
    a2: jax.Array
    anomalous_nodes: np.ndarray  # ground truth
    components: np.ndarray


def gmm_graph_sequence(
    ctx: DistContext,
    n: int,
    *,
    seed: int = 0,
    noise: float = 0.05,
    inject_p: float = 0.05,
    dtype=jnp.float32,
) -> GMMSequence:
    """Paper synthetic: A1 = P, A2 = Q + (R + R^T)/2, ground-truth = nodes of
    injected *inter-cluster* edges."""
    rng = np.random.default_rng(seed)
    pts, comp = gmm_points(n, seed)
    a1 = similarity_graph(ctx, pts, dtype=dtype)

    pts2 = pts + noise * rng.normal(size=pts.shape).astype(np.float32)
    q = similarity_graph(ctx, pts2, dtype=dtype)

    # R: sparse random uniform injections (centralized here is fine for the
    # sizes tests use; the sharded path would draw R counter-based like Q).
    mask = rng.random((n, n)) < inject_p
    r = np.where(mask, rng.random((n, n)), 0.0).astype(np.float32)
    r_sym = (r + r.T) / 2.0
    np.fill_diagonal(r_sym, 0.0)
    a2 = jnp.add(q, ctx.put_matrix(r_sym.astype(np.float32))).astype(dtype)

    inter = (comp[:, None] != comp[None, :]) & (r_sym > 0)
    truth = np.unique(np.nonzero(inter.any(axis=1))[0])
    # Rank ground-truth nodes by total injected inter-cluster weight so tests
    # can compare against the strongest true anomalies.
    strength = (r_sym * inter).sum(1)
    truth = truth[np.argsort(-strength[truth])]
    return GMMSequence(a1=a1, a2=a2, anomalous_nodes=truth, components=comp)


def climate_like_sequence(
    ctx: DistContext,
    n_lat: int,
    n_lon: int,
    *,
    seed: int = 0,
    sigma: float = 1.0,
    event_frac: float = 0.02,
    event_strength: float = 6.0,
    dtype=jnp.float32,
):
    """Two smooth precipitation-like fields; field 2 has a localized event.

    Returns (a1, a2, event_nodes).  Node features are per-location monthly
    profiles (12-dim), smoothed over the grid -- a stand-in for NCEP monthly
    means at 0.5 degree resolution.
    """
    rng = np.random.default_rng(seed)
    n = n_lat * n_lon

    def smooth_field(x: np.ndarray, passes: int = 8) -> np.ndarray:
        f = x.reshape(n_lat, n_lon, -1)
        for _ in range(passes):
            f = 0.5 * f + 0.125 * (
                np.roll(f, 1, 0) + np.roll(f, -1, 0) + np.roll(f, 1, 1) + np.roll(f, -1, 1)
            )
        return f.reshape(n, -1)

    base = smooth_field(rng.normal(size=(n, 12)).astype(np.float32))
    drift = smooth_field(0.1 * rng.normal(size=(n, 12)).astype(np.float32))

    n_event = max(1, int(event_frac * n))
    centre = rng.integers(0, n)
    ci, cj = divmod(int(centre), n_lon)
    ii, jj = np.meshgrid(np.arange(n_lat), np.arange(n_lon), indexing="ij")
    dist = ((ii - ci) ** 2 + (jj - cj) ** 2).reshape(-1)
    event_nodes = np.argsort(dist)[:n_event]
    bump = np.zeros((n, 12), np.float32)
    bump[event_nodes] = event_strength
    field2 = base + drift + smooth_field(bump, passes=2)

    a1 = gaussian_kernel_graph(ctx, base, sigma=sigma, dtype=dtype)
    a2 = gaussian_kernel_graph(ctx, field2, sigma=sigma, dtype=dtype)
    return a1, a2, event_nodes


# ---------------------------------------------------------------------------
# T-length snapshot sequences (the SequenceDetector's input)
# ---------------------------------------------------------------------------


@dataclass
class SnapshotSequence:
    """A lazily-built sequence of T sharded snapshots plus per-transition truth.

    Snapshots are built one at a time inside :meth:`snapshots` -- the whole
    sequence is never resident, matching the engine's two-snapshot budget.
    ``truth[t]`` holds the ground-truth anomalous nodes for transition
    (t, t+1), ranked strongest-first (may be empty for quiet transitions).
    """

    t_steps: int
    truth: list[np.ndarray]
    components: np.ndarray | None = None
    # Per-node ground-truth labels (1 = planted outlier), set by the labeled
    # anomaly mode of gmm_snapshot_sequence -- the ROC-AUC harness's target.
    labels: np.ndarray | None = None
    _build: Callable[[int], jax.Array] = field(default=None, repr=False)

    def snapshots(self) -> Iterator[jax.Array]:
        for t in range(self.t_steps):
            yield self._build(t)


def _gmm_injection(n: int, seed: int, t: int, inject_p: float) -> np.ndarray:
    """Deterministic per-step injected-edge matrix R_t + R_t^T (numpy)."""
    rng = np.random.default_rng((seed + 1) * 1_000_003 + t)
    mask = rng.random((n, n)) < inject_p
    r = np.where(mask, rng.random((n, n)), 0.0).astype(np.float32)
    r_sym = (r + r.T) / 2.0
    np.fill_diagonal(r_sym, 0.0)
    return r_sym


def _dimmed_similarity_kern(bandwidth: float):
    """Similarity kernel over (x, y, scale) features: exp(-d/bw) * s_i * s_j.

    The scale column dims a node's whole row AND column of the similarity
    matrix -- a low-degree node at a perfectly normal position.  Folding the
    dimming into the kernel keeps the build sharded (build_from_nodes never
    materializes n x n on the host).
    """

    def kern(xi, xj):
        d2 = jnp.sum((xi[:, None, :2] - xj[None, :, :2]) ** 2, -1)
        sim = jnp.exp(-jnp.sqrt(jnp.maximum(d2, 1e-12)) / bandwidth)
        return sim * xi[:, None, 2] * xj[None, :, 2]

    return kern


def gmm_snapshot_sequence(
    ctx: DistContext,
    n: int,
    t_steps: int,
    *,
    seed: int = 0,
    noise: float = 0.05,
    inject_p: float = 0.05,
    inject_steps: set[int] | None = None,
    drift_nodes: int | None = None,
    anomaly_nodes: int | np.ndarray | None = None,
    anomaly_scale: float = 12.0,
    dim_nodes: int = 0,
    dim_factor: float = 0.05,
    dtype=jnp.float32,
) -> SnapshotSequence:
    """T-snapshot GMM sequence: drifting points + per-step edge injections.

    Snapshot 0 is the clean similarity graph; each later snapshot drifts
    points by ``noise`` and, at steps in ``inject_steps`` (default: every
    t >= 1), adds a fresh uniform-edge injection R_t.  Ground truth for
    transition (t, t+1) is the inter-cluster injected nodes of the two
    endpoint injections (both the appearance at t+1 and the disappearance of
    step t's edges are anomalous), ranked by combined injected weight.

    ``drift_nodes`` localizes the drift: only that many nodes (a fresh
    deterministic subset per step) move each transition, the rest stay put.
    The adjacency then changes only in the movers' rows and columns, so
    ``dS`` is near-low-rank (~2 x movers + normalization) -- the
    slowly-drifting regime the incremental delta-chain path
    (:mod:`repro.core.delta_chain`) is built for.  ``None`` (default) keeps
    the historical global drift.

    ``anomaly_nodes`` (a count, or explicit node ids) switches on the
    *labeled* mode the query-path ROC-AUC harness consumes: the chosen nodes
    are moved into one tight clump at radius ``anomaly_scale`` (a satellite
    cluster, tethered to the main mass only through a commute bottleneck --
    persistent across drift), and the returned sequence carries ``labels``,
    an (n,) 0/1 ground-truth vector.  ``dim_nodes`` additionally dims that
    many *normal* nodes' similarity rows/columns by ``dim_factor``
    (labeled 0): low-degree distractors at perfectly normal positions.  That
    is the von Luxburg degenerate-regime fixture -- raw commute distance
    ranks the distractors spuriously high through their 1/deg term, while the
    corrected scorer subtracts it and keeps only the structural outliers.
    """
    if t_steps < 2:
        raise ValueError("a sequence needs at least 2 snapshots")
    inject_steps = set(range(1, t_steps)) if inject_steps is None else set(inject_steps)
    rng = np.random.default_rng(seed)
    pts0, comp = gmm_points(n, seed)

    labels = None
    scale = None
    if anomaly_nodes is not None:
        if np.ndim(anomaly_nodes) == 0:
            outliers = rng.choice(n, size=min(int(anomaly_nodes), n), replace=False)
        else:
            outliers = np.asarray(anomaly_nodes, np.int64).reshape(-1)
        labels = np.zeros(n, np.int8)
        labels[outliers] = 1
        # Plant the outliers as one tight clump at a common far-out location
        # (radius ``anomaly_scale``; the GMM means sit at radius ~5.7): a
        # satellite cluster tethered to the main mass only through a
        # commute-time bottleneck.  Internal clump edges keep their degrees
        # near normal, so the anomaly is *structural*, not a degree artifact
        # -- exactly the signal the von Luxburg correction preserves while it
        # subtracts out the dimmed distractors below.  (Independently
        # scattered outliers would be pure low-degree anomalies, and the
        # correction would erase their signal along with the distractors'.)
        theta = float(rng.uniform(0, 2 * np.pi))
        centre = anomaly_scale * np.array([np.cos(theta), np.sin(theta)], np.float32)
        pts0 = pts0.copy()
        pts0[outliers] = centre + 0.3 * rng.normal(
            size=(outliers.size, 2)
        ).astype(np.float32)
        scale = np.ones(n, np.float32)
        if dim_nodes:
            normal = np.setdiff1d(np.arange(n), outliers)
            dimmed = rng.choice(normal, size=min(int(dim_nodes), normal.size), replace=False)
            scale[dimmed] = float(dim_factor)

    pts_all = [pts0]
    for _ in range(1, t_steps):
        step = noise * rng.normal(size=pts0.shape).astype(np.float32)
        if drift_nodes is not None:
            movers = rng.choice(n, size=min(int(drift_nodes), n), replace=False)
            mask = np.zeros((n, 1), np.float32)
            mask[movers] = 1.0
            step = step * mask
        pts_all.append(pts_all[-1] + step)

    # Per-step injected inter-cluster weight per node (n,) -- small, so truth
    # is precomputed; the n x n injections themselves are regenerated lazily.
    inter = comp[:, None] != comp[None, :]
    strength: dict[int, np.ndarray] = {}
    for t in sorted(inject_steps):
        r_sym = _gmm_injection(n, seed, t, inject_p)
        strength[t] = (r_sym * inter).sum(1)

    truth = []
    for t in range(t_steps - 1):
        s = np.zeros(n, np.float32)
        for endpoint in (t, t + 1):
            if endpoint in strength:
                s = s + strength[endpoint]
        nodes = np.nonzero(s > 0)[0]
        truth.append(nodes[np.argsort(-s[nodes])])

    def build(t: int) -> jax.Array:
        if scale is not None:
            feats = np.concatenate([pts_all[t], scale[:, None]], axis=1)
            a = build_from_nodes(
                ctx, jnp.asarray(feats), _dimmed_similarity_kern(1.0), dtype=dtype
            )
        else:
            a = similarity_graph(ctx, pts_all[t], dtype=dtype)
        if t in inject_steps:
            r_sym = _gmm_injection(n, seed, t, inject_p)
            a = jnp.add(a, ctx.put_matrix(r_sym)).astype(dtype)
        return a

    return SnapshotSequence(
        t_steps=t_steps, truth=truth, components=comp, labels=labels, _build=build
    )


def climate_snapshot_sequence(
    ctx: DistContext,
    n_lat: int,
    n_lon: int,
    t_steps: int,
    *,
    seed: int = 0,
    sigma: float = 1.0,
    drift: float = 0.1,
    event_steps: set[int] | None = None,
    event_frac: float = 0.02,
    event_strength: float = 6.0,
    dtype=jnp.float32,
):
    """T-month climate-like sequence; a localized event at ``event_steps``.

    Fields drift smoothly month to month; at steps in ``event_steps``
    (default: the middle snapshot only) a localized precipitation event is
    superimposed.  Ground truth for transition (t, t+1) is the event region
    when the event appears or disappears at that transition, else empty.
    Returns a :class:`SnapshotSequence`.
    """
    if t_steps < 2:
        raise ValueError("a sequence needs at least 2 snapshots")
    event_steps = {t_steps // 2} if event_steps is None else set(event_steps)
    rng = np.random.default_rng(seed)
    n = n_lat * n_lon

    def smooth_field(x: np.ndarray, passes: int = 8) -> np.ndarray:
        f = x.reshape(n_lat, n_lon, -1)
        for _ in range(passes):
            f = 0.5 * f + 0.125 * (
                np.roll(f, 1, 0) + np.roll(f, -1, 0) + np.roll(f, 1, 1) + np.roll(f, -1, 1)
            )
        return f.reshape(n, -1)

    base = smooth_field(rng.normal(size=(n, 12)).astype(np.float32))
    fields = [base]
    for _ in range(1, t_steps):
        step = smooth_field(drift * rng.normal(size=(n, 12)).astype(np.float32))
        fields.append(fields[-1] + step)

    n_event = max(1, int(event_frac * n))
    centre = rng.integers(0, n)
    ci, cj = divmod(int(centre), n_lon)
    ii, jj = np.meshgrid(np.arange(n_lat), np.arange(n_lon), indexing="ij")
    dist = ((ii - ci) ** 2 + (jj - cj) ** 2).reshape(-1)
    event_nodes = np.argsort(dist)[:n_event]
    bump = np.zeros((n, 12), np.float32)
    bump[event_nodes] = event_strength
    bump = smooth_field(bump, passes=2)

    truth = []
    for t in range(t_steps - 1):
        toggled = (t in event_steps) != ((t + 1) in event_steps)
        truth.append(event_nodes.copy() if toggled else np.empty(0, np.int64))

    def build(t: int) -> jax.Array:
        f = fields[t] + (bump if t in event_steps else 0.0)
        return gaussian_kernel_graph(ctx, f, sigma=sigma, dtype=dtype)

    return SnapshotSequence(t_steps=t_steps, truth=truth, components=None, _build=build)


# ---------------------------------------------------------------------------
# snapshot writers (out-of-core store integration)
# ---------------------------------------------------------------------------


def store_snapshot_sequence(store, seq: SnapshotSequence, *, ids: list[str] | None = None) -> list[str]:
    """Write a :class:`SnapshotSequence` into a :class:`repro.store.TileStore`.

    Snapshots are built (sharded) one at a time, gathered, tiled to the store
    and dropped -- at most one snapshot is resident during the write, matching
    the sequence engine's residency discipline.  Already-committed ids are
    skipped, so an interrupted write resumes where it stopped.
    """
    ids = ids if ids is not None else [f"t{t:04d}" for t in range(seq.t_steps)]
    if len(ids) != seq.t_steps:
        raise ValueError(f"{len(ids)} ids for {seq.t_steps} snapshots")
    committed = set(store.snapshot_ids)
    for sid, a in zip(ids, seq.snapshots()):
        if sid not in committed:
            store.put_snapshot(sid, np.asarray(a))
    return ids


def gmm_store_sequence(
    store,
    t_steps: int,
    *,
    seed: int = 0,
    noise: float = 0.05,
    bandwidth: float = 1.0,
) -> list[str]:
    """Write a drifting-GMM similarity sequence *tile-by-tile* (pure numpy).

    The fully out-of-core writer: only the (n, 2) point table is ever
    resident, each ``exp(-d(i, j))`` tile is computed from the points and
    written independently -- so store sequences far larger than host RAM can
    be laid down (the benchmark's path to "n bounded by disk").  Same kernel
    as :func:`similarity_graph`, no injections (no ground truth).
    """
    if t_steps < 1:
        raise ValueError("need at least 1 snapshot")
    n = store.n
    pts, _ = gmm_points(n, seed)
    rng = np.random.default_rng(seed)
    ids = []
    for t in range(t_steps):
        sid = f"t{t:04d}"

        def tile_fn(rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
            xi, xj = pts[rows], pts[cols]
            d2 = ((xi[:, None, :] - xj[None, :, :]) ** 2).sum(-1)
            blk = np.exp(-np.sqrt(np.maximum(d2, 1e-12)) / bandwidth).astype(np.float32)
            blk[rows[:, None] == cols[None, :]] = 0.0
            return blk

        if sid not in store.snapshot_ids:
            store.put_snapshot_tiles(sid, tile_fn)
        ids.append(sid)
        pts = pts + noise * rng.normal(size=pts.shape).astype(np.float32)
    return ids
