"""Batched serving driver: prefill a prompt batch, decode N tokens.

Usage (CPU container -- tiny smoke config):
  python -m repro.launch.serve --arch qwen2-1.5b --smoke --batch 4 \
      --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.launch.mesh import make_cpu_mesh
from repro.models import lm
from repro.serving import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    mesh = make_cpu_mesh(data=args.data, model=args.model)
    spec = lm.build_spec(cfg)
    params = lm.init_params(spec, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)
    frames = None
    if cfg.input_mode == "frames":
        frames = rng.normal(size=(args.batch, args.prompt_len, cfg.d_model)).astype(np.float32)

    eng = ServeEngine(
        spec, mesh, params,
        s_max=args.prompt_len + args.max_new,
        batch=args.batch,
        cfg=ServeConfig(max_new_tokens=args.max_new, temperature=args.temperature),
    )
    t0 = time.perf_counter()
    out = eng.generate(prompts, frames=frames)
    dt = time.perf_counter() - t0
    tput = args.batch * args.max_new / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s ({tput:.1f} tok/s)")
    print("[serve] first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
