import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY for this dry-run process so
# jax.make_mesh can build the production meshes; smoke tests and benches
# (separate processes) see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the right step function (train_step / prefill /
serve_step) against ShapeDtypeStruct inputs (zero allocation), compiles it
for the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh, and records:

  - compiled.memory_analysis()   (bytes per device -- proves it fits)
  - compiled.cost_analysis()     (HLO FLOPs / bytes -> roofline terms)
  - collective bytes by op type  (parsed from the post-SPMD HLO text)

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline report (benchmarks/roofline.py) and EXPERIMENTS.md read from them.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.models import common as cm
from repro.models import lm
from repro.serving.engine import make_serve_step
from repro.training.optim import OptConfig
from repro.training.train_step import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


# ---------------------------------------------------------------------------
# lowering per cell
# ---------------------------------------------------------------------------


def _named(mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def _sds_with(shapes, shardings):
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s), shapes, shardings
    )


def long_context_rules(mesh: Mesh) -> dict:
    """long_500k (batch=1): batch unshardable; spread the KV sequence over
    every mesh axis instead and keep heads/inner replicated."""
    r = dict(cm.DEFAULT_RULES)
    r["batch"] = None
    r["kv_seq"] = tuple(mesh.axis_names)  # ("pod","data","model") or ("data","model")
    r["heads"] = "model"
    r["inner"] = "model"
    return r


def fsdp_rules(mesh: Mesh) -> dict:
    """Beyond-baseline preset: pure FSDP/ZeRO-3 -- batch over BOTH mesh axes,
    no tensor parallelism.  Kills the per-layer TP activation all-reduces
    (the dominant collective term of the baseline) in exchange for per-layer
    parameter all-gathers that XLA overlaps with the layer scan.  Multi-pod:
    params replicate across pods (one cross-pod grad all-reduce per step)."""
    r = dict(cm.DEFAULT_RULES)
    r["batch"] = ("data", "model")
    r["batch_inner"] = ("data", "model")
    r["heads"] = None
    r["ff"] = None
    r["inner"] = None
    r["vocab"] = None  # logits stay unsharded per loss chunk (small)
    # ZeRO-3: every weight's d_model dim shards over the WHOLE mesh --
    # params/grads/opt states are 256-way; grad sync lowers to
    # reduce-scatter instead of a 16-way all-reduce.
    r["embed_p"] = ("data", "model")
    r["embed_d"] = ("data", "model")
    r["kv_seq"] = "model"
    return r


def seqshard_rules(mesh: Mesh) -> dict:
    """Beyond-baseline preset for prefill: shard the SEQUENCE over "model"
    instead of tensor-parallelism.  The chunked-flash scan streams KV chunks
    (each step all-gathers one chunk -- ring-attention-style), so the
    per-layer TP activation all-reduces disappear; params stay ZeRO-sharded
    over the whole mesh (they carry no seq axis)."""
    r = dict(cm.DEFAULT_RULES)
    r["batch"] = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    r["batch_inner"] = r["batch"]
    r["seq"] = "model"
    r["heads"] = None
    r["ff"] = None
    r["inner"] = None
    r["vocab"] = None
    r["embed_p"] = ("data", "model")
    r["embed_d"] = ("data", "model")
    r["kv_seq"] = "model"
    return r


RULE_PRESETS = {"baseline": None, "fsdp": fsdp_rules, "seqshard": seqshard_rules}


def rules_for(mesh: Mesh, shape: configs.ShapeSpec, preset: str = "baseline") -> dict:
    if shape.name.startswith("long"):
        return long_context_rules(mesh)
    if preset != "baseline" and shape.kind in ("train", "prefill"):
        return RULE_PRESETS[preset](mesh)
    return cm.multipod_rules() if "pod" in mesh.axis_names else dict(cm.DEFAULT_RULES)


def lower_cell(arch_id: str, shape: configs.ShapeSpec, mesh: Mesh, *, accum: int = 1,
               preset: str = "baseline", vocab_chunk: int | None = None):
    """Lower + compile one cell; returns the record dict."""
    cfg = configs.get_config(arch_id)
    if vocab_chunk:
        cfg = cfg.replace(vocab_chunk=vocab_chunk)
    spec = lm.build_spec(cfg)
    rules = rules_for(mesh, shape, preset)
    t0 = time.time()

    if shape.kind == "train":
        opt_cfg = OptConfig(name=cfg.optimizer)
        step_fn, pspecs, ospecs, batch_spec = make_train_step(
            spec, mesh, opt_cfg, rules=rules, accum=accum
        )
        pshape = jax.eval_shape(partial(lm.init_params, spec), jax.random.PRNGKey(0))
        from repro.training.optim import make_optimizer

        opt_init, _ = make_optimizer(opt_cfg)
        oshape = jax.eval_shape(opt_init, pshape)
        batch_shapes = configs.input_specs(cfg, shape)
        bspecs = {
            k: cm.sanitize_spec(
                cm.logical_to_spec(("batch", "seq", "embed")[: v.ndim], rules), v.shape, mesh
            )
            for k, v in batch_shapes.items()
        }
        args = (
            _sds_with(pshape, _named(mesh, pspecs)),
            _sds_with(oshape, _named(mesh, ospecs)),
            _sds_with(batch_shapes, _named(mesh, bspecs)),
        )
        lowered = step_fn.lower(*args)

    elif shape.kind == "prefill":
        from repro.serving.engine import make_prefill

        pf, pspecs = make_prefill(spec, mesh, s_max=shape.seq_len, rules=rules)
        pshape = jax.eval_shape(partial(lm.init_params, spec), jax.random.PRNGKey(0))
        batch_shapes = configs.input_specs(cfg, shape)
        bspecs = {
            k: cm.sanitize_spec(
                cm.logical_to_spec(("batch", "seq", "embed")[: v.ndim], rules), v.shape, mesh
            )
            for k, v in batch_shapes.items()
        }
        lowered = pf.lower(
            _sds_with(pshape, _named(mesh, pspecs)),
            _sds_with(batch_shapes, _named(mesh, bspecs)),
        )

    elif shape.kind == "decode":
        enc_len = shape.seq_len if spec.is_encdec else 0
        step_fn, cache_shapes, cache_shardings, pspecs = make_serve_step(
            spec, mesh, batch=shape.global_batch, s_max=shape.seq_len,
            enc_len=enc_len, rules=rules, donate_cache=True,
        )
        pshape = jax.eval_shape(partial(lm.init_params, spec), jax.random.PRNGKey(0))
        tok_spec = cm.sanitize_spec(
            cm.logical_to_spec(("batch",), rules), (shape.global_batch,), mesh
        )
        tok = jax.ShapeDtypeStruct(
            (shape.global_batch,), jnp.int32, sharding=NamedSharding(mesh, tok_spec)
        )
        lowered = step_fn.lower(
            _sds_with(pshape, _named(mesh, pspecs)), tok, _sds_with(cache_shapes, cache_shardings)
        )
    else:
        raise ValueError(shape.kind)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not support it
        mem_d = {"error": str(e)}

    try:
        cost_list = compiled.cost_analysis()
        cost = cost_list if isinstance(cost_list, dict) else (cost_list[0] if cost_list else {})
        cost_d = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
        }
    except Exception as e:
        cost_d = {"error": str(e)}

    hlo = compiled.as_text()
    t0 = time.time()
    ana = hlo_analysis.analyze(hlo)  # trip-count-corrected flops + collectives
    t_ana = time.time() - t0

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(pshape))
    record = {
        "arch": arch_id,
        "shape": shape.name,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "mesh": {k: int(v) for k, v in mesh.shape.items()},
        "chips": mesh_chip_count(mesh),
        "preset": preset,
        "accum": accum,
        "n_params": n_params,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "analyze_s": round(t_ana, 2),
        "memory_analysis": mem_d,
        "cost_analysis_raw": cost_d,  # XLA counts while bodies ONCE (see hlo_analysis)
        "hlo_analysis": ana,  # trip-count-corrected, per-device
        "hlo_bytes": len(hlo),
    }
    return record


def run_cells(cells, meshes, out_dir: str, accum: int = 1, preset: str = "baseline") -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    records = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        for arch_id, shape in cells:
            tag = f"{arch_id}__{shape.name}__{mesh_name}"
            path = os.path.join(out_dir, tag + ".json")
            print(f"=== {tag} ===", flush=True)
            try:
                rec = lower_cell(arch_id, shape, mesh, accum=accum, preset=preset)
                rec["status"] = "ok"
                print(
                    f"  ok: compile={rec['compile_s']}s "
                    f"dot_flops={rec['hlo_analysis']['dot_flops']:.3e} "
                    f"coll={rec['hlo_analysis']['collective_total_bytes']:.3e}B",
                    flush=True,
                )
            except Exception as e:
                rec = {
                    "arch": arch_id, "shape": shape.name, "mesh": mesh_name,
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:],
                }
                print(f"  ERROR: {type(e).__name__}: {str(e)[:200]}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            records.append(rec)
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all supported)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--rules", default="baseline", choices=list(RULE_PRESETS),
                    help="sharding preset for train/prefill cells (see EXPERIMENTS.md Perf)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out_dir = args.out or os.path.normpath(OUT_DIR)
    if args.all or args.arch is None:
        cells = configs.all_cells()
    else:
        cfg = configs.get_config(args.arch)
        shapes = (
            [configs.SHAPES_BY_NAME[args.shape]]
            if args.shape
            else list(configs.supported_shapes(cfg))
        )
        cells = [(args.arch, s) for s in shapes]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    records = run_cells(cells, meshes, out_dir, accum=args.accum, preset=args.rules)
    n_ok = sum(r["status"] == "ok" for r in records)
    print(f"\n{n_ok}/{len(records)} cells compiled OK")
    if n_ok < len(records):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
