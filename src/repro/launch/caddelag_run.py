"""CADDeLaG driver: the paper's anomaly-detection pipeline on a mesh.

Runs Algorithm 4 end-to-end on a synthetic GMM graph sequence (paper section
4.2.1) or a climate-like sequence, with the matmul schedule, chain length d,
Richardson iterations q and eps_RP all selectable -- the knobs of the paper's
accuracy study (Fig. 2) and scaling study (Fig. 3).

  python -m repro.launch.caddelag_run --n 256 --schedule cannon --d 6 --q 10
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import CommuteConfig, detect_anomalies, make_context
from repro.graphs import climate_like_sequence, gmm_graph_sequence
from repro.launch.mesh import make_cpu_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256, help="graph nodes")
    ap.add_argument("--dataset", default="gmm", choices=["gmm", "climate"])
    ap.add_argument("--schedule", default="cannon", choices=["xla", "summa", "cannon"])
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--d", type=int, default=6)
    ap.add_argument("--q", type=int, default=10)
    ap.add_argument("--top-k", type=int, default=20)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--use-kernel", action="store_true", help="Pallas block GEMM")
    args = ap.parse_args()

    mesh = make_cpu_mesh(data=args.data, model=args.model)
    ctx = make_context(mesh)
    cfg = CommuteConfig(eps_rp=args.eps, d=args.d, q=args.q, schedule=args.schedule)

    if args.dataset == "gmm":
        seq = gmm_graph_sequence(ctx, n=args.n, seed=0, inject_p=0.01)
        a1, a2, truth = seq.a1, seq.a2, set(seq.anomalous_nodes[: args.top_k].tolist())
    else:
        side = int(np.sqrt(args.n))
        a1, a2, ev = climate_like_sequence(ctx, side, args.n // side, sigma=1.0)
        truth = set(np.asarray(ev).tolist())

    t0 = time.perf_counter()
    res = detect_anomalies(ctx, a1, a2, cfg, top_k=args.top_k, use_kernel=args.use_kernel)
    jax.block_until_ready(res.scores)
    dt = time.perf_counter() - t0

    found = np.asarray(res.top_idx).tolist()
    hits = len(truth & set(found))
    print(f"[caddelag] n={args.n} schedule={args.schedule} d={args.d} q={args.q} "
          f"eps={args.eps}: {dt:.2f}s")
    print(f"[caddelag] top-{args.top_k} anomalies: {found}")
    print(f"[caddelag] overlap with ground truth: {hits}/{args.top_k}")


if __name__ == "__main__":
    main()
