"""CADDeLaG driver: the paper's anomaly-detection pipeline on a mesh.

Runs the sequence engine end-to-end on a synthetic GMM snapshot sequence
(paper section 4.2.1) or a climate-like sequence, with the matmul schedule,
chain length d, Richardson iterations q, eps_RP and the sequence length T all
selectable -- the knobs of the paper's accuracy study (Fig. 2) and scaling
study (Fig. 3).  Every snapshot's chain operator is built exactly once and
reused for both transitions it touches.

  python -m repro.launch.caddelag_run --n 256 --t-steps 4 --schedule cannon

Out-of-core mode: ``--store DIR`` writes the synthetic sequence into a tiled
on-disk snapshot store (resumable; skipped if already present) and scores it
end-to-end from disk -- adjacencies are streamed through the tile executor
one row panel at a time and are never fully device-resident.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import CommuteConfig, SequenceDetector, make_context, reset_stream_stats, stream_stats
from repro.graphs import climate_snapshot_sequence, gmm_snapshot_sequence, store_snapshot_sequence
from repro.launch.mesh import make_cpu_mesh


def _default_grid(n: int, n_row_shards: int) -> int:
    """Finest store grid with panels of >= 32 rows that divide the row shards."""
    for g in (16, 8, 4, 2):
        if n % g == 0 and (n // g) % n_row_shards == 0 and n // g >= 32:
            return g
    return 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256, help="graph nodes")
    ap.add_argument("--t-steps", type=int, default=2, help="snapshots in the sequence")
    ap.add_argument("--dataset", default="gmm", choices=["gmm", "climate"])
    ap.add_argument("--drift-nodes", type=int, default=None,
                    help="gmm dataset only: slowly-drifting sequence where "
                         "only this many nodes move per step and no edges are "
                         "injected (near-low-rank dS per transition -- the "
                         "regime --incremental-chain targets)")
    ap.add_argument("--schedule", default="cannon", choices=["xla", "summa", "cannon"])
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--d", type=int, default=6)
    ap.add_argument("--q", type=int, default=10)
    ap.add_argument("--top-k", type=int, default=20)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--use-kernel", action="store_true", help="Pallas tile bodies")
    ap.add_argument("--donate", action="store_true", help="free outgoing snapshots eagerly")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="score out-of-core from a tiled snapshot store at DIR")
    ap.add_argument("--store-grid", type=int, default=None,
                    help="tiles per side when creating the store (default: auto)")
    ap.add_argument("--emb-store", default=None, metavar="DIR",
                    help="publish each snapshot's committed (Z, vol, deg) "
                         "embedding into an EmbeddingStore at DIR -- the "
                         "artifact caddelag-query serves top-k / neighbor "
                         "reads from without re-running the pipeline")
    ap.add_argument("--emb-codec", default="raw", choices=["raw", "bf16"],
                    help="embedding artifact codec (bf16 halves bytes; the "
                         "query kernel decodes it on-device)")
    ap.add_argument("--oocore-chain", action="store_true",
                    help="run the squaring chain out-of-core: S/T/P spill through a "
                         "TileStore scratch, device residency is panels, not n^2")
    ap.add_argument("--oocore-dir", default=None, metavar="DIR",
                    help="scratch dir for --oocore-chain working matrices "
                         "(default: host-RAM scratch)")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="panel-pipeline staging depth: how many row panels the "
                         "background prefetch thread keeps decoded ahead of compute")
    ap.add_argument("--tile-codec", default="raw", choices=["raw", "bf16", "zstd"],
                    help="tile storage codec for --store and the --oocore-chain "
                         "scratch (bf16 halves bytes; zstd needs the optional "
                         "'zstandard' package and falls back to raw without it)")
    ap.add_argument("--use-gemm-kernel", action="store_true",
                    help="fused Pallas stream-GEMM path for the out-of-core "
                         "chain and solver: panels ship in stored form (bf16 "
                         "bit patterns decode on-device, halving H2D) and "
                         "each streamed solve iteration is one fused pass "
                         "over the P2 scratch; interpret-mode fallback "
                         "off-TPU, no effect without --oocore-chain")
    ap.add_argument("--solver-batch", type=int, default=1,
                    help="solver iterations per scratch stream of P2: the "
                         "solver streams the store once per batch and replays "
                         "decoded panels from host RAM (identical scores, "
                         "~batch x fewer scratch reads)")
    ap.add_argument("--solver", default="richardson",
                    choices=["richardson", "chebyshev", "cg"],
                    help="iterative method for the chain solve (see "
                         "repro.core.solvers): chebyshev accelerates the "
                         "Richardson iteration to ~sqrt-fewer iterations using "
                         "the rho(S^{2^d}) estimate cached at chain build "
                         "(adapted upward in-solve when the measured "
                         "contraction misses the predicted rate); cg runs "
                         "conjugate gradients on the deflated SPD subspace "
                         "with degree-weighted inner products")
    ap.add_argument("--warm-start", action="store_true",
                    help="seed each transition's solve with the previous "
                         "snapshot's solution (sequence solves only; "
                         "transition 1 onward) -- slowly-drifting sequences "
                         "converge in far fewer iterations at the same "
                         "tolerance, with scores allclose to cold solves")
    ap.add_argument("--incremental-chain", action="store_true",
                    help="incremental delta-chain updates (repro.core."
                         "delta_chain): on slowly-drifting transitions the "
                         "O(n^3) chain rebuild is replaced by a rank-r "
                         "correction propagated with skinny O(n^2 r) panel "
                         "GEMMs against the retained base chain; a sketched "
                         "drift monitor falls back to a full rebuild when "
                         "||dS||/||S|| exceeds --delta-budget")
    ap.add_argument("--delta-rank", type=int, default=4,
                    help="rank of the incremental chain correction (higher = "
                         "more accurate corrected scores, more skinny-GEMM "
                         "work per transition)")
    ap.add_argument("--delta-budget", type=float, default=0.1,
                    help="drift gate for --incremental-chain: sketched "
                         "||dS||_F / ||S||_F (measured against the last full "
                         "rebuild, so corrections never compound) above which "
                         "the transition triggers a full rebuild")
    ap.add_argument("--solver-tol", type=float, default=None,
                    help="stop the solve when the relative preconditioned "
                         "residual drops below this (default: fixed q "
                         "iterations, the paper's worst-case bound)")
    ap.add_argument("--solver-max-iters", type=int, default=None,
                    help="hard cap on solver refinement steps (default: "
                         "derived from --delta when given; a 300-step safety "
                         "cap when only --solver-tol is set; else q-1)")
    ap.add_argument("--delta", type=float, default=None,
                    help="paper accuracy parameter: bounds iterations at "
                         "q = ceil(log 1/delta) when no explicit cap is given")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a Chrome trace-event JSON of the run "
                         "(Perfetto / chrome://tracing loadable); enables "
                         "span fencing, so phase spans measure honest device "
                         "walls at the cost of extra synchronization")
    ap.add_argument("--run-report", default=None, metavar="OUT.json",
                    help="write a structured RunReport JSON (schema-versioned; "
                         "see repro.obs.report): per-transition phase/bytes/"
                         "solver telemetry, cache hit rates, roofline fraction")
    ap.add_argument("--strict-convergence", action="store_true",
                    help="exit nonzero (code 2) if any transition's solve "
                         "finished NOT-CONVERGED")
    args = ap.parse_args()

    from repro.obs import enable_tracing, tracer
    from repro.obs.report import build_run_report, save_run_report

    if args.trace is not None:
        enable_tracing(fence=True)

    # Resolve the codec once up front: a backend-less zstd request degrades to
    # raw (with a warning) and everything downstream -- scratch stores, the
    # snapshot store, the summary lines -- must report what tiles really are.
    from repro.store import resolve_codec

    effective_codec = resolve_codec(args.tile_codec).name

    mesh = make_cpu_mesh(data=args.data, model=args.model)
    ctx = make_context(mesh)
    cfg = CommuteConfig(eps_rp=args.eps, d=args.d, q=args.q, schedule=args.schedule,
                        oocore=args.oocore_chain, oocore_dir=args.oocore_dir,
                        prefetch_depth=args.prefetch_depth,
                        tile_codec=args.tile_codec, solver_batch=args.solver_batch,
                        use_gemm_kernel=args.use_gemm_kernel,
                        solver=args.solver, solver_tol=args.solver_tol,
                        solver_max_iters=args.solver_max_iters, delta=args.delta,
                        warm_start=args.warm_start,
                        incremental_chain=args.incremental_chain,
                        delta_rank=args.delta_rank,
                        delta_budget=args.delta_budget)

    if args.dataset == "gmm":
        n_nodes = args.n
        if args.drift_nodes is not None:
            seq = gmm_snapshot_sequence(
                ctx, n_nodes, args.t_steps, seed=0, noise=0.02,
                inject_steps=set(), drift_nodes=args.drift_nodes,
            )
        else:
            seq = gmm_snapshot_sequence(ctx, n_nodes, args.t_steps, seed=0, inject_p=0.01)
    else:
        side = int(np.sqrt(args.n))
        n_nodes = side * (args.n // side)  # climate grid may round n down
        if n_nodes != args.n:
            print(f"[caddelag] climate grid {side}x{args.n // side}: using n={n_nodes}")
        seq = climate_snapshot_sequence(ctx, side, args.n // side, args.t_steps, sigma=1.0)

    emb_store = None
    if args.emb_store is not None:
        from repro.store import EmbeddingStore

        emb_store = EmbeddingStore.create(
            args.emb_store, n=n_nodes, k=cfg.k_rp(n_nodes),
            codec=args.emb_codec, seed=cfg.seed,
            meta={"dataset": args.dataset, "n": n_nodes, "seed": 0},
        )

    det = SequenceDetector(
        ctx, cfg, top_k=args.top_k, use_kernel=args.use_kernel, donate=args.donate,
        emb_store=emb_store,
    )
    if args.store is not None:
        from repro.store import TileStore

        grid = args.store_grid or _default_grid(n_nodes, ctx.n_row_shards)
        # meta fingerprints the generator so a reused directory with stale
        # content (different dataset/params) is rejected, not silently scored.
        meta = {"dataset": args.dataset, "n": n_nodes, "seed": 0}
        store = TileStore.create(
            args.store, n=n_nodes, grid=grid, codec=args.tile_codec, meta=meta
        )
        ids = store_snapshot_sequence(store, seq)
        reset_stream_stats()
        res = det.run(store.snapshot(sid) for sid in ids)
        st = stream_stats()
        # One StreamStats covers the run: with --oocore-chain the adjacency
        # panels and the chain-scratch panels share these counters, so label
        # the line accordingly rather than misattributing one to the other.
        what = "adjacency + chain scratch" if args.oocore_chain else "adjacency"
        print(
            f"[caddelag] store={args.store} grid={grid}x{grid} "
            f"codec={store.manifest.codec} prefetch={args.prefetch_depth}: "
            f"{args.t_steps} snapshots, {args.t_steps * store.snapshot_nbytes / 1e6:.1f} MB logical; "
            f"read {st.bytes_read / 1e6:.1f} MB from store, decoded "
            f"{st.bytes_decoded / 1e6:.1f} MB, streamed {st.bytes_h2d / 1e6:.1f} MB "
            f"H2D ({what}) in {st.panels} panels, peak device panel residency "
            f"{st.peak_live_bytes / 1e6:.2f} MB"
        )
    else:
        reset_stream_stats()
        res = det.run(seq.snapshots())
    if args.oocore_chain:
        st = stream_stats()
        extra = " (incl. adjacency streaming)" if args.store is not None else ""
        saved = (
            f" ({st.bytes_h2d_saved / 1e6:.1f} MB saved by on-device decode)"
            if st.bytes_h2d_saved else ""
        )
        print(
            f"[caddelag] oocore chain: working matrices spilled to "
            f"{args.oocore_dir or 'host RAM'} (codec={effective_codec}, "
            f"solver_batch={args.solver_batch}); {st.panels} panels{extra}, "
            f"{st.bytes_read / 1e6:.1f} MB scratch reads, {st.bytes_h2d / 1e6:.1f} MB "
            f"H2D{saved}, peak device panel residency "
            f"{st.peak_live_bytes / 1e6:.2f} MB (vs ~{5 * n_nodes * n_nodes * 4 / 1e6:.2f} MB "
            f"resident chain working set)"
        )

    if emb_store is not None:
        print(
            f"[caddelag] embedding artifacts -> {args.emb_store}: "
            f"{len(emb_store.embedding_ids)} committed (codec="
            f"{emb_store.manifest.codec}, panel_rows={emb_store.panel_rows}); "
            f"serve reads with: caddelag-query --store {args.emb_store} "
            f"--top-k {args.top_k}"
        )

    print(
        f"[caddelag] n={args.n} T={args.t_steps} schedule={args.schedule} "
        f"d={args.d} q={args.q} eps={args.eps}: "
        f"{res.chain_builds} chain builds for {len(res.transitions)} transitions"
    )
    if args.incremental_chain:
        from repro.obs.metrics import REGISTRY

        print(
            f"[caddelag] incremental chain: "
            f"{int(REGISTRY.value('chain.full_rebuilds'))} full rebuilds, "
            f"{int(REGISTRY.value('chain.incremental_updates'))} incremental "
            f"updates, {int(REGISTRY.value('chain.drift_fallbacks'))} drift "
            f"fallbacks (rank={args.delta_rank}, budget={args.delta_budget}, "
            f"last drift={REGISTRY.gauge('chain.drift_last'):.2e}); "
            f"delta GEMM {REGISTRY.value('chain.delta_gemm_flops') / 1e9:.3f} "
            f"GFLOP, {REGISTRY.value('chain.delta_gemm_bytes') / 1e6:.1f} MB "
            f"operand traffic"
        )
    for t, (r, dt) in enumerate(zip(res.transitions, res.transition_seconds)):
        found = np.asarray(r.top_idx).tolist()
        # truth is ranked strongest-first; score recall against its top-k slice
        truth = set(np.asarray(seq.truth[t])[: args.top_k].tolist())
        hits = len(truth & set(found)) if truth else "-"
        print(
            f"[caddelag]   transition {t}->{t + 1}: {dt:6.2f}s  "
            f"top-{args.top_k} truth overlap: {hits}/{len(truth) if truth else 0}"
        )
        # Per-transition solver telemetry: one SolveReport per endpoint
        # embedding (the left one was built by the previous push).
        reps = [rep for rep in r.solve_reports if rep is not None]
        if reps:
            its = "+".join(str(rep.iterations) for rep in reps)
            worst = max(reps, key=lambda rep: rep.residual)
            scratch = sum(rep.bytes_read for rep in reps)
            io = f", {scratch / 1e6:.1f} MB scratch" if any(
                rep.streamed for rep in reps) else ""
            conv = "" if all(rep.converged for rep in reps) else "  NOT-CONVERGED"
            warm = " warm" if any(rep.warm_start for rep in reps) else ""
            print(
                f"[caddelag]     solver[{worst.method}{warm}]: {its} its "
                f"(cap {worst.max_iters}), res {worst.residual:.1e}{io}{conv}"
            )
    total = sum(res.transition_seconds)
    print(f"[caddelag] total {total:.2f}s "
          f"({total / max(len(res.transitions), 1):.2f}s per transition, amortized)")
    g_idx = np.asarray(res.global_top_idx).tolist()
    g_step = np.asarray(res.global_top_step).tolist()
    print(f"[caddelag] sequence-wide top-{args.top_k}: "
          f"{[f'{i}@t{s}' for i, s in zip(g_idx, g_step)]}")

    # Convergence summary: count transitions where any endpoint solve ended
    # NOT-CONVERGED (the per-transition lines above flag which ones).
    bad = sum(
        1 for r in res.transitions
        if any(rep is not None and not rep.converged for rep in r.solve_reports)
    )
    if bad:
        print(
            f"[caddelag] WARNING: {bad}/{len(res.transitions)} transitions "
            f"had a NOT-CONVERGED solve"
        )

    if args.run_report is not None:
        doc = build_run_report(
            config={k.replace("-", "_"): v for k, v in vars(args).items()},
            result=res,
            n=n_nodes,
            k_rp=cfg.k_rp(n_nodes),
        )
        save_run_report(doc, args.run_report)
        print(f"[caddelag] run report -> {args.run_report}")
    if args.trace is not None:
        tracer().save(args.trace)
        print(f"[caddelag] trace -> {args.trace} "
              f"({len(tracer().events())} events; open in Perfetto)")

    if bad and args.strict_convergence:
        raise SystemExit(2)


if __name__ == "__main__":
    main()
