"""Trip-count-aware HLO cost extraction.

``compiled.cost_analysis()`` visits every computation ONCE -- a 95-layer
scan body or a 32-chunk flash-attention loop is counted a single time, which
understates FLOPs and collective bytes by the trip count.  This module parses
the post-SPMD HLO text into its computation blocks, builds the call graph
(fusions/calls weight 1, while bodies weight = known trip count), propagates
execution multipliers from ENTRY, and sums per-computation

  - dot/convolution FLOPs  (2 * prod(result dims) * prod(contracting dims))
  - collective bytes by op type (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), result-shape bytes x ring multiplier

into trip-corrected totals.  Validated against analytic einsum counts in
tests/test_hlo_analysis.py (unrolled-vs-scanned programs must agree).
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "c64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# bytes moved per participating device (large-ring limit)
RING_MULTIPLIER = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CALL_REFS = (
    (re.compile(r"body=%?([\w.\-]+)"), "body"),
    (re.compile(r"condition=%?([\w.\-]+)"), "cond"),
    (re.compile(r"calls=%?([\w.\-]+)"), "call"),
    (re.compile(r"to_apply=%?([\w.\-]+)"), "call"),
    (re.compile(r"branch_computations=\{([^}]*)\}"), "branches"),
)
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_TRIP2 = re.compile(r'"trip_count"\s*:\s*\{\s*"n"\s*:\s*"?(\d+)"?')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _parse_op_line(ln: str):
    """'  %name = SIG op-type(args), attrs' -> (name, sig, op_type, rest).

    SIG may be a parenthesized tuple containing nested brackets/spaces; we
    balance parens instead of regexing.  Returns None if not an op def.
    """
    s = ln.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") and not s[:1].isalpha():
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].lstrip("%").strip()
    rhs = s[eq + 3:]
    if rhs.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        sig = rhs[: i + 1]
        rest = rhs[i + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        sig = rhs[:sp]
        rest = rhs[sp + 1:]
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    return name, sig, m.group(1), rest


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(sig: str) -> int:
    m = _SHAPE.search(sig)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(sig: str) -> list[int]:
    m = _SHAPE.search(sig)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    dot_flops: float = 0.0
    coll_bytes: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_counts: dict = field(default_factory=lambda: {k: 0 for k in COLLECTIVES})
    # edges: (callee, kind, trip)
    edges: list = field(default_factory=list)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    shapes: dict[str, str] = {}  # op name -> type signature
    cur: Computation | None = None

    lines = text.splitlines()
    # pass 1: op shapes (needed for dot operand lookup)
    for ln in lines:
        p = _parse_op_line(ln)
        if p:
            shapes[p[0]] = p[1]

    for ln in lines:
        h = _COMP_HEADER.match(ln.strip()) if ln.rstrip().endswith("{") else None
        if h:
            cur = Computation(name=h.group(2), is_entry=bool(h.group(1)))
            comps[cur.name] = cur
            continue
        if ln.strip() == "}":
            continue
        if cur is None:
            continue
        p = _parse_op_line(ln)
        if not p:
            continue
        op_name, sig, op_type, _rest = p

        # call edges
        for rx, kind in _CALL_REFS:
            for ref in rx.finditer(ln):
                if kind == "branches":
                    for b in _OPERANDS.findall(ref.group(1)):
                        cur.edges.append((b, "call", 1))
                elif kind == "body":
                    trip = 1
                    tm = _TRIP.search(ln) or _TRIP2.search(ln)
                    if tm:
                        trip = int(tm.group(1))
                    cur.edges.append((ref.group(1), "body", trip))
                elif kind == "cond":
                    trip = 1
                    tm = _TRIP.search(ln) or _TRIP2.search(ln)
                    if tm:
                        trip = int(tm.group(1)) + 1
                    cur.edges.append((ref.group(1), "cond", trip))
                else:
                    cur.edges.append((ref.group(1), "call", 1))

        base = op_type.replace("-start", "")
        if base in COLLECTIVES and not op_type.endswith("-done"):
            b = _shape_bytes(sig)
            cur.coll_bytes[base] += b * RING_MULTIPLIER[base]
            cur.coll_counts[base] += 1
        elif op_type in ("dot", "convolution"):
            result_elems = _shape_elems(sig)
            # contracting sizes from lhs operand shape
            operands = _OPERANDS.findall(_rest)
            flops = 0.0
            cm_ = _CONTRACT.search(ln)
            if operands and cm_ is not None and operands[0] in shapes:
                lhs_dims = _shape_dims(shapes[operands[0]])
                contract = 1
                for ci in cm_.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        contract *= lhs_dims[int(ci)]
                flops = 2.0 * result_elems * contract
            else:
                # convolution or unparsable dot: fall back to 2*result elems
                flops = 2.0 * result_elems
            cur.dot_flops += flops
    return comps


def multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    """Execution count per computation, propagated from ENTRY."""
    mult = {name: 0.0 for name in comps}
    entries = [c for c in comps.values() if c.is_entry] or list(comps.values())[:1]

    def visit(name: str, m: float, depth=0):
        if name not in comps or depth > 64:
            return
        mult[name] += m
        for callee, kind, trip in comps[name].edges:
            visit(callee, m * trip, depth + 1)

    for e in entries:
        visit(e.name, 1.0)
    return mult


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    mult = multipliers(comps)
    dot_flops = 0.0
    coll = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0.0 for k in COLLECTIVES}
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        dot_flops += c.dot_flops * m
        for k in COLLECTIVES:
            coll[k] += c.coll_bytes[k] * m
            counts[k] += c.coll_counts[k] * m
    return {
        "dot_flops": dot_flops,
        "collective_bytes": {k: int(v) for k, v in coll.items()},
        "collective_total_bytes": int(sum(coll.values())),
        "collective_counts": {k: int(v) for k, v in counts.items()},
        "n_computations": len(comps),
    }
