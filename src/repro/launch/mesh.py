"""Production mesh factories (functions, never module-level constants --
importing this module must not touch jax device state).

Single pod:  (16, 16)    = 256 v5e chips, axes ("data", "model")
Multi pod:   (2, 16, 16) = 512 chips,     axes ("pod", "data", "model")

``"data"`` carries the batch (FSDP weight shard inside a pod), ``"model"``
carries tensor-parallel / expert / flash-decode-sequence shards, ``"pod"``
is pure data parallelism across pods (slowest links -> fewest collectives:
one gradient all-reduce per step, optionally int8-compressed).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(data: int = 1, model: int = 1, pod: int = 0) -> Mesh:
    """Small mesh over however many (host) devices exist -- tests & examples."""
    n = (pod or 1) * data * model
    devs = np.array(jax.devices()[:n])
    if pod:
        return Mesh(devs.reshape(pod, data, model), ("pod", "data", "model"))
    return Mesh(devs.reshape(data, model), ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
