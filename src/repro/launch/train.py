"""End-to-end fault-tolerant training driver.

Features exercised end-to-end (and tested in tests/test_training.py):
  - jit'd train step with FSDP/TP shardings from the arch's logical axes
  - microbatch gradient accumulation
  - deterministic counter-RNG data pipeline (restart-exact)
  - atomic async checkpointing + restore-on-start (restart loop)
  - failure injection (--fail-at N) to demonstrate recovery
  - straggler watchdog (step-time EMA)
  - elastic re-mesh: restore a checkpoint onto a different mesh shape

Usage (CPU container -- tiny smoke config):
  python -m repro.launch.train --arch granite-3-2b --smoke --steps 20 \
      --ckpt-dir /tmp/ckpt --ckpt-every 5 [--fail-at 12]
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro import configs
from repro.data import DataConfig, host_batch
from repro.launch.mesh import make_cpu_mesh
from repro.models import common as cm
from repro.models import lm
from repro.training import (
    AsyncCheckpointer,
    FailureInjector,
    InjectedFailure,
    OptConfig,
    StepTimer,
    StragglerWatchdog,
    latest_step,
    make_train_step,
    restore,
)
from repro.training.optim import make_optimizer
from repro.training.train_step import _named, init_state


def train_loop(
    cfg,
    mesh,
    *,
    steps: int,
    batch: int,
    seq: int,
    accum: int = 1,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    fail_at: int | None = None,
    seed: int = 0,
    log_every: int = 1,
):
    """Returns (params, opt_state, losses).  Restarts from ckpt if present."""
    spec = lm.build_spec(cfg)
    opt_cfg = OptConfig(name=cfg.optimizer, lr=1e-3, warmup_steps=5, total_steps=steps)
    step_fn, pspecs, ospecs, bspec = make_train_step(spec, mesh, opt_cfg, accum=accum)
    dcfg = DataConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=seed,
        frames_dim=cfg.d_model if cfg.input_mode == "frames" else 0,
    )

    start = 0
    if ckpt_dir and (last := latest_step(ckpt_dir)) is not None:
        pshape = jax.eval_shape(partial(lm.init_params, spec), jax.random.PRNGKey(seed))
        opt_init, _ = make_optimizer(opt_cfg)
        oshape = jax.eval_shape(opt_init, pshape)
        tpl = {"params": pshape, "opt": oshape}
        shardings = {
            "params": _named(mesh, pspecs),
            "opt": _named(mesh, ospecs),
        }
        state, extra, start = restore(ckpt_dir, last, tpl, shardings=shardings)
        params, opt_state = state["params"], state["opt"]
        print(f"[train] restored step {start} from {ckpt_dir}")
    else:
        params, opt_state = init_state(spec, mesh, opt_cfg, seed=seed)

    ckpt = AsyncCheckpointer()
    dog = StragglerWatchdog()
    inj = FailureInjector(fail_at_step=fail_at)
    losses = []

    with mesh:
        for step in range(start, steps):
            inj.check(step)
            b = host_batch(dcfg, step)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            with StepTimer() as t:
                params, opt_state, metrics = step_fn(params, opt_state, b)
                loss = float(metrics["loss"])  # blocks
            losses.append(loss)
            if dog.observe(step, t.dt):
                print(f"[watchdog] straggling step {step}: {t.dt:.3f}s vs EMA {dog.ema:.3f}s")
            if step % log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} ({t.dt*1e3:.0f} ms)")
            if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
                ckpt.save(ckpt_dir, step + 1, {"params": params, "opt": opt_state},
                          extra={"loss": loss})
    ckpt.wait()
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--data", type=int, default=1, help="mesh data-axis size")
    ap.add_argument("--model", type=int, default=1, help="mesh model-axis size")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get_config(args.arch)
    mesh = make_cpu_mesh(data=args.data, model=args.model)

    try:
        _, _, losses = train_loop(
            cfg, mesh, steps=args.steps, batch=args.batch, seq=args.seq,
            accum=args.accum, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            fail_at=args.fail_at,
        )
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    except InjectedFailure as e:
        print(f"[train] {e}; restart the same command to resume from checkpoint")
        raise SystemExit(42)


if __name__ == "__main__":
    main()
