"""Mixture-of-Experts with capacity-based scatter dispatch (EP over "model").

Routing: softmax router, top-k experts per token, position-in-expert by a
cumulative-sum priority, tokens beyond capacity dropped (standard Switch/GShard
semantics).  Dispatch/combine are scatter/gather ``.at[]`` ops on an
(E, C, d) buffer -- XLA lowers the cross-shard movement to an all-to-all when
experts are sharded over "model" and tokens over "data".

Aux losses: load-balancing (Switch LB = E * sum_e f_e * p_e) and router
z-loss, both returned for the trainer to weight.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.tiles import shard_map
from repro.models import common as cm
from repro.models.common import ArchConfig


def init_moe(cfg: ArchConfig, key):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": cm.dense_init(ks[0], (d, e), jnp.float32),  # router in fp32
        "w_gate": cm.dense_init(ks[1], (e, d, f), cfg.pdtype),
        "w_up": cm.dense_init(ks[2], (e, d, f), cfg.pdtype),
        "w_down": cm.dense_init(ks[3], (e, f, d), cfg.pdtype),
    }
    if cfg.n_shared_experts:
        from repro.models.mlp import init_mlp

        f_shared = (cfg.d_expert or cfg.d_ff) * cfg.n_shared_experts
        p["shared"] = init_mlp(cfg, ks[4], d_ff=f_shared)
    return p


def moe_axes(cfg: ArchConfig):
    ax = {
        "router": ("embed_p", "experts"),
        "w_gate": ("experts", "expert_embed", "expert_ff"),
        "w_up": ("experts", "expert_embed", "expert_ff"),
        "w_down": ("experts", "expert_ff", "expert_embed"),
    }
    if cfg.n_shared_experts:
        from repro.models.mlp import mlp_axes

        ax["shared"] = mlp_axes(cfg)
    return ax


def _moe_local(cfg: ArchConfig, p, xt, *, e_total, e_loc, e_offset, k, cap):
    """Shard-local routing + dispatch + expert FFNs.

    xt (t, d): this shard's tokens.  Routing is GLOBAL (router sees all
    ``e_total`` experts); this shard owns experts [e_offset, e_offset+e_loc)
    whose weights are the (sliced) w_* in ``p``.  Contributions to non-local
    experts are dropped by the scatter's out-of-bounds ``mode="drop"`` --
    tokens are model-replicated, so every expert shard sees every token and
    no all-to-all is needed; the partial outputs psum outside.

    Returns (y_partial (t, d), aux).
    """
    t, d = xt.shape
    dt = cfg.cdtype
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = lax.top_k(probs, k)  # (t, k) over e_total
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # per-expert positions: local cumsum priority over this shard's tokens
    onehot = jax.nn.one_hot(expert_ids, e_total, dtype=jnp.int32)  # (t, k, e)
    flat = onehot.reshape(t * k, e_total)
    pos_flat = jnp.cumsum(flat, axis=0) - flat  # exclusive
    position = (pos_flat.reshape(t, k, e_total) * onehot).sum(-1)  # (t, k)
    keep = position < cap

    # dispatch into the LOCAL (e_loc, C, d) buffer; non-local experts are
    # redirected to row e_loc (out of bounds HIGH -> dropped; negative
    # indices would WRAP python-style, so they cannot be used for dropping)
    local_ids = expert_ids - e_offset
    owned = (local_ids >= 0) & (local_ids < e_loc)
    dispatch_ids = jnp.where(owned, local_ids, e_loc)
    buf = jnp.zeros((e_loc, cap, d), dt)
    safe_pos = jnp.where(keep, position, cap - 1)
    contrib = jnp.where(keep[..., None], xt[:, None, :].astype(dt), 0)
    buf = buf.at[dispatch_ids, safe_pos].add(contrib, mode="drop")

    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))

    take = owned & keep
    gathered = out_buf[jnp.clip(local_ids, 0, e_loc - 1), safe_pos]  # (t, k, d)
    w = (gate_vals * take).astype(jnp.float32)[..., None]
    y = (gathered.astype(jnp.float32) * w).sum(axis=1).astype(dt)

    me = probs.mean(axis=0)
    ce = (onehot.sum(1) > 0).astype(jnp.float32).mean(axis=0)
    lb_loss = e_total * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return y, {"lb_loss": lb_loss, "z_loss": z_loss}


def _apply_moe_gathered(cfg: ArchConfig, p, x, *, rules, mesh, e_ax, d_ax, batch_axes):
    """Decode-path MoE: move the (tiny) token batch, never the weights.

    Weight in_specs MATCH the 2-axis storage (experts over ``e_ax``, d_model
    over ``d_ax``), so entering the shard_map moves ZERO weight bytes --
    vs the train path's per-layer d-gather, which at decode (one token per
    step) re-gathers GBs of expert weights per token.  Tokens are
    all-gathered (KBs), each d-shard contracts its slice, h psums over the
    d axis, combine psums over the expert axis.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n_e = mesh.shape[e_ax]
    n_d = mesh.shape[d_ax]
    e_loc, d_loc = e // n_e, d // n_d
    f_dim = cfg.d_expert or cfg.d_ff
    t = b * s
    cap = max(4, min(int(cfg.capacity_factor * t * k / e), t))
    dt = cfg.cdtype

    def local(x_loc, wp):
        xt = x_loc.reshape(-1, d)
        xt_all = lax.all_gather(xt, batch_axes, axis=0, tiled=True)  # (t, d)
        logits = jnp.einsum("td,de->te", xt_all.astype(jnp.float32), wp["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

        onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.int32)
        flat = onehot.reshape(t * k, e)
        pos_flat = jnp.cumsum(flat, axis=0) - flat
        position = (pos_flat.reshape(t, k, e) * onehot).sum(-1)
        keep = position < cap

        e_off = lax.axis_index(e_ax) * e_loc
        local_ids = expert_ids - e_off
        owned = (local_ids >= 0) & (local_ids < e_loc)
        dispatch_ids = jnp.where(owned, local_ids, e_loc)

        r_d = lax.axis_index(d_ax)
        xt_d = lax.dynamic_slice_in_dim(xt_all, r_d * d_loc, d_loc, axis=1)
        buf = jnp.zeros((e_loc, cap, d_loc), dt)
        safe_pos = jnp.where(keep, position, cap - 1)
        contrib = jnp.where(keep[..., None], xt_d[:, None, :].astype(dt), 0)
        buf = buf.at[dispatch_ids, safe_pos].add(contrib, mode="drop")

        # d-partial expert GEMMs; h exact after psum over the d axis
        g = jnp.einsum("ecd,edf->ecf", buf, wp["w_gate"].astype(dt))
        u = jnp.einsum("ecd,edf->ecf", buf, wp["w_up"].astype(dt))
        g = lax.psum(g, d_ax)
        u = lax.psum(u, d_ax)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, wp["w_down"].astype(dt))  # (e_loc,C,d_loc)

        take = owned & keep
        gathered = out_buf[jnp.clip(local_ids, 0, e_loc - 1), safe_pos]
        w = (gate_vals * take).astype(jnp.float32)[..., None]
        y_all = (gathered.astype(jnp.float32) * w).sum(axis=1).astype(dt)  # (t, d_loc)
        y_all = lax.psum(y_all, e_ax)

        me = probs.mean(axis=0)
        ce = (onehot.sum(1) > 0).astype(jnp.float32).mean(axis=0)
        aux = {
            "lb_loss": e * jnp.sum(me * ce),
            "z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        }
        return y_all, aux

    wspec = {
        "router": P(),
        "w_gate": P(e_ax, d_ax, None),
        "w_up": P(e_ax, d_ax, None),
        "w_down": P(e_ax, None, d_ax),
    }
    xspec = P(batch_axes, None, None)
    wp = {kk: p[kk] for kk in ("router", "w_gate", "w_up", "w_down")}
    y_all, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(xspec, wspec),
        out_specs=(P(None, d_ax), jax.tree.map(lambda _: P(), {"lb_loss": 0, "z_loss": 0})),
        check=False,
    )(x, wp)
    # back to batch-sharded layout (tiny resharding collective)
    y = cm.constrain(y_all.reshape(b, s, d), ("batch", "seq", "embed"), rules)
    return y, aux


def apply_moe(cfg: ArchConfig, p, x, *, rules=cm.DEFAULT_RULES):
    """x (B, S, d) -> (y (B, S, d), aux dict with lb_loss / z_loss).

    Distribution (manual shard_map; the GSPMD scatter lowering of capacity
    dispatch is pathological, all-gathering every contribution):

      - tokens: sharded over the batch axes, replicated over "model"
      - experts: if E divides the "model" axis -> expert parallelism (each
        model shard owns E_loc experts and processes every token routed to
        them; psum over "model" combines -- no all-to-all since tokens are
        already replicated there)
      - else (fine-grained experts, e.g. granite-moe's 40): expert weights
        replicated over "model" with the expert FFN dim f sharded instead
        (psum over "model" on the f contraction)
      - capacity is per batch-shard (the standard EP formulation)

    On a plain context (no mesh in rules) the same math runs locally.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    mesh = rules.get("_mesh") if isinstance(rules, dict) else None

    def cap_for(t):
        c = int(cfg.capacity_factor * t * k / e)
        return max(4, min(c, t))

    if mesh is None:
        xt = x.reshape(b * s, d)
        y, aux = _moe_local(cfg, p, xt, e_total=e, e_loc=e, e_offset=0, k=k,
                            cap=cap_for(b * s))
    elif rules.get("moe_gathered"):
        e_ax = rules.get("experts")
        d_ax = rules.get("expert_embed")
        d_ax = d_ax if isinstance(d_ax, str) else None
        batch_axes = rules.get("batch") or ()
        batch_axes = batch_axes if isinstance(batch_axes, tuple) else (batch_axes,)
        batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
        ok = (
            e_ax in mesh.axis_names and e % mesh.shape[e_ax] == 0
            and d_ax in mesh.axis_names and d % mesh.shape[d_ax] == 0
            and batch_axes and b % int(np.prod([mesh.shape[a] for a in batch_axes])) == 0
        )
        if ok:
            yg, aux = _apply_moe_gathered(
                cfg, p, x, rules=rules, mesh=mesh, e_ax=e_ax, d_ax=d_ax,
                batch_axes=batch_axes,
            )
            return _shared_expert_add(cfg, p, x, yg, rules), aux
        return apply_moe(cfg, p, x, rules={k_: v for k_, v in rules.items() if k_ != "moe_gathered"})
    else:
        batch_axes = rules.get("batch") or ()
        batch_axes = batch_axes if isinstance(batch_axes, tuple) else (batch_axes,)
        batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
        if batch_axes and b % int(np.prod([mesh.shape[a] for a in batch_axes])):
            batch_axes = ()
        n_batch = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
        t_loc = b * s // n_batch
        cap = cap_for(t_loc)

        e_ax = rules.get("experts")
        if e_ax is not None and e % mesh.shape.get(e_ax, 1):
            e_ax = None  # cannot shard the expert dim evenly
        f_dim = cfg.d_expert or cfg.d_ff
        f_ax = rules.get("expert_ff") if e_ax is None else None
        if f_ax is not None and f_dim % mesh.shape.get(f_ax, 1):
            f_ax = None
        n_e = mesh.shape[e_ax] if e_ax else 1
        reduce_axes = tuple(a for a in (e_ax, f_ax) if a is not None)

        wspec = {
            "router": P(),
            "w_gate": P(e_ax, None, f_ax),
            "w_up": P(e_ax, None, f_ax),
            "w_down": P(e_ax, f_ax, None),
        }

        def local(xt, wp):
            off = lax.axis_index(e_ax) * (e // n_e) if e_ax else 0
            y, aux = _moe_local(
                cfg, wp, xt.reshape(-1, d),
                e_total=e, e_loc=e // n_e, e_offset=off, k=k, cap=cap,
            )
            if reduce_axes:
                y = lax.psum(y, reduce_axes)
            if batch_axes:
                aux = jax.tree.map(lambda v: lax.pmean(v, batch_axes), aux)
            return y.reshape(xt.shape), aux

        xspec = P(batch_axes if batch_axes else None, None, None)
        wp = {kk: p[kk] for kk in ("router", "w_gate", "w_up", "w_down")}
        y, aux = shard_map(
            local,
            mesh=mesh,
            in_specs=(xspec, wspec),
            out_specs=(xspec, jax.tree.map(lambda _: P(), {"lb_loss": 0, "z_loss": 0})),
            check=False,
        )(x, wp)
        y = y.reshape(b * s, d)

    y = _shared_expert_add(cfg, p, x, y.reshape(b, s, d), rules)
    return y, aux


def _shared_expert_add(cfg, p, x, y, rules):
    """y (B,S,d) += shared-expert MLP(x) when the arch has one."""
    if cfg.n_shared_experts:
        from repro.models.mlp import apply_mlp

        return y + apply_mlp(cfg, p["shared"], x, rules=rules)
    return y
