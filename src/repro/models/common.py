"""Shared model substrate: config, sharding rules, norms, initializers.

Every parameter carries a tuple of *logical* axis names; ``logical_to_spec``
maps them to mesh axes via the rules table.  The same model code therefore
runs on a 1-device CPU mesh, the 16x16 single-pod mesh, and the 2x16x16
multi-pod mesh -- only the rules change.

Sharding strategy (baseline):
  batch         -> ("pod", "data")   # DP across pods, FSDP axis inside
  vocab/heads/ff/experts -> "model"  # tensor parallel
  embed (d_model) on *params*  -> "data"  (FSDP: gather per layer under scan)
  kv sequence on *decode caches* -> "model" (flash-decode style)
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """One config object for every assigned architecture family."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    norm: str = "rms"  # rms | ln
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    moe_layer_step: int = 1  # every k-th layer is MoE (llama4: 2)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0  # zamba2: shared attention block every k SSM layers
    # --- RWKV6 ---
    rwkv: bool = False
    rwkv_head_dim: int = 64
    # --- encoder-decoder ---
    enc_layers: int = 0
    dec_layers: int = 0
    # --- modality frontend ---
    input_mode: str = "tokens"  # tokens | frames (precomputed embeddings stub)
    # --- sharding ---
    # per-arch logical-rule overrides, e.g. granite-moe's 40 experts do not
    # divide a 16-way "model" axis, so it shards the MoE capacity dim instead
    rules_override: tuple = ()
    # --- numerics / execution ---
    optimizer: str = "adamw"  # adamw | adafactor (large-MoE memory diet)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    vocab_chunk: int = 4096  # sequence chunk for the vocab-chunked loss
    attn_chunk: int = 1024  # KV chunk for pure-JAX flash attention
    max_seq: int = 131072  # RoPE table upper bound (decode positions)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to a multiple of 256 so the vocab axis
        shards evenly on any mesh up to 256-way; logits beyond ``vocab``
        are masked to -inf in the unembed (standard MaxText-style padding)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# logical sharding rules
# ---------------------------------------------------------------------------

# logical axis -> mesh axis (or None).  "batch" may map to a tuple of axes.
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("data",),
    "batch_inner": ("data",),  # batch axes usable alongside vocab sharding
    "seq": None,
    "kv_seq": "model",  # decode caches: flash-decode over model axis
    "embed": None,  # activations d_model replicated
    "embed_p": "data",  # params d_model axis: FSDP shard
    "embed_d": "data",  # embedding/unembedding tables' d_model axis
    "vocab": "model",
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "ff": "model",
    "experts": "model",
    "expert_embed": "data",  # expert weights' d_model dim (2-axis storage)
    "expert_ff": None,
    "moe_cap": "data",  # MoE dispatch-buffer capacity dim
    "inner": "model",  # mamba/rwkv inner channels
    "state": None,
    "layers": None,
}


def multipod_rules() -> dict[str, Any]:
    r = dict(DEFAULT_RULES)
    r["batch"] = ("pod", "data")
    r["batch_inner"] = ("pod", "data")
    return r


def arch_rules(cfg: "ArchConfig", rules: dict[str, Any]) -> dict[str, Any]:
    """Apply the config's per-arch logical-rule overrides."""
    if not cfg.rules_override:
        return rules
    return {**rules, **dict(cfg.rules_override)}


def logical_to_spec(axes: Sequence[str | None], rules: dict[str, Any]) -> P:
    parts = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        parts.append(m)
    return P(*parts)


def tree_specs(logical_tree, rules: dict[str, Any]):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes: logical_to_spec(axes, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def constrain(x: jax.Array, axes: Sequence[str | None], rules: dict[str, Any]) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op outside jit mesh ctx).

    If the rules carry ``_axis_sizes`` (attached by the launcher), any spec
    entry whose mesh-axis product does not divide the dimension is dropped --
    e.g. qwen2's 12 q-heads are left unsharded on a 16-wide "model" axis
    instead of tripping GSPMD padding on an activation.
    """
    spec = logical_to_spec(axes, rules)
    sizes = rules.get("_axis_sizes")
    if sizes:
        parts = []
        entries = list(tuple(spec)) + [None] * (x.ndim - len(tuple(spec)))
        for dim, entry in enumerate(entries):
            if entry is None:
                parts.append(None)
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            prod = int(np.prod([sizes.get(n, 1) for n in names]))
            parts.append(entry if (prod and x.shape[dim] % prod == 0) else None)
        spec = P(*parts)
    mesh = rules.get("_mesh")
    if mesh is not None:
        # explicit NamedSharding: works outside a `with mesh:` context too
        # (the dry-run lowers without an ambient mesh).
        return lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    try:
        return lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):  # no ambient mesh (plain CPU tests)
        return x


def attach_axis_sizes(rules: dict[str, Any], mesh: Mesh) -> dict[str, Any]:
    """Return a copy of rules carrying the mesh + axis sizes (for constrain)."""
    return {
        **rules,
        "_mesh": mesh,
        "_axis_sizes": {k: int(v) for k, v in mesh.shape.items()},
    }


def sanitize_spec(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Drop spec entries that do not divide the dim (jit in_shardings must
    divide exactly); the dim falls back to replicated."""
    parts = []
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    for dim, entry in zip(shape, entries):
        if entry is None:
            parts.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        prod = int(np.prod([mesh.shape[n] for n in names]))
        parts.append(entry if (prod and dim % prod == 0) else None)
    return P(*parts)


def sanitize_specs(specs, shapes, mesh: Mesh):
    """Tree-map sanitize_spec over (specs, ShapeDtypeStruct-tree)."""
    return jax.tree.map(
        lambda s, x: sanitize_spec(s, x.shape, mesh),
        specs,
        shapes,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# initializers & primitive layers (pure functions over param dicts)
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (the LLaMA/PaLM default)."""
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def make_norm(cfg: ArchConfig, d: int):
    """Returns (init_fn, apply_fn) for the configured norm type."""

    def init(key):
        p = {"scale": jnp.ones((d,), cfg.pdtype)}
        if cfg.norm == "ln":
            p["bias"] = jnp.zeros((d,), cfg.pdtype)
        return p

    def apply(p, x):
        xf = x.astype(jnp.float32)
        if cfg.norm == "ln":
            mu = xf.mean(-1, keepdims=True)
            var = ((xf - mu) ** 2).mean(-1, keepdims=True)
            y = (xf - mu) * lax.rsqrt(var + 1e-5)
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        else:
            ms = (xf * xf).mean(-1, keepdims=True)
            y = xf * lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
        return y.astype(x.dtype)

    return init, apply


def norm_axes(cfg: ArchConfig):
    ax = {"scale": ("embed",)}
    if cfg.norm == "ln":
        ax["bias"] = ("embed",)
    return ax


def rope_tables(positions: jax.Array, head_dim: int, theta: float):
    """cos/sin tables for the given absolute positions, (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (S, D/2) broadcast over batch/heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :] if x.ndim == 4 else cos
    s = sin[..., None, :] if x.ndim == 4 else sin
    # broadcast (S, half) -> (..., S, H, half)
    while c.ndim < x1.ndim:
        c, s = c[None], s[None]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1).astype(x.dtype)


def stack_init(init_fn, key, count: int):
    """vmap an init over ``count`` layer keys -> params stacked on axis 0."""
    keys = jax.random.split(key, count)
    return jax.vmap(init_fn)(keys)


def stacked_axes(axes_tree):
    """Prepend the scanned 'layers' logical axis to every leaf's axes."""
    return jax.tree.map(
        lambda axes: ("layers",) + axes,
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )
