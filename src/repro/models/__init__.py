"""Pure-JAX composable LM substrate (GQA / MoE / Mamba2 / RWKV6 / enc-dec)."""

from repro.models.common import ArchConfig, DEFAULT_RULES, multipod_rules
from repro.models.lm import (
    LMSpec,
    build_spec,
    cache_axes,
    decode_step,
    init_cache,
    init_params,
    loss_fn,
    param_axes,
    param_count,
    param_specs,
    prefill,
)

__all__ = [
    "ArchConfig",
    "DEFAULT_RULES",
    "LMSpec",
    "build_spec",
    "cache_axes",
    "decode_step",
    "init_cache",
    "init_params",
    "loss_fn",
    "multipod_rules",
    "param_axes",
    "param_count",
    "param_specs",
    "prefill",
]
