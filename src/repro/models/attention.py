"""GQA attention: chunked-flash training/prefill, flash-decode serving.

Pure-JAX chunked flash (lax.scan over KV blocks, online softmax) is the
portable path that lowers on any backend -- it is what the dry-run compiles.
``repro.kernels.flash_attention`` is the Pallas fast path for real TPUs; the
two are allclose-tested against each other.

Decode shards the KV cache *sequence* over the "model" mesh axis
(flash-decode): per-shard partial softmax statistics are combined by the
all-reduces XLA inserts for the sharded-S softmax/contraction -- no
materialized (B, H, S) ever lives on one chip.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as cm
from repro.models.common import ArchConfig

_NEG_INF = -1e30


def init_attention(cfg: ArchConfig, key, *, d_in: int | None = None):
    """QKVO projections (+optional bias, qk-norm scales)."""
    d = d_in or cfg.d_model
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": cm.dense_init(ks[0], (d, nh * hd), cfg.pdtype),
        "wk": cm.dense_init(ks[1], (d, nkv * hd), cfg.pdtype),
        "wv": cm.dense_init(ks[2], (d, nkv * hd), cfg.pdtype),
        "wo": cm.dense_init(ks[3], (nh * hd, cfg.d_model), cfg.pdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((nkv * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((nkv * hd,), cfg.pdtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.pdtype)
        p["k_norm"] = jnp.ones((hd,), cfg.pdtype)
    return p


def attention_axes(cfg: ArchConfig):
    ax = {
        "wq": ("embed_p", "heads"),
        "wk": ("embed_p", "kv_heads"),
        "wv": ("embed_p", "kv_heads"),
        "wo": ("heads", "embed_p"),
    }
    if cfg.qkv_bias:
        ax.update(bq=("heads",), bk=("kv_heads",), bv=("kv_heads",))
    if cfg.qk_norm:
        ax.update(q_norm=("head_dim",), k_norm=("head_dim",))
    return ax


def _rms(x, scale):
    xf = x.astype(jnp.float32)
    y = xf * lax.rsqrt((xf * xf).mean(-1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _project_qkv(cfg: ArchConfig, p, x, positions):
    """x (B, S, d_in) -> q (B,S,nh,hd), k/v (B,S,nkv,hd) with RoPE applied."""
    b, s, _ = x.shape
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    dt = cfg.cdtype
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)
    if cfg.qk_norm:
        q = _rms(q, p["q_norm"])
        k = _rms(k, p["k_norm"])
    cos, sin = cm.rope_tables(positions, hd, cfg.rope_theta)
    q = cm.apply_rope(q, cos, sin)
    k = cm.apply_rope(k, cos, sin)
    return q, k, v


def _chunked_flash(cfg: ArchConfig, q, k, v, *, causal: bool, rules) -> jax.Array:
    """(B,S,nh,hd) x (B,T,nkv,hd) -> (B,S,nh,hd): scan over KV chunks.

    Online softmax; GQA handled by reshaping q to (B,S,nkv,groups,hd) so the
    kv head axis contracts without materializing repeated K/V.
    """
    b, s, nh, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    ck = min(cfg.attn_chunk, t)
    while t % ck:
        ck //= 2
    n_chunks = t // ck
    scale = 1.0 / math.sqrt(hd)

    qf = q.astype(jnp.float32).reshape(b, s, nkv, g, hd) * scale
    kc = k.astype(jnp.float32).reshape(b, n_chunks, ck, nkv, hd)
    vc = v.astype(jnp.float32).reshape(b, n_chunks, ck, nkv, hd)
    q_pos = jnp.arange(s)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        idx, kb, vb = inp  # kb/vb: (B, ck, nkv, hd)
        sc = jnp.einsum("bsngh,bcnh->bsngc", qf, kb)  # (B,S,nkv,g,ck)
        if causal:
            k_pos = idx * ck + jnp.arange(ck)
            mask = q_pos[:, None] >= k_pos[None, :]  # (S, ck)
            sc = jnp.where(mask[None, :, None, None, :], sc, _NEG_INF)
        m_cur = jnp.max(sc, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        pexp = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + pexp.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bsngc,bcnh->bsngh", pexp, vb)
        return (m_new, l_new, acc), None

    # constrain the carry init: without this GSPMD may pick a replicated-batch
    # layout for the while-loop carries (16x the per-device work).
    init = (
        cm.constrain(jnp.full((b, s, nkv, g), _NEG_INF, jnp.float32), ("batch", "seq", None, None), rules),
        cm.constrain(jnp.zeros((b, s, nkv, g), jnp.float32), ("batch", "seq", None, None), rules),
        cm.constrain(jnp.zeros((b, s, nkv, g, hd), jnp.float32), ("batch", "seq", None, None, None), rules),
    )
    xs = (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0))
    # remat each KV-chunk step: the backward recomputes the (bq, bk) score
    # tile instead of saving it per chunk -- flash-attention-backward memory.
    step_fn = jax.checkpoint(step) if cfg.remat else step
    (m, l, acc), _ = lax.scan(step_fn, init, xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, s, nh, hd).astype(cfg.cdtype)


def attend_train(cfg: ArchConfig, p, x, *, causal: bool = True, rules=cm.DEFAULT_RULES,
                 kv_override: tuple[jax.Array, jax.Array] | None = None):
    """Full-sequence attention (training / encoder / cross-attention).

    ``kv_override=(k, v)`` turns this into cross-attention (q from x).
    """
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(cfg, p, x, positions)
    if kv_override is not None:
        k, v = kv_override
    q = cm.constrain(q, ("batch", "seq", "heads", "head_dim"), rules)
    out = _chunked_flash(cfg, q, k, v, causal=causal, rules=rules)
    out = out.reshape(b, s, -1)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cfg.cdtype))


def attend_prefill(cfg: ArchConfig, p, x, *, rules=cm.DEFAULT_RULES):
    """Causal attention that also returns the (k, v) cache for decode."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(cfg, p, x, positions)
    out = _chunked_flash(cfg, q, k, v, causal=True, rules=rules)
    out = out.reshape(b, s, -1)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cfg.cdtype))
    # cache layout (B, S, nkv, hd), sequence sharded over "model" (flash-decode)
    k = cm.constrain(k, ("batch", "kv_seq", "kv_heads", "head_dim"), rules)
    v = cm.constrain(v, ("batch", "kv_seq", "kv_heads", "head_dim"), rules)
    return y, (k, v)


def attend_decode(cfg: ArchConfig, p, x, cache, pos, *, rules=cm.DEFAULT_RULES):
    """One-token decode against a (k, v) cache; returns (y, new_cache).

    cache k/v: (B, S_max, nkv, hd) with the current token written at ``pos``.
    Softmax over the sequence-sharded cache = flash-decode (XLA inserts the
    cross-shard max/sum all-reduces).
    """
    b, one, _ = x.shape
    k_cache, v_cache = cache
    s_max = k_cache.shape[1]
    positions = jnp.full((one,), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)

    k_cache = lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0))
    k_cache = cm.constrain(k_cache, ("batch", "kv_seq", "kv_heads", "head_dim"), rules)
    v_cache = cm.constrain(v_cache, ("batch", "kv_seq", "kv_heads", "head_dim"), rules)

    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = nh // nkv
    qf = q.astype(jnp.float32).reshape(b, one, nkv, g, hd) * (1.0 / math.sqrt(hd))
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    sc = jnp.einsum("bsngh,btnh->bsngt", qf, kf)  # (B,1,nkv,g,S_max)
    valid = jnp.arange(s_max) <= pos
    sc = jnp.where(valid[None, None, None, None, :], sc, _NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bsngt,btnh->bsngh", w, vf)
    out = out.reshape(b, one, nh * hd).astype(cfg.cdtype)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cfg.cdtype))
    return y, (k_cache, v_cache)


def cross_attend_decode(cfg: ArchConfig, p, x, enc_kv, pos, *, rules=cm.DEFAULT_RULES):
    """Decode-time cross-attention: static encoder K/V, no cache update.

    Q gets RoPE at the decoder position (matching attend_train's projection
    path at prefill); encoder K stays unrotated on both paths.
    """
    b, one, _ = x.shape
    k, v = enc_kv
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = nh // nkv
    dt = cfg.cdtype
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    q = q.reshape(b, one, nh, hd)
    if cfg.qk_norm:
        q = _rms(q, p["q_norm"])
    positions = jnp.full((one,), pos, jnp.int32)
    cos, sin = cm.rope_tables(positions, hd, cfg.rope_theta)
    q = cm.apply_rope(q, cos, sin)
    qf = q.astype(jnp.float32).reshape(b, one, nkv, g, hd) * (1.0 / math.sqrt(hd))
    sc = jnp.einsum("bsngh,btnh->bsngt", qf, k.astype(jnp.float32))
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bsngt,btnh->bsngh", w, v.astype(jnp.float32))
    out = out.reshape(b, one, nh * hd).astype(dt)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(dt))


def project_kv(cfg: ArchConfig, p, x_enc):
    """Encoder output -> cross-attention K/V (no RoPE on cross keys)."""
    b, t, _ = x_enc.shape
    nkv, hd = cfg.n_kv_heads, cfg.hd
    dt = cfg.cdtype
    k = jnp.einsum("bsd,dh->bsh", x_enc, p["wk"].astype(dt)).reshape(b, t, nkv, hd)
    v = jnp.einsum("bsd,dh->bsh", x_enc, p["wv"].astype(dt)).reshape(b, t, nkv, hd)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt).reshape(nkv, hd)
        v = v + p["bv"].astype(dt).reshape(nkv, hd)
    return k, v
