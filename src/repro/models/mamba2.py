"""Mamba2 (SSD) layer: chunked state-space dual form + one-step decode.

The SSD recurrence per head h with scalar decay a_t = exp(dt_t * A_h):

    H_t = a_t * H_{t-1} + dt_t * B_t (x) x_t          (H: (headdim, d_state))
    y_t = C_t . H_t + D_h * x_t

Chunked evaluation (chunk Q): intra-chunk is a masked (C B^T) "attention"
with decay mask L[i,j] = exp(cum_i - cum_j); inter-chunk carries the state
through a scan over chunks -- O(S Q) instead of O(S^2), all MXU matmuls.

TP note: the input projections are SPLIT (w_z, w_x, w_b, w_c, w_dt) rather
than one packed matrix so the wide ones (w_z, w_x: d -> d_inner) shard
evenly over the "model" axis; the packed layout's odd total width
(2*d_inner + 2*N + H) cannot.  Same math, shardable layout.

``ssd_reference`` is the naive per-step scan used as the allclose oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as cm
from repro.models.common import ArchConfig

_CONV_K = 4


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    return d_inner, n_heads, cfg.ssm_state


def init_mamba(cfg: ArchConfig, key):
    d = cfg.d_model
    d_inner, nh, ds = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_z": cm.dense_init(ks[0], (d, d_inner), cfg.pdtype),  # gate
        "w_x": cm.dense_init(ks[1], (d, d_inner), cfg.pdtype),
        "w_b": cm.dense_init(ks[2], (d, ds), cfg.pdtype),
        "w_c": cm.dense_init(ks[3], (d, ds), cfg.pdtype),
        "w_dt": cm.dense_init(ks[4], (d, nh), cfg.pdtype),
        "conv_wx": (0.1 * jax.random.normal(ks[5], (d_inner, _CONV_K), jnp.float32)).astype(cfg.pdtype),
        "conv_wbc": (0.1 * jax.random.normal(ks[6], (2 * ds, _CONV_K), jnp.float32)).astype(cfg.pdtype),
        "conv_b": jnp.zeros((d_inner + 2 * ds,), cfg.pdtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((d_inner,), cfg.pdtype),
        "w_out": cm.dense_init(ks[7], (d_inner, d), cfg.pdtype),
    }


def mamba_axes(cfg: ArchConfig):
    return {
        "w_z": ("embed_p", "inner"),
        "w_x": ("embed_p", "inner"),
        "w_b": ("embed_p", None),
        "w_c": ("embed_p", None),
        "w_dt": ("embed_p", None),
        "conv_wx": ("inner", None),
        "conv_wbc": (None, None),
        "conv_b": (None,),
        "a_log": ("state",),
        "d_skip": ("state",),
        "dt_bias": ("state",),
        "norm": ("inner",),
        "w_out": ("inner", "embed_p"),
    }


def _project(cfg: ArchConfig, p, x):
    """x (B,S,d) -> (z, x_in, b, c, dt_raw) pre-conv projections."""
    dt = cfg.cdtype
    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(dt))
    xi = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(dt))
    b = jnp.einsum("bsd,dn->bsn", x, p["w_b"].astype(dt))
    c = jnp.einsum("bsd,dn->bsn", x, p["w_c"].astype(dt))
    dtr = jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(dt))
    return z, xi, b, c, dtr


def _causal_conv(u, w, b):
    """Depthwise causal conv, kernel _CONV_K; u (B, S, C), w (C, K)."""
    pad = jnp.pad(u, ((0, 0), (_CONV_K - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + u.shape[1], :] * w[None, None, :, i].astype(u.dtype)
        for i in range(_CONV_K)
    )
    return out + b.astype(u.dtype)


def _conv_all(cfg, p, xi, b, c):
    """Conv x with the sharded filter, (B, C) jointly with the tiny one."""
    d_inner, _, ds = _dims(cfg)
    bx = p["conv_b"][:d_inner]
    bbc = p["conv_b"][d_inner:]
    xi = _causal_conv(xi, p["conv_wx"], bx)
    bc = _causal_conv(jnp.concatenate([b, c], -1), p["conv_wbc"], bbc)
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(xi.dtype)
    bc = jax.nn.silu(bc.astype(jnp.float32)).astype(bc.dtype)
    return xi, bc[..., :ds], bc[..., ds:]


def _gated_norm(p, y, z):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * lax.rsqrt((yf * yf).mean(-1, keepdims=True) + 1e-6)
    return yf * p["norm"].astype(jnp.float32)


def ssd_chunked(x, dt, a_log, b_mat, c_mat, d_skip, *, chunk: int, h0=None):
    """SSD core.  x (B,S,H,P); dt (B,S,H); b/c (B,S,N); returns (y, h_final).

    h0 / h_final: (B, H, P, N) inter-chunk state.
    """
    bs, s, nh, hd = x.shape
    ds = b_mat.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q

    la = (-jnp.exp(a_log)[None, None, :] * dt).reshape(bs, nc, q, nh)  # log a_t
    xc = x.reshape(bs, nc, q, nh, hd)
    dtc = dt.reshape(bs, nc, q, nh)
    bc = b_mat.reshape(bs, nc, q, ds)
    cc = c_mat.reshape(bs, nc, q, ds)

    cum = jnp.cumsum(la, axis=2)  # (B,nc,Q,H) inclusive
    # intra-chunk: Y[i] = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B,nc,Q,Q)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    # mask the EXPONENT (not the exp): exp(+large) at masked (i<j) positions
    # would be inf, and where(mask, inf, 0) has NaN gradients (0 * inf).
    decay = jnp.where(mask[None, None, :, :, None], decay, -jnp.inf)
    lmat = jnp.exp(decay)
    y_intra = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp", scores, lmat, dtc, xc)

    # chunk states: S_c = sum_j exp(cum_Q - cum_j) dt_j B_j (x) x_j
    tail = jnp.exp(cum[:, :, -1:, :] - cum) * dtc  # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", tail, bc, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    def carry_fn(h, inp):
        s_c, dec = inp  # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + s_c
        return h_new, h

    h_init = h0 if h0 is not None else jnp.zeros((bs, nh, hd, ds), x.dtype)
    h_fin, h_prevs = lax.scan(
        carry_fn,
        h_init.astype(jnp.float32),
        (jnp.moveaxis(s_chunk, 1, 0).astype(jnp.float32), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,nc,H,P,N) state entering chunk

    # inter-chunk: Y_inter[i] = exp(cum_i) * C_i . H_prev
    y_inter = jnp.einsum(
        "bcih,bcin,bchpn->bcihp", jnp.exp(cum), cc, h_prevs.astype(x.dtype)
    )
    y = (y_intra + y_inter).reshape(bs, s, nh, hd)
    y = y + d_skip[None, None, :, None] * x
    return y, h_fin.astype(x.dtype)


def ssd_reference(x, dt, a_log, b_mat, c_mat, d_skip, h0=None):
    """Naive per-step recurrence (oracle for tests)."""
    bs, s, nh, hd = x.shape
    ds = b_mat.shape[-1]
    h = h0 if h0 is not None else jnp.zeros((bs, nh, hd, ds), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,P),(B,H),(B,N),(B,N)
        a = jnp.exp(-jnp.exp(a_log)[None, :] * dtt)  # (B,H)
        h = h * a[..., None, None] + jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b_mat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c_mat.astype(jnp.float32), 1, 0),
    )
    h, ys = lax.scan(step, h.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1) + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h.astype(x.dtype)


def apply_mamba(cfg: ArchConfig, p, x, *, rules=cm.DEFAULT_RULES, return_cache: bool = False):
    """Training / prefill forward; x (B, S, d) -> (B, S, d) [, cache]."""
    d_inner, nh, ds = _dims(cfg)
    dt_ = cfg.cdtype
    z, xi, b, c, dtr = _project(cfg, p, x)
    conv_tail = jnp.concatenate([xi, b, c], -1)[:, -(_CONV_K - 1):, :]
    xi, b, c = _conv_all(cfg, p, xi, b, c)
    dt_pos = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"][None, None, :])
    xi = cm.constrain(xi, ("batch", "seq", "inner"), rules)
    y, h_fin = ssd_chunked(
        xi.reshape(*xi.shape[:2], nh, cfg.ssm_headdim),
        dt_pos, p["a_log"], b, c, p["d_skip"], chunk=cfg.ssm_chunk,
    )
    y = _gated_norm(p, y.reshape(*xi.shape[:2], d_inner), z)
    out = jnp.einsum("bse,ed->bsd", y.astype(dt_), p["w_out"].astype(dt_))
    if return_cache:
        return out, {"conv": conv_tail, "ssm": h_fin}
    return out


def mamba_cache_init(cfg: ArchConfig, batch: int, dtype):
    d_inner, nh, ds = _dims(cfg)
    conv_dim = d_inner + 2 * ds
    return {
        "conv": jnp.zeros((batch, _CONV_K - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, cfg.ssm_headdim, ds), dtype),
    }


def apply_mamba_decode(cfg: ArchConfig, p, x, cache, *, rules=cm.DEFAULT_RULES):
    """One-token step; x (B, 1, d); returns (y, new_cache)."""
    d_inner, nh, ds = _dims(cfg)
    dt_ = cfg.cdtype
    z, xi, b, c, dtr = _project(cfg, p, x)
    new_row = jnp.concatenate([xi, b, c], -1)  # (B, 1, conv_dim)
    win = jnp.concatenate([cache["conv"], new_row], axis=1)  # (B, K, conv_dim)
    w_full = jnp.concatenate([p["conv_wx"], p["conv_wbc"]], axis=0)
    out = jnp.einsum("bkc,ck->bc", win.astype(jnp.float32), w_full.astype(jnp.float32))
    act = jax.nn.silu(out + p["conv_b"].astype(jnp.float32))[:, None, :].astype(dt_)
    xi1, b1, c1 = act[..., :d_inner], act[..., d_inner:d_inner + ds], act[..., d_inner + ds:]
    dt_pos = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"][None, None, :])

    xt = xi1.reshape(-1, nh, cfg.ssm_headdim).astype(jnp.float32)
    a = jnp.exp(-jnp.exp(p["a_log"])[None, :] * dt_pos[:, 0])  # (B,H)
    h = cache["ssm"].astype(jnp.float32) * a[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt_pos[:, 0], b1[:, 0].astype(jnp.float32), xt
    )
    y = jnp.einsum("bn,bhpn->bhp", c1[:, 0].astype(jnp.float32), h)
    y = y + p["d_skip"][None, :, None] * xt
    y = _gated_norm(p, y.reshape(-1, 1, d_inner), z)
    out = jnp.einsum("bse,ed->bsd", y.astype(dt_), p["w_out"].astype(dt_))
    new_cache = {"conv": win[:, 1:, :], "ssm": h.astype(cache["ssm"].dtype)}
    return out, new_cache
