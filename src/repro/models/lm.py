"""Composable LM stack: groups of scanned layers covering every assigned arch.

A model is a sequence of *groups*; each group is (block_types, count) and is
executed as one ``lax.scan`` over ``count`` stacked parameter sets (remat
around the body when cfg.remat).  Non-uniform stacks compose groups:

  dense            [("attn",) x L]
  moe (granite)    [("attn_moe",) x L]
  llama4           [("attn", "attn_moe") x L/2]   (alternating, ff 2x on dense)
  rwkv6            [("rwkv",) x L]
  zamba2 (hybrid)  [("mamba" x 6, "shared_attn") x 13] + [("mamba",) x 3]
                   -- shared_attn params are NOT stacked (weight sharing);
                   its KV caches ARE stacked per invocation.
  seamless (encdec) enc: [("enc",) x 12]; dec: [("dec",) x 12]

Three entry points per model: ``loss_fn`` (train), ``prefill`` + ``decode_step``
(serve).  The loss is vocab-chunked: hidden states are scanned in sequence
chunks so the (B, S, vocab) logits tensor never materializes.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mamba2 as mb
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import ArchConfig


@dataclass(frozen=True)
class GroupSpec:
    block_types: tuple[str, ...]
    count: int
    # per-block-type overrides, e.g. {"attn": {"d_ff": 16384}}
    overrides: tuple[tuple[str, Any], ...] = ()

    def override(self, bt: str) -> dict:
        return dict(self.overrides).get(bt, {})


@dataclass(frozen=True)
class LMSpec:
    cfg: ArchConfig
    groups: tuple[GroupSpec, ...]
    enc_groups: tuple[GroupSpec, ...] = ()

    @property
    def is_encdec(self) -> bool:
        return bool(self.enc_groups)

    @property
    def has_shared_attn(self) -> bool:
        return any("shared_attn" in g.block_types for g in self.groups)


def build_spec(cfg: ArchConfig) -> LMSpec:
    if cfg.family == "encdec":
        return LMSpec(
            cfg=cfg,
            groups=(GroupSpec(("dec",), cfg.dec_layers),),
            enc_groups=(GroupSpec(("enc",), cfg.enc_layers),),
        )
    if cfg.family == "moe":
        if cfg.moe_layer_step == 2:
            # llama4-style: alternate dense (2x ff) and MoE layers
            return LMSpec(
                cfg=cfg,
                groups=(
                    GroupSpec(
                        ("attn", "attn_moe"),
                        cfg.n_layers // 2,
                        overrides=(("attn", {"d_ff": 2 * cfg.d_ff}),),
                    ),
                ),
            )
        return LMSpec(cfg=cfg, groups=(GroupSpec(("attn_moe",), cfg.n_layers),))
    if cfg.family == "ssm" and cfg.rwkv:
        return LMSpec(cfg=cfg, groups=(GroupSpec(("rwkv",), cfg.n_layers),))
    if cfg.family == "hybrid":
        k = cfg.attn_every
        full, rem = divmod(cfg.n_layers, k)
        groups = [GroupSpec(tuple(["mamba"] * k + ["shared_attn"]), full)]
        if rem:
            groups.append(GroupSpec(("mamba",), rem))
        return LMSpec(cfg=cfg, groups=tuple(groups))
    # dense / vlm
    return LMSpec(cfg=cfg, groups=(GroupSpec(("attn",), cfg.n_layers),))


# ---------------------------------------------------------------------------
# per-block init / axes / apply
# ---------------------------------------------------------------------------


def _shared_attn_cfg(cfg: ArchConfig) -> ArchConfig:
    """Zamba's shared block attends over concat(h, emb0): d_in = 2*d_model."""
    hd = 2 * cfg.d_model // cfg.n_heads
    return cfg.replace(head_dim=hd, qk_norm=False, qkv_bias=False)


def init_block(cfg: ArchConfig, bt: str, key, ov: dict):
    ninit, _ = cm.make_norm(cfg, cfg.d_model)
    ks = jax.random.split(key, 4)
    if bt == "attn":
        return {
            "ln1": ninit(ks[0]),
            "attn": attn.init_attention(cfg, ks[1]),
            "ln2": ninit(ks[2]),
            "mlp": mlp_mod.init_mlp(cfg, ks[3], d_ff=ov.get("d_ff")),
        }
    if bt == "attn_moe":
        return {
            "ln1": ninit(ks[0]),
            "attn": attn.init_attention(cfg, ks[1]),
            "ln2": ninit(ks[2]),
            "moe": moe_mod.init_moe(cfg, ks[3]),
        }
    if bt == "mamba":
        return {"ln": ninit(ks[0]), "mamba": mb.init_mamba(cfg, ks[1])}
    if bt == "rwkv":
        return {"ln1": ninit(ks[0]), "ln2": ninit(ks[1]), "rwkv": rwkv_mod.init_rwkv(cfg, ks[2])}
    if bt == "enc":
        return {
            "ln1": ninit(ks[0]),
            "attn": attn.init_attention(cfg, ks[1]),
            "ln2": ninit(ks[2]),
            "mlp": mlp_mod.init_mlp(cfg, ks[3]),
        }
    if bt == "dec":
        ks = jax.random.split(key, 6)
        return {
            "ln1": ninit(ks[0]),
            "attn": attn.init_attention(cfg, ks[1]),
            "lnx": ninit(ks[2]),
            "xattn": attn.init_attention(cfg, ks[3]),
            "ln2": ninit(ks[4]),
            "mlp": mlp_mod.init_mlp(cfg, ks[5]),
        }
    raise ValueError(f"unknown block type {bt!r}")


def block_axes(cfg: ArchConfig, bt: str):
    nx = cm.norm_axes(cfg)
    if bt == "attn" or bt == "enc":
        return {"ln1": nx, "attn": attn.attention_axes(cfg), "ln2": nx, "mlp": mlp_mod.mlp_axes(cfg)}
    if bt == "attn_moe":
        return {"ln1": nx, "attn": attn.attention_axes(cfg), "ln2": nx, "moe": moe_mod.moe_axes(cfg)}
    if bt == "mamba":
        return {"ln": nx, "mamba": mb.mamba_axes(cfg)}
    if bt == "rwkv":
        return {"ln1": nx, "ln2": nx, "rwkv": rwkv_mod.rwkv_axes(cfg)}
    if bt == "dec":
        return {
            "ln1": nx,
            "attn": attn.attention_axes(cfg),
            "lnx": nx,
            "xattn": attn.attention_axes(cfg),
            "ln2": nx,
            "mlp": mlp_mod.mlp_axes(cfg),
        }
    raise ValueError(bt)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_params(spec: LMSpec, key) -> dict:
    cfg = spec.cfg
    keys = jax.random.split(key, 8)
    ninit, _ = cm.make_norm(cfg, cfg.d_model)
    params: dict[str, Any] = {
        "embed": cm.embed_init(keys[0], (cfg.vocab_padded, cfg.d_model), cfg.pdtype),
        "final_norm": ninit(keys[1]),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(keys[2], (cfg.d_model, cfg.vocab_padded), cfg.pdtype)

    def group_params(groups, key):
        gps = []
        for gi, g in enumerate(groups):
            gk = jax.random.split(jax.random.fold_in(key, gi), len(g.block_types))
            gp = {}
            for bi, bt in enumerate(g.block_types):
                if bt == "shared_attn":
                    continue  # shared; initialized once below
                gp[str(bi)] = cm.stack_init(
                    lambda k, bt=bt, ov=g.override(bt): init_block(cfg, bt, k, ov),
                    gk[bi],
                    g.count,
                )
            gps.append(gp)
        return gps

    params["groups"] = group_params(spec.groups, keys[3])
    if spec.enc_groups:
        params["enc_groups"] = group_params(spec.enc_groups, keys[4])
        params["enc_final_norm"] = ninit(keys[5])
    if spec.has_shared_attn:
        scfg = _shared_attn_cfg(cfg)
        sn, _ = cm.make_norm(cfg, 2 * cfg.d_model)
        sk = jax.random.split(keys[7], 3)
        params["shared_attn"] = {
            "ln": sn(keys[6]),
            "attn": attn.init_attention(scfg, sk[0], d_in=2 * cfg.d_model),
            "ln2": ninit(sk[1]),
            "mlp": mlp_mod.init_mlp(cfg, sk[2]),  # zamba's shared-block FFN (d_ff)
        }
    return params


def param_axes(spec: LMSpec) -> dict:
    cfg = spec.cfg
    nx = cm.norm_axes(cfg)
    axes: dict[str, Any] = {"embed": ("vocab", "embed_d"), "final_norm": nx}
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed_d", "vocab")

    def group_axes(groups):
        gax = []
        for g in groups:
            gp = {}
            for bi, bt in enumerate(g.block_types):
                if bt == "shared_attn":
                    continue
                gp[str(bi)] = cm.stacked_axes(block_axes(cfg, bt))
            gax.append(gp)
        return gax

    axes["groups"] = group_axes(spec.groups)
    if spec.enc_groups:
        axes["enc_groups"] = group_axes(spec.enc_groups)
        axes["enc_final_norm"] = nx
    if spec.has_shared_attn:
        scfg = _shared_attn_cfg(cfg)
        axes["shared_attn"] = {
            "ln": nx,
            "attn": attn.attention_axes(scfg),
            "ln2": nx,
            "mlp": mlp_mod.mlp_axes(cfg),
        }
    return axes


def param_specs(spec: LMSpec, rules) -> dict:
    return cm.tree_specs(param_axes(spec), rules)


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# forward blocks (train path: full sequence, no cache)
# ---------------------------------------------------------------------------


def _apply_block_train(cfg, spec, bt, bp, h, aux, *, rules, shared=None, emb0=None, enc_out=None, ov=None):
    _, napply = cm.make_norm(cfg, cfg.d_model)
    if bt == "attn" or bt == "enc":
        causal = bt == "attn"
        h = h + attn.attend_train(cfg, bp["attn"], napply(bp["ln1"], h), causal=causal, rules=rules)
        h = h + mlp_mod.apply_mlp(cfg, bp["mlp"], napply(bp["ln2"], h), rules=rules)
        return h, aux
    if bt == "attn_moe":
        h = h + attn.attend_train(cfg, bp["attn"], napply(bp["ln1"], h), rules=rules)
        y, a = moe_mod.apply_moe(cfg, bp["moe"], napply(bp["ln2"], h), rules=rules)
        h = h + y
        aux = {k: aux.get(k, 0.0) + a[k] for k in a}
        return h, aux
    if bt == "mamba":
        h = h + mb.apply_mamba(cfg, bp["mamba"], napply(bp["ln"], h), rules=rules)
        return h, aux
    if bt == "rwkv":
        h = h + rwkv_mod.apply_rwkv_timemix(cfg, bp["rwkv"], napply(bp["ln1"], h), rules=rules)
        h = h + rwkv_mod.apply_rwkv_channelmix(cfg, bp["rwkv"], napply(bp["ln2"], h), rules=rules)
        return h, aux
    if bt == "shared_attn":
        scfg = _shared_attn_cfg(cfg)
        _, napply2 = cm.make_norm(cfg, 2 * cfg.d_model)
        zin = jnp.concatenate([h, emb0], axis=-1)
        zin = napply2(shared["ln"], zin)
        h = h + attn.attend_train(scfg, shared["attn"], zin, rules=rules)
        h = h + mlp_mod.apply_mlp(cfg, shared["mlp"], napply(shared["ln2"], h), rules=rules)
        return h, aux
    if bt == "dec":
        h = h + attn.attend_train(cfg, bp["attn"], napply(bp["ln1"], h), rules=rules)
        kv = attn.project_kv(cfg, bp["xattn"], enc_out)
        h = h + attn.attend_train(
            cfg, bp["xattn"], napply(bp["lnx"], h), causal=False, rules=rules, kv_override=kv
        )
        h = h + mlp_mod.apply_mlp(cfg, bp["mlp"], napply(bp["ln2"], h), rules=rules)
        return h, aux
    raise ValueError(bt)


def _run_groups_train(spec: LMSpec, params, groups_key, groups, h, *, rules, emb0=None, enc_out=None):
    cfg = spec.cfg
    aux0 = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}
    shared = params.get("shared_attn")

    for gi, g in enumerate(groups):
        gp = params[groups_key][gi]

        def body(carry, xs, g=g):
            h, aux = carry
            for bi, bt in enumerate(g.block_types):
                bp = xs.get(str(bi)) if bt != "shared_attn" else None
                h, aux = _apply_block_train(
                    cfg, spec, bt, bp, h, aux,
                    rules=rules, shared=shared, emb0=emb0, enc_out=enc_out,
                    ov=g.override(bt),
                )
            h = cm.constrain(h, ("batch", "seq", "embed"), rules)
            return (h, aux), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (h, aux), _ = lax.scan(body_fn, (h, aux0), gp, length=g.count)
        aux0 = aux
    return h, aux0


# ---------------------------------------------------------------------------
# loss (vocab-chunked)
# ---------------------------------------------------------------------------


def _embed_tokens(cfg: ArchConfig, params, tokens, rules):
    h = params["embed"][tokens].astype(cfg.cdtype)
    return cm.constrain(h, ("batch", "seq", "embed"), rules)


def _unembed(cfg, params, h):
    """Logits over the padded vocab; padding columns masked to -inf."""
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(cfg.cdtype))
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def _chunked_xent(cfg: ArchConfig, params, h, labels, rules):
    """Cross-entropy without materializing (B, S, vocab) logits."""
    b, s, d = h.shape
    ck = min(cfg.vocab_chunk, s)
    while s % ck:
        ck //= 2
    nc = s // ck
    hc = jnp.moveaxis(h.reshape(b, nc, ck, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, ck), 1, 0)

    def chunk_loss(carry, inp):
        hh, ll = inp
        logits = _unembed(cfg, params, hh).astype(jnp.float32)
        # batch_inner: the batch axes that never collide with "vocab" (under
        # full-flat FSDP the batch owns both mesh axes; the loss chunk cedes
        # one back so logits/grad partials stay vocab-sharded)
        logits = cm.constrain(logits, ("batch_inner", "seq", "vocab"), rules)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    fn = jax.checkpoint(chunk_loss) if cfg.remat else chunk_loss
    total, _ = lax.scan(fn, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (b * s)


def loss_fn(spec: LMSpec, params, batch, *, rules=cm.DEFAULT_RULES):
    """batch: tokens (B,S) int32, labels (B,S) int32 [+ frames (B,S,d)]."""
    cfg = spec.cfg
    if spec.is_encdec:
        frames = batch["frames"].astype(cfg.cdtype)
        frames = cm.constrain(frames, ("batch", "seq", "embed"), rules)
        _, napply = cm.make_norm(cfg, cfg.d_model)
        enc, _ = _run_groups_train(spec, params, "enc_groups", spec.enc_groups, frames, rules=rules)
        enc = napply(params["enc_final_norm"], enc)
        h = _embed_tokens(cfg, params, batch["tokens"], rules)
        h, aux = _run_groups_train(spec, params, "groups", spec.groups, h, rules=rules, enc_out=enc)
    else:
        h = _embed_tokens(cfg, params, batch["tokens"], rules)
        emb0 = h if spec.has_shared_attn else None
        h, aux = _run_groups_train(spec, params, "groups", spec.groups, h, rules=rules, emb0=emb0)
    _, napply = cm.make_norm(cfg, cfg.d_model)
    h = napply(params["final_norm"], h)
    xent = _chunked_xent(cfg, params, h, batch["labels"], rules)
    loss = xent + 0.01 * aux["lb_loss"] + 0.001 * aux["z_loss"]
    return loss, {"xent": xent, **aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode (cache pytrees stacked per group)
# ---------------------------------------------------------------------------


def init_cache(spec: LMSpec, batch: int, s_max: int, *, enc_len: int = 0) -> dict:
    """Decode caches, stacked (count, ...) per group."""
    cfg = spec.cfg
    nkv, hd = cfg.n_kv_heads, cfg.hd
    dt = cfg.cdtype
    caches = []
    for g in spec.groups:
        gc: dict[str, Any] = {}
        for bi, bt in enumerate(g.block_types):
            if bt in ("attn", "attn_moe", "dec"):
                gc[str(bi)] = {
                    "k": jnp.zeros((g.count, batch, s_max, nkv, hd), dt),
                    "v": jnp.zeros((g.count, batch, s_max, nkv, hd), dt),
                }
                if bt == "dec":
                    gc[str(bi)]["xk"] = jnp.zeros((g.count, batch, enc_len, nkv, hd), dt)
                    gc[str(bi)]["xv"] = jnp.zeros((g.count, batch, enc_len, nkv, hd), dt)
            elif bt == "mamba":
                one = mb.mamba_cache_init(cfg, batch, dt)
                gc[str(bi)] = jax.tree.map(lambda x: jnp.broadcast_to(x, (g.count,) + x.shape), one)
            elif bt == "rwkv":
                one = rwkv_mod.rwkv_cache_init(cfg, batch, dt)
                gc[str(bi)] = jax.tree.map(lambda x: jnp.broadcast_to(x, (g.count,) + x.shape), one)
            elif bt == "shared_attn":
                scfg = _shared_attn_cfg(cfg)
                gc[str(bi)] = {
                    "k": jnp.zeros((g.count, batch, s_max, scfg.n_kv_heads, scfg.hd), dt),
                    "v": jnp.zeros((g.count, batch, s_max, scfg.n_kv_heads, scfg.hd), dt),
                }
        caches.append(gc)
    return {"groups": caches, "pos": jnp.zeros((), jnp.int32)}


def cache_axes(spec: LMSpec) -> dict:
    """Logical axes for cache sharding (kv_seq over 'model' = flash-decode)."""
    caches = []
    for g in spec.groups:
        gc: dict[str, Any] = {}
        for bi, bt in enumerate(g.block_types):
            if bt in ("attn", "attn_moe", "dec", "shared_attn"):
                e = {
                    "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                    "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                }
                if bt == "dec":
                    e["xk"] = ("layers", "batch", None, "kv_heads", "head_dim")
                    e["xv"] = ("layers", "batch", None, "kv_heads", "head_dim")
                gc[str(bi)] = e
            elif bt == "mamba":
                gc[str(bi)] = {
                    "conv": ("layers", "batch", None, "inner"),
                    "ssm": ("layers", "batch", "inner", None, None),
                }
            elif bt == "rwkv":
                gc[str(bi)] = {
                    "tm_prev": ("layers", "batch", None, "embed"),
                    "cm_prev": ("layers", "batch", None, "embed"),
                    "wkv": ("layers", "batch", "inner", None, None),
                }
        caches.append(gc)
    return {"groups": caches, "pos": ()}


def _write_prefill_kv(cache_kv, kv, s_max):
    """Place prefill (k, v) of length S into the S_max cache buffers."""
    k, v = kv
    pad = [(0, 0), (0, s_max - k.shape[1]), (0, 0), (0, 0)]
    return jnp.pad(k, pad), jnp.pad(v, pad)


def prefill(spec: LMSpec, params, batch, s_max: int, *, rules=cm.DEFAULT_RULES):
    """Run the prompt, return (last-position logits, cache)."""
    cfg = spec.cfg
    _, napply = cm.make_norm(cfg, cfg.d_model)
    enc_out = None
    if spec.is_encdec:
        frames = batch["frames"].astype(cfg.cdtype)
        enc_out, _ = _run_groups_train(spec, params, "enc_groups", spec.enc_groups, frames, rules=rules)
        enc_out = napply(params["enc_final_norm"], enc_out)
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = _embed_tokens(cfg, params, tokens, rules)
    emb0 = h if spec.has_shared_attn else None
    shared = params.get("shared_attn")

    caches = []
    for gi, g in enumerate(spec.groups):
        gp = params["groups"][gi]

        def body(carry, xs, g=g):
            h = carry
            gc = {}
            for bi, bt in enumerate(g.block_types):
                bp = xs.get(str(bi)) if bt != "shared_attn" else None
                h, c = _apply_block_prefill(
                    cfg, spec, bt, bp, h, s_max,
                    rules=rules, shared=shared, emb0=emb0, enc_out=enc_out,
                )
                if c is not None:
                    gc[str(bi)] = c
            h = cm.constrain(h, ("batch", "seq", "embed"), rules)
            return h, gc

        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, gc = lax.scan(body_fn, h, gp, length=g.count)
        caches.append(gc)

    h = napply(params["final_norm"], h)
    logits = _unembed(cfg, params, h[:, -1:, :])
    cache = {"groups": caches, "pos": jnp.asarray(s, jnp.int32)}
    if spec.is_encdec:
        cache["enc_out"] = enc_out
    return logits[:, 0], cache


def _apply_block_prefill(cfg, spec, bt, bp, h, s_max, *, rules, shared, emb0, enc_out):
    _, napply = cm.make_norm(cfg, cfg.d_model)
    if bt in ("attn", "attn_moe"):
        y, kv = attn.attend_prefill(cfg, bp["attn"], napply(bp["ln1"], h), rules=rules)
        h = h + y
        if bt == "attn":
            h = h + mlp_mod.apply_mlp(cfg, bp["mlp"], napply(bp["ln2"], h), rules=rules)
        else:
            y2, _ = moe_mod.apply_moe(cfg, bp["moe"], napply(bp["ln2"], h), rules=rules)
            h = h + y2
        k, v = _write_prefill_kv(None, kv, s_max)
        return h, {"k": k, "v": v}
    if bt == "mamba":
        x = napply(bp["ln"], h)
        y, c = _mamba_prefill(cfg, bp["mamba"], x, rules=rules)
        return h + y, c
    if bt == "rwkv":
        x1 = napply(bp["ln1"], h)
        y1, tm_prev, wkv = _rwkv_tm_prefill(cfg, bp["rwkv"], x1, rules=rules)
        h = h + y1
        x2 = napply(bp["ln2"], h)
        y2 = rwkv_mod.apply_rwkv_channelmix(cfg, bp["rwkv"], x2, rules=rules)
        h = h + y2
        return h, {"tm_prev": tm_prev, "cm_prev": x2[:, -1:, :], "wkv": wkv}
    if bt == "shared_attn":
        scfg = _shared_attn_cfg(cfg)
        _, napply2 = cm.make_norm(cfg, 2 * cfg.d_model)
        zin = napply2(shared["ln"], jnp.concatenate([h, emb0], axis=-1))
        y, kv = attn.attend_prefill(scfg, shared["attn"], zin, rules=rules)
        h = h + y
        h = h + mlp_mod.apply_mlp(cfg, shared["mlp"], napply(shared["ln2"], h), rules=rules)
        k, v = _write_prefill_kv(None, kv, s_max)
        return h, {"k": k, "v": v}
    if bt == "dec":
        y, kv = attn.attend_prefill(cfg, bp["attn"], napply(bp["ln1"], h), rules=rules)
        h = h + y
        xk, xv = attn.project_kv(cfg, bp["xattn"], enc_out)
        h = h + attn.attend_train(
            cfg, bp["xattn"], napply(bp["lnx"], h), causal=False, rules=rules, kv_override=(xk, xv)
        )
        h = h + mlp_mod.apply_mlp(cfg, bp["mlp"], napply(bp["ln2"], h), rules=rules)
        k, v = _write_prefill_kv(None, kv, s_max)
        return h, {"k": k, "v": v, "xk": xk, "xv": xv}
    raise ValueError(bt)


def _mamba_prefill(cfg, p, x, *, rules):
    """apply_mamba that also returns the decode cache (conv tail + state)."""
    return mb.apply_mamba(cfg, p, x, rules=rules, return_cache=True)


def _rwkv_tm_prefill(cfg, p, x, *, rules):
    shifted = rwkv_mod._token_shift(x)
    r, k, v, g, lw = rwkv_mod._time_mix_inputs(cfg, p, x, shifted)
    y, s_fin = rwkv_mod.wkv_chunked(r, k, v, lw, p["u_bonus"], chunk=cfg.ssm_chunk)
    y = rwkv_mod._group_norm(p, y) * g
    out = jnp.einsum("bsd,de->bse", y.astype(cfg.cdtype), p["wo"].astype(cfg.cdtype))
    return out, x[:, -1:, :], s_fin


def decode_step(spec: LMSpec, params, token, cache, *, rules=cm.DEFAULT_RULES):
    """One greedy decode step.  token (B,) int32 -> (logits (B,V), cache)."""
    cfg = spec.cfg
    _, napply = cm.make_norm(cfg, cfg.d_model)
    pos = cache["pos"]
    h = _embed_tokens(cfg, params, token[:, None], rules)
    emb0 = h if spec.has_shared_attn else None
    shared = params.get("shared_attn")
    enc_out = cache.get("enc_out")

    new_groups = []
    for gi, g in enumerate(spec.groups):
        gp = params["groups"][gi]
        gc = cache["groups"][gi]

        def body(carry, xs, g=g):
            h = carry
            bp_all, c_all = xs
            c_new = {}
            for bi, bt in enumerate(g.block_types):
                bp = bp_all.get(str(bi)) if bt != "shared_attn" else None
                c = c_all.get(str(bi))
                h, cn = _apply_block_decode(
                    cfg, spec, bt, bp, h, c, pos,
                    rules=rules, shared=shared, emb0=emb0, enc_out=enc_out,
                )
                if cn is not None:
                    c_new[str(bi)] = cn
            return h, c_new

        h, gc_new = lax.scan(body, h, (gp, gc), length=g.count)
        new_groups.append(gc_new)

    h = napply(params["final_norm"], h)
    logits = _unembed(cfg, params, h)[:, 0]
    new_cache = {"groups": new_groups, "pos": pos + 1}
    if spec.is_encdec:
        new_cache["enc_out"] = enc_out
    return logits, new_cache


def _apply_block_decode(cfg, spec, bt, bp, h, c, pos, *, rules, shared, emb0, enc_out):
    _, napply = cm.make_norm(cfg, cfg.d_model)
    if bt in ("attn", "attn_moe"):
        y, (k, v) = attn.attend_decode(cfg, bp["attn"], napply(bp["ln1"], h), (c["k"], c["v"]), pos, rules=rules)
        h = h + y
        if bt == "attn":
            h = h + mlp_mod.apply_mlp(cfg, bp["mlp"], napply(bp["ln2"], h), rules=rules)
        else:
            y2, _ = moe_mod.apply_moe(cfg, bp["moe"], napply(bp["ln2"], h), rules=rules)
            h = h + y2
        return h, {"k": k, "v": v}
    if bt == "mamba":
        y, cn = mb.apply_mamba_decode(cfg, bp["mamba"], napply(bp["ln"], h), c, rules=rules)
        return h + y, cn
    if bt == "rwkv":
        x1 = napply(bp["ln1"], h)
        y1, cn = rwkv_mod.apply_rwkv_timemix_decode(cfg, bp["rwkv"], x1, c, rules=rules)
        h = h + y1
        x2 = napply(bp["ln2"], h)
        y2, cn = rwkv_mod.apply_rwkv_channelmix_decode(cfg, bp["rwkv"], x2, cn, rules=rules)
        h = h + y2
        return h, cn
    if bt == "shared_attn":
        scfg = _shared_attn_cfg(cfg)
        _, napply2 = cm.make_norm(cfg, 2 * cfg.d_model)
        zin = napply2(shared["ln"], jnp.concatenate([h, emb0], axis=-1))
        y, (k, v) = attn.attend_decode(scfg, shared["attn"], zin, (c["k"], c["v"]), pos, rules=rules)
        h = h + y
        h = h + mlp_mod.apply_mlp(cfg, shared["mlp"], napply(shared["ln2"], h), rules=rules)
        return h, {"k": k, "v": v}
    if bt == "dec":
        y, (k, v) = attn.attend_decode(cfg, bp["attn"], napply(bp["ln1"], h), (c["k"], c["v"]), pos, rules=rules)
        h = h + y
        h = h + attn.cross_attend_decode(cfg, bp["xattn"], napply(bp["lnx"], h), (c["xk"], c["xv"]), pos, rules=rules)
        h = h + mlp_mod.apply_mlp(cfg, bp["mlp"], napply(bp["ln2"], h), rules=rules)
        return h, {"k": k, "v": v, "xk": c["xk"], "xv": c["xv"]}
    raise ValueError(bt)
