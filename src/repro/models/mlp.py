"""SwiGLU MLP (the dense FFN used by every assigned transformer)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ArchConfig


def init_mlp(cfg: ArchConfig, key, *, d_ff: int | None = None):
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": cm.dense_init(ks[0], (cfg.d_model, f), cfg.pdtype),
        "w_up": cm.dense_init(ks[1], (cfg.d_model, f), cfg.pdtype),
        "w_down": cm.dense_init(ks[2], (f, cfg.d_model), cfg.pdtype),
    }


def mlp_axes(cfg: ArchConfig):
    return {
        "w_gate": ("embed_p", "ff"),
        "w_up": ("embed_p", "ff"),
        "w_down": ("ff", "embed_p"),
    }


def apply_mlp(cfg: ArchConfig, p, x, *, rules=cm.DEFAULT_RULES):
    dt = cfg.cdtype
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    h = cm.constrain(h, ("batch", "seq", "ff"), rules)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
