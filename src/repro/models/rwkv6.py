"""RWKV6 ("Finch") layer: data-dependent decay, token shift, chunked WKV.

Time-mix per head (dk = dv = head_dim), with per-channel decay w_t computed
from the token via a LoRA bottleneck (the Finch contribution):

    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)

Chunked evaluation: within a chunk the pairwise decay ratio
exp(lw_{t-1} - lw_i) (lw = cumulative log decay) turns the recurrence into two
masked matmuls plus a carried (dk, dv) state per head -- O(S*C) MXU work.

``wkv_reference`` is the per-step scan oracle used by tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as cm
from repro.models.common import ArchConfig

_LORA_R = 64


def _dims(cfg: ArchConfig):
    hd = cfg.rwkv_head_dim
    nh = cfg.d_model // hd
    return nh, hd


def init_rwkv(cfg: ArchConfig, key):
    d = cfg.d_model
    nh, hd = _dims(cfg)
    ks = jax.random.split(key, 12)
    p = {
        # token-shift mix coefficients for r, k, v, w, g
        "mix": 0.5 * jnp.ones((5, d), cfg.pdtype),
        "wr": cm.dense_init(ks[0], (d, d), cfg.pdtype),
        "wk": cm.dense_init(ks[1], (d, d), cfg.pdtype),
        "wv": cm.dense_init(ks[2], (d, d), cfg.pdtype),
        "wg": cm.dense_init(ks[3], (d, d), cfg.pdtype),
        "wo": cm.dense_init(ks[4], (d, d), cfg.pdtype),
        # data-dependent decay LoRA: w = base + B(tanh(A x))
        "w_base": -6.0 * jnp.ones((d,), jnp.float32),
        "w_lora_a": cm.dense_init(ks[5], (d, _LORA_R), jnp.float32),
        "w_lora_b": (0.01 * jax.random.normal(ks[6], (_LORA_R, d), jnp.float32)),
        "u_bonus": (0.1 * jax.random.normal(ks[7], (nh, hd), jnp.float32)),
        "ln_x": jnp.ones((d,), cfg.pdtype),
        # channel-mix
        "cm_mix": 0.5 * jnp.ones((2, d), cfg.pdtype),
        "cm_k": cm.dense_init(ks[8], (d, cfg.d_ff), cfg.pdtype),
        "cm_v": cm.dense_init(ks[9], (cfg.d_ff, d), cfg.pdtype),
        "cm_r": cm.dense_init(ks[10], (d, d), cfg.pdtype),
    }
    return p


def rwkv_axes(cfg: ArchConfig):
    return {
        "mix": (None, "embed_p"),
        "wr": ("embed_p", "inner"),
        "wk": ("embed_p", "inner"),
        "wv": ("embed_p", "inner"),
        "wg": ("embed_p", "inner"),
        "wo": ("inner", "embed_p"),
        "w_base": ("inner",),
        "w_lora_a": ("embed_p", None),
        "w_lora_b": (None, "inner"),
        "u_bonus": (None, None),
        "ln_x": ("inner",),
        "cm_mix": (None, "embed_p"),
        "cm_k": ("embed_p", "ff"),
        "cm_v": ("ff", "embed_p"),
        "cm_r": ("embed_p", "inner"),
    }


def _token_shift(x, prev=None):
    """Shift right by one along S; ``prev`` (B,1,d) feeds position 0."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv_chunked(r, k, v, lw, u, *, chunk: int, s0=None):
    """Chunked WKV.  r/k (B,S,H,K), v (B,S,H,V), lw (B,S,H,K) log-decay <= 0.

    Returns (y (B,S,H,V), s_final (B,H,K,V)).
    """
    b, s, nh, dk = r.shape
    dv = v.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    rc = r.reshape(b, nc, q, nh, dk).astype(jnp.float32)
    kc = k.reshape(b, nc, q, nh, dk).astype(jnp.float32)
    vc = v.reshape(b, nc, q, nh, dv).astype(jnp.float32)
    lwc = lw.reshape(b, nc, q, nh, dk)

    cum = jnp.cumsum(lwc, axis=2)  # inclusive cumulative log decay
    # intra-chunk: A[t,i] = sum_K r_t * exp(cum_{t-1} - cum_i) * k_i  (i < t)
    cum_tm1 = cum - lwc  # exclusive cumsum (cum_{t-1})
    r_dec = rc * jnp.exp(cum_tm1)  # r_t (x) prod_{j<t} w_j
    # clamp the positive exponent: with strong decay exp(-cum) overflows for
    # late chunk positions; valid (i < t) pairs always combine to <= 1, and
    # masked pairs are zeroed below -- the clamp only keeps them finite so
    # the where() gradient is not 0 * inf = NaN.
    k_dec = kc * jnp.exp(jnp.minimum(-cum, 40.0))
    scores = jnp.einsum("bcthk,bcihk->bcthi", r_dec, k_dec)
    mask = jnp.tril(jnp.ones((q, q), bool), k=-1)  # strictly lower
    scores = jnp.where(mask[None, None, :, None, :], scores, 0.0)
    bonus = jnp.einsum("bcthk,hk,bcthk->bcth", rc, u.astype(jnp.float32), kc)
    y_intra = jnp.einsum("bcthi,bcihv->bcthv", scores, vc) + bonus[..., None] * vc

    # chunk state contribution: S_c = sum_i diag(W_Q / W_i) k_i (x) v_i
    tail = jnp.exp(cum[:, :, -1:, :, :] - cum)  # (b,nc,q,h,k)
    s_chunk = jnp.einsum("bcihk,bcihk,bcihv->bchkv", tail, kc, vc)
    chunk_decay = jnp.exp(cum[:, :, -1])  # (b,nc,h,k)

    def carry(sprev, inp):
        s_c, dec = inp
        return sprev * dec[..., None] + s_c, sprev

    s_init = (
        s0.astype(jnp.float32) if s0 is not None else jnp.zeros((b, nh, dk, dv), jnp.float32)
    )
    s_fin, s_prevs = lax.scan(
        carry, s_init, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)  # (b,nc,h,k,v)
    y_inter = jnp.einsum("bcthk,bchkv->bcthv", r_dec, s_prevs)
    y = (y_intra + y_inter).reshape(b, s, nh, dv)
    return y.astype(r.dtype), s_fin


def wkv_reference(r, k, v, lw, u, s0=None):
    """Naive per-step recurrence (oracle)."""
    b, s, nh, dk = r.shape
    dv = v.shape[-1]
    st = s0.astype(jnp.float32) if s0 is not None else jnp.zeros((b, nh, dk, dv), jnp.float32)

    def step(st, inp):
        rt, kt, vt, lwt = (x.astype(jnp.float32) for x in inp)
        y = jnp.einsum("bhk,bhkv->bhv", rt, st) + jnp.einsum(
            "bhk,hk,bhk,bhv->bhv", rt, u.astype(jnp.float32), kt, vt
        )
        st = st * jnp.exp(lwt)[..., None] + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return st, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, lw))
    st, ys = lax.scan(step, st, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), st


def _time_mix_inputs(cfg: ArchConfig, p, x, shifted):
    """Return (r, k, v, g, lw) projections, each (B,S,...)."""
    nh, hd = _dims(cfg)
    dt = cfg.cdtype
    mix = p["mix"].astype(dt)
    xr = x * mix[0] + shifted * (1 - mix[0])
    xk = x * mix[1] + shifted * (1 - mix[1])
    xv = x * mix[2] + shifted * (1 - mix[2])
    xw = x * mix[3] + shifted * (1 - mix[3])
    xg = x * mix[4] + shifted * (1 - mix[4])
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt))
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(dt))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(dt)).astype(jnp.float32))
    # data-dependent decay (Finch): w = base + B tanh(A xw); lw = -exp(w)
    lora = jnp.einsum(
        "bsr,re->bse",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", xw.astype(jnp.float32), p["w_lora_a"])),
        p["w_lora_b"],
    )
    lw = -jnp.exp(p["w_base"][None, None, :] + lora)  # (B,S,d) log decay < 0
    b, s, d = x.shape
    return (
        r.reshape(b, s, nh, hd),
        k.reshape(b, s, nh, hd),
        v.reshape(b, s, nh, hd),
        g,
        lw.reshape(b, s, nh, hd),
    )


def _group_norm(p, y):
    """Per-head group norm on the WKV output (B,S,H,V) -> (B,S,d)."""
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yf = (yf - mu) * lax.rsqrt(var + 1e-5)
    b, s = y.shape[:2]
    return yf.reshape(b, s, -1) * p["ln_x"].astype(jnp.float32)


def apply_rwkv_timemix(cfg: ArchConfig, p, x, *, rules=cm.DEFAULT_RULES):
    shifted = _token_shift(x)
    r, k, v, g, lw = _time_mix_inputs(cfg, p, x, shifted)
    y, _ = wkv_chunked(r, k, v, lw, p["u_bonus"], chunk=cfg.ssm_chunk)
    y = _group_norm(p, y) * g
    return jnp.einsum("bsd,de->bse", y.astype(cfg.cdtype), p["wo"].astype(cfg.cdtype))


def apply_rwkv_channelmix(cfg: ArchConfig, p, x, *, rules=cm.DEFAULT_RULES):
    dt = cfg.cdtype
    shifted = _token_shift(x)
    mix = p["cm_mix"].astype(dt)
    xk = x * mix[0] + shifted * (1 - mix[0])
    xr = x * mix[1] + shifted * (1 - mix[1])
    k = jnp.einsum("bsd,df->bsf", xk, p["cm_k"].astype(dt))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(dt)
    k = cm.constrain(k, ("batch", "seq", "ff"), rules)
    kv = jnp.einsum("bsf,fd->bsd", k, p["cm_v"].astype(dt))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm_r"].astype(dt)).astype(jnp.float32))
    return (r * kv.astype(jnp.float32)).astype(dt)


def rwkv_cache_init(cfg: ArchConfig, batch: int, dtype):
    nh, hd = _dims(cfg)
    return {
        "tm_prev": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "cm_prev": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, nh, hd, hd), jnp.float32),
    }


def apply_rwkv_timemix_decode(cfg: ArchConfig, p, x, cache, *, rules=cm.DEFAULT_RULES):
    """One-token time-mix; x is the *normed* layer input (B, 1, d)."""
    r, k, v, g, lw = _time_mix_inputs(cfg, p, x, cache["tm_prev"])
    y, s_new = wkv_reference(r, k, v, lw, p["u_bonus"], s0=cache["wkv"])
    y = _group_norm(p, y) * g
    out = jnp.einsum("bsd,de->bse", y.astype(cfg.cdtype), p["wo"].astype(cfg.cdtype))
    return out, {**cache, "tm_prev": x, "wkv": s_new}


def apply_rwkv_channelmix_decode(cfg: ArchConfig, p, x, cache, *, rules=cm.DEFAULT_RULES):
    """One-token channel-mix; x is the *normed* sublayer input (B, 1, d)."""
    dt = cfg.cdtype
    mix = p["cm_mix"].astype(dt)
    prev = cache["cm_prev"]
    xk = x * mix[0] + prev * (1 - mix[0])
    xr = x * mix[1] + prev * (1 - mix[1])
    kk = jnp.einsum("bsd,df->bsf", xk, p["cm_k"].astype(dt))
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(dt)
    kv = jnp.einsum("bsf,fd->bsd", kk, p["cm_v"].astype(dt))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm_r"].astype(dt)).astype(jnp.float32))
    out = (rr * kv.astype(jnp.float32)).astype(dt)
    return out, {**cache, "cm_prev": x}
