"""Structured run reports: versioned JSON telemetry for a sequence run.

``build_run_report`` turns a finished :class:`~repro.core.sequence.SequenceResult`
plus the process metrics registry into one versioned JSON document -- the
per-transition phase breakdown (ingest/chain/solve/score), bytes
read/decoded/H2D/saved, solver iterations/residual series/convergence flags,
program-cache hit rates, prefetch efficiency, and the streamed-solve roofline
fraction.  What used to exist only as ``caddelag_run.py`` print lines is now
a diffable artifact: ``caddelag-run --run-report out.json``.

The document is self-describing (``kind`` + ``schema``); consumers must
reject unknown kinds and newer majors.  ``validate_run_report`` /
``validate_chrome_trace`` are dependency-free structural validators (no
jsonschema package in this environment) used by tests and the CI smoke:

    python -m repro.obs.report report.json trace.json

validates any mix of run reports and Chrome traces, exiting nonzero with a
list of problems on failure.

Totals are read from the same registry counters the ``stream_stats()``
facade serves, so the report's byte totals equal the legacy counters on the
same run *by construction*, not by parallel bookkeeping.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Mapping

from repro.obs.metrics import MetricsRegistry, registry as _default_registry
from repro.obs.roofline import streamed_solve_flops, streamed_solve_roofline

RUN_REPORT_KIND = "caddelag_run_report"
# Schema history:
#   1 -- initial: transitions/totals/cache/pipeline/solver/roofline.
#   2 -- adds the top-level "chain" section (build vs incremental-update
#        counters and logical GEMM flops/bytes/scratch) and per-transition
#        "chain" counter deltas (additive; all new fields default to zero
#        counters, so a schema-1 consumer reading schema 2 loses nothing).
RUN_REPORT_SCHEMA = 2

# Chain-phase registry counters surfaced in the report, totals and
# per-transition (see repro.core.chain / repro.core.delta_chain).
_CHAIN_FIELDS = (
    "builds", "full_rebuilds", "incremental_updates", "drift_fallbacks",
    "gemm_flops", "gemm_bytes", "scratch_bytes",
    "delta_gemm_flops", "delta_gemm_bytes",
)


def _chain_from_delta(delta: Mapping[str, float]) -> dict[str, float]:
    return {f: float(delta.get(f"chain.{f}", 0.0)) for f in _CHAIN_FIELDS}

# The per-transition phase vocabulary, in pipeline order.  `phase()` spans and
# registry counters use exactly these names (phase.<name>.seconds).
PHASES = ("ingest", "chain", "solve", "score")

_BYTE_FIELDS = ("bytes_read", "bytes_decoded", "bytes_h2d", "bytes_h2d_saved")


def _phases_from_delta(delta: Mapping[str, float]) -> dict[str, float]:
    return {p: float(delta.get(f"phase.{p}.seconds", 0.0)) for p in PHASES}


def _bytes_from_delta(delta: Mapping[str, float]) -> dict[str, int]:
    return {f: int(delta.get(f"stream.{f}", 0)) for f in _BYTE_FIELDS}


def _solve_record(rep: Any) -> dict[str, Any]:
    return {
        "method": rep.method,
        "iterations": int(rep.iterations),
        "residual": float(rep.residual),
        "converged": bool(rep.converged),
        "tolerance": None if rep.tolerance is None else float(rep.tolerance),
        "max_iters": int(rep.max_iters),
        "streamed": bool(rep.streamed),
        "rho": None if rep.rho is None else float(rep.rho),
        "rho_final": None
        if getattr(rep, "rho_final", None) is None
        else float(rep.rho_final),
        "warm_start": bool(getattr(rep, "warm_start", False)),
        "bytes_read": int(rep.bytes_read),
        "bytes_h2d": int(getattr(rep, "bytes_h2d", 0)),
        "panels": int(rep.panels),
        "residuals": [float(r) for r in getattr(rep, "residuals", ())],
    }


def build_run_report(
    *,
    config: Mapping[str, Any],
    result: Any,
    n: int | None = None,
    k_rp: int | None = None,
    reg: MetricsRegistry | None = None,
) -> dict[str, Any]:
    """Assemble the versioned run-report document for a finished sequence run.

    ``result`` is a :class:`~repro.core.sequence.SequenceResult`;
    ``config`` is whatever JSON-serializable run configuration the caller
    wants embedded (the CLI passes its resolved argument dict).  ``n`` and
    ``k_rp`` enable the streamed-solve roofline attribution when given.
    Registry totals are read at call time, so build the report at end of run,
    after the last transition.
    """
    reg = reg or _default_registry()
    snap = reg.snapshot()
    c = snap.counters

    per_push = list(getattr(result, "transition_metrics", ()) or ())
    transitions: list[dict[str, Any]] = []
    warnings: list[dict[str, Any]] = []
    import numpy as np

    for t, r in enumerate(result.transitions):
        delta = per_push[t] if t < len(per_push) else {}
        solves = [_solve_record(rep) for rep in r.solve_reports if rep is not None]
        rec = {
            "index": t,
            "seconds": float(result.transition_seconds[t])
            if t < len(result.transition_seconds)
            else None,
            "phases": _phases_from_delta(delta),
            "bytes": _bytes_from_delta(delta),
            "chain": _chain_from_delta(delta),
            "panels": int(delta.get("stream.panels", 0)),
            "solves": solves,
            "top_idx": np.asarray(r.top_idx).tolist(),
            "top_val": np.asarray(r.top_val, dtype=np.float64).tolist(),
        }
        transitions.append(rec)
        for s in solves:
            if not s["converged"]:
                warnings.append(
                    {
                        "level": "warning",
                        "event": "solver_not_converged",
                        "transition": t,
                        "method": s["method"],
                        "iterations": s["iterations"],
                        "residual": s["residual"],
                        "tolerance": s["tolerance"],
                    }
                )

    warmup = getattr(result, "warmup_metrics", None)
    warmup_rec = None
    if warmup:
        warmup_rec = {
            "phases": _phases_from_delta(warmup),
            "bytes": _bytes_from_delta(warmup),
        }

    hits = int(c.get("program_cache.hits", 0))
    misses = int(c.get("program_cache.misses", 0))
    fetch_s = float(c.get("pipeline.producer_fetch_seconds", 0.0))
    wait_s = float(c.get("pipeline.consumer_wait_seconds", 0.0))
    # Fraction of producer fetch time hidden behind compute: 1 when the
    # consumer never blocked on the ring, 0 when it waited out every fetch.
    prefetch_eff = max(0.0, min(1.0, 1.0 - wait_s / fetch_s)) if fetch_s > 0 else None

    totals = {
        "seconds": float(sum(result.transition_seconds)),
        "phases": _phases_from_delta(c),
        "bytes": _bytes_from_delta(c),
        "panels": int(c.get("stream.panels", 0)),
        "peak_live_bytes": int(snap.gauges.get("stream.peak_live_bytes", 0)),
    }

    solver_totals = {
        "solves": int(c.get("solver.solves", 0)),
        "iterations": int(c.get("solver.iterations", 0)),
        "not_converged": int(c.get("solver.not_converged", 0)),
    }

    roofline = None
    streamed = [
        s for rec in transitions for s in rec["solves"] if s["streamed"]
    ]
    if streamed and n and k_rp:
        solve_seconds = totals["phases"]["solve"]
        roofline = streamed_solve_roofline(
            bytes_read=float(sum(s["bytes_read"] for s in streamed)),
            bytes_h2d=float(sum(s["bytes_h2d"] for s in streamed)),
            flops=float(
                sum(streamed_solve_flops(n, k_rp, s["iterations"]) for s in streamed)
            ),
            seconds=solve_seconds,
        )

    return {
        "kind": RUN_REPORT_KIND,
        "schema": RUN_REPORT_SCHEMA,
        "config": dict(config),
        "n_snapshots": int(result.n_snapshots),
        "chain_builds": int(result.chain_builds),
        "transitions": transitions,
        "warmup": warmup_rec,
        "totals": totals,
        "chain": {
            **_chain_from_delta(c),
            "drift_last": snap.gauges.get("chain.drift_last"),
            "drift_series": [float(v) for v in reg.series("chain.drift")],
        },
        "cache": {
            "hits": hits,
            "misses": misses,
            "traces": int(c.get("program_cache.traces", 0)),
            "hit_rate": hits / (hits + misses) if (hits + misses) else None,
        },
        "pipeline": {
            "producer_fetch_seconds": fetch_s,
            "consumer_wait_seconds": wait_s,
            "panels_fetched": int(c.get("pipeline.panels_fetched", 0)),
            "prefetch_efficiency": prefetch_eff,
        },
        "solver": solver_totals,
        "roofline": roofline,
        "warnings": warnings,
    }


def save_run_report(doc: Mapping[str, Any], path: str) -> None:
    import os

    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# structural validators (dependency-free; used by tests and the CI smoke)
# ---------------------------------------------------------------------------


def _expect(problems: list[str], cond: bool, msg: str) -> bool:
    if not cond:
        problems.append(msg)
    return cond


def _is_num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_run_report(doc: Any) -> None:
    """Raise ``ValueError`` listing every structural problem in ``doc``."""
    p: list[str] = []
    if not _expect(p, isinstance(doc, dict), "run report must be a JSON object"):
        raise ValueError("; ".join(p))
    _expect(p, doc.get("kind") == RUN_REPORT_KIND,
            f"kind must be {RUN_REPORT_KIND!r}, got {doc.get('kind')!r}")
    _expect(p, isinstance(doc.get("schema"), int) and doc.get("schema", 0) >= 1,
            "schema must be an int >= 1")
    _expect(p, isinstance(doc.get("config"), dict), "config must be an object")
    _expect(p, isinstance(doc.get("n_snapshots"), int), "n_snapshots must be int")
    for key in ("totals", "cache", "pipeline", "solver"):
        _expect(p, isinstance(doc.get(key), dict), f"{key} must be an object")
    if doc.get("schema", 0) >= 2:
        ch = doc.get("chain")
        if _expect(p, isinstance(ch, dict), "chain must be an object (schema >= 2)"):
            for f_ in _CHAIN_FIELDS:
                _expect(p, _is_num(ch.get(f_, None)) and ch[f_] >= 0,
                        f"chain.{f_} must be a number >= 0")
            _expect(p, ch.get("drift_last") is None or _is_num(ch["drift_last"]),
                    "chain.drift_last must be a number or null")
            _expect(p, isinstance(ch.get("drift_series"), list),
                    "chain.drift_series must be a list")
    _expect(p, isinstance(doc.get("warnings"), list), "warnings must be a list")
    trs = doc.get("transitions")
    if _expect(p, isinstance(trs, list) and len(trs) > 0,
               "transitions must be a non-empty list"):
        for i, tr in enumerate(trs):
            where = f"transitions[{i}]"
            if not _expect(p, isinstance(tr, dict), f"{where} must be an object"):
                continue
            _expect(p, tr.get("index") == i, f"{where}.index must equal {i}")
            _expect(p, tr.get("seconds") is None or _is_num(tr["seconds"]),
                    f"{where}.seconds must be a number or null")
            phases = tr.get("phases")
            if _expect(p, isinstance(phases, dict), f"{where}.phases must be an object"):
                for ph in PHASES:
                    _expect(p, _is_num(phases.get(ph, None)) and phases[ph] >= 0,
                            f"{where}.phases.{ph} must be a number >= 0")
            by = tr.get("bytes")
            if _expect(p, isinstance(by, dict), f"{where}.bytes must be an object"):
                for f_ in _BYTE_FIELDS:
                    _expect(p, isinstance(by.get(f_, None), int) and by[f_] >= 0,
                            f"{where}.bytes.{f_} must be an int >= 0")
            if doc.get("schema", 0) >= 2:
                tch = tr.get("chain")
                if _expect(p, isinstance(tch, dict),
                           f"{where}.chain must be an object (schema >= 2)"):
                    for f_ in _CHAIN_FIELDS:
                        _expect(p, _is_num(tch.get(f_, None)) and tch[f_] >= 0,
                                f"{where}.chain.{f_} must be a number >= 0")
            solves = tr.get("solves")
            if _expect(p, isinstance(solves, list), f"{where}.solves must be a list"):
                for j, s in enumerate(solves):
                    sw = f"{where}.solves[{j}]"
                    if not _expect(p, isinstance(s, dict), f"{sw} must be an object"):
                        continue
                    _expect(p, isinstance(s.get("method"), str), f"{sw}.method must be str")
                    _expect(p, isinstance(s.get("iterations"), int) and s["iterations"] >= 0,
                            f"{sw}.iterations must be int >= 0")
                    _expect(p, _is_num(s.get("residual", None)),
                            f"{sw}.residual must be a number")
                    _expect(p, isinstance(s.get("converged"), bool),
                            f"{sw}.converged must be bool")
                    _expect(p, isinstance(s.get("residuals"), list),
                            f"{sw}.residuals must be a list")
    if isinstance(doc.get("totals"), dict):
        tb = doc["totals"].get("bytes")
        if _expect(p, isinstance(tb, dict), "totals.bytes must be an object"):
            for f_ in _BYTE_FIELDS:
                _expect(p, isinstance(tb.get(f_, None), int) and tb[f_] >= 0,
                        f"totals.bytes.{f_} must be an int >= 0")
    for i, w in enumerate(doc.get("warnings") or []):
        _expect(p, isinstance(w, dict) and isinstance(w.get("level"), str)
                and isinstance(w.get("event"), str),
                f"warnings[{i}] must be an object with level and event")
    if p:
        raise ValueError("invalid run report: " + "; ".join(p))


def validate_chrome_trace(doc: Any) -> None:
    """Structural check of a Chrome trace-event JSON object."""
    p: list[str] = []
    if not _expect(p, isinstance(doc, dict), "trace must be a JSON object"):
        raise ValueError("; ".join(p))
    evs = doc.get("traceEvents")
    if not _expect(p, isinstance(evs, list), "traceEvents must be a list"):
        raise ValueError("invalid chrome trace: " + "; ".join(p))
    n_complete = 0
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not _expect(p, isinstance(e, dict), f"{where} must be an object"):
            continue
        _expect(p, isinstance(e.get("name"), str), f"{where}.name must be str")
        ph = e.get("ph")
        _expect(p, isinstance(ph, str) and len(ph) == 1, f"{where}.ph must be a 1-char str")
        if ph == "X":
            n_complete += 1
            _expect(p, _is_num(e.get("ts", None)) and e["ts"] >= 0,
                    f"{where}.ts must be a number >= 0")
            _expect(p, _is_num(e.get("dur", None)) and e["dur"] >= 0,
                    f"{where}.dur must be a number >= 0")
            _expect(p, isinstance(e.get("pid"), int), f"{where}.pid must be int")
            _expect(p, isinstance(e.get("tid"), int), f"{where}.tid must be int")
            _expect(p, isinstance(e.get("args", {}), dict), f"{where}.args must be an object")
    _expect(p, n_complete > 0, "trace has no complete ('X') events")
    if p:
        raise ValueError("invalid chrome trace: " + "; ".join(p))


def validate_file(path: str) -> str:
    """Validate one JSON file, auto-detecting run report vs Chrome trace.

    Returns the detected kind; raises ``ValueError`` on failure.
    """
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict) and "traceEvents" in doc:
        validate_chrome_trace(doc)
        return "chrome_trace"
    validate_run_report(doc)
    return RUN_REPORT_KIND


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Validate run-report / Chrome-trace JSON files."
    )
    ap.add_argument("files", nargs="+", help="JSON files to validate")
    ap.add_argument("--validate", action="store_true",
                    help="(default action; flag accepted for clarity)")
    args = ap.parse_args(argv)
    rc = 0
    for path in args.files:
        try:
            kind = validate_file(path)
        except (ValueError, OSError, json.JSONDecodeError) as e:
            print(f"[obs.report] FAIL {path}: {e}", file=sys.stderr)
            rc = 1
        else:
            print(f"[obs.report] OK {path} ({kind})")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
