"""Streamed-solve roofline model (importable home; benches re-export).

The out-of-core solve is bound by whichever of scratch-disk read, host->device
staging, or MXU FLOPs saturates first.  All three terms come from measured
traffic (the ``stream.*`` byte counters) plus the iteration count, so run
reports and benchmarks can state measured-vs-bound directly.  Lived in
``benchmarks/roofline.py`` through PR 6; moved here so ``obs/report.py`` can
attribute a roofline fraction per run without importing the benchmarks tree.
"""

from __future__ import annotations

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e-class)
DISK_BW = 2.0e9  # bytes/s sustained scratch-store read (NVMe-class)
H2D_BW = 32e9  # bytes/s host->device staging (PCIe gen4 x16-class)

__all__ = [
    "PEAK_FLOPS",
    "DISK_BW",
    "H2D_BW",
    "streamed_solve_flops",
    "streamed_solve_roofline",
]


def streamed_solve_flops(n: int, k: int, iterations: int) -> float:
    """Dense FLOPs of a streamed solve: one (n x n) @ (n x k) mat-vec per
    iteration plus the chi build (P1 @ b), 2nk per MAC row."""
    return 2.0 * n * n * k * (iterations + 1)


def streamed_solve_roofline(
    *,
    bytes_read: float,
    bytes_h2d: float,
    flops: float,
    seconds: float,
    disk_bw: float = DISK_BW,
    h2d_bw: float = H2D_BW,
    peak_flops: float = PEAK_FLOPS,
) -> dict:
    """Three-term bound for a streamed solve, from measured traffic.

    ``bound_s = max(read/disk_bw, h2d/h2d_bw, flops/peak)`` is the fastest
    the solve could have gone on the modeled hardware; ``roofline_frac =
    bound_s / seconds`` is the fraction of that bound actually achieved
    (CPU-interpret runs will sit far below 1 -- the *trajectory* of the
    fraction and of the byte terms across PRs is the signal, the absolute
    value only means something on real accelerator + NVMe tiers).
    """
    t_disk = bytes_read / disk_bw
    t_h2d = bytes_h2d / h2d_bw
    t_flop = flops / peak_flops
    bound_s, bound = max(
        (t_disk, "disk"), (t_h2d, "h2d"), (t_flop, "compute")
    )
    return {
        "t_disk_s": t_disk,
        "t_h2d_s": t_h2d,
        "t_compute_s": t_flop,
        "bound": bound,
        "bound_s": bound_s,
        "measured_s": seconds,
        "roofline_frac": bound_s / seconds if seconds > 0 else 0.0,
    }
