"""Process-wide metrics registry: counters, gauges, series, atomic snapshots.

Telemetry used to be scattered across process-global dataclasses
(``StreamStats``, ``ProgramCacheStats``), per-call report objects and ad-hoc
print lines -- each with its own (or no) locking and its own reset semantics.
This registry owns the storage once:

* **counters** are monotonically increasing floats, mutated only through
  :meth:`MetricsRegistry.add` / :meth:`inc` under the registry lock -- a
  producer thread ``add``-ing bytes while the main thread resets or snapshots
  can never lose an update or observe a torn read (the ``reset_stream_stats``
  race the old ``st.bytes_read += n`` read-modify-writes allowed);
* **gauges** hold "current value" semantics, with :meth:`max_gauge` for
  high-water marks (peak live bytes);
* **series** are bounded append-only float lists (per-iteration solver
  residuals) -- once a series hits its cap, further appends are dropped and
  the drop is counted, never silently resized;
* **snapshots** (:meth:`snapshot`) copy the whole registry atomically, and
  :meth:`delta` yields exactly the counter increments (and series suffixes)
  recorded since a snapshot -- the scoped-measurement primitive every
  per-transition / per-solve breakdown is built on.

Names are dot-scoped by convention (``stream.bytes_read``,
``phase.solve.seconds``, ``pipeline.consumer_wait_seconds``,
``program_cache.hits``, ``solver.residuals``); :meth:`reset` takes a prefix
so one subsystem's counters can be zeroed without touching the rest.

The module-level :data:`REGISTRY` is the process default.  Facades over it
(``repro.core.tiles.StreamStats``) may also be constructed over a private
registry for isolated accounting in tests.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Iterable, Mapping

DEFAULT_SERIES_CAP = 4096


@dataclass(frozen=True)
class MetricsSnapshot:
    """Immutable, internally consistent copy of a registry at one instant."""

    counters: Mapping[str, float]
    gauges: Mapping[str, float]
    series_len: Mapping[str, int]

    def counter(self, name: str, default: float = 0.0) -> float:
        return self.counters.get(name, default)


class MetricsRegistry:
    """Thread-safe counters / gauges / series with atomic snapshot + reset."""

    def __init__(self, series_cap: int = DEFAULT_SERIES_CAP):
        self._lock = threading.RLock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._series: dict[str, list[float]] = {}
        self._series_dropped: dict[str, int] = {}
        self._series_cap = int(series_cap)

    # -- counters ------------------------------------------------------------

    def inc(self, name: str, value: float = 1.0) -> None:
        """Atomically increment one counter."""
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + value

    def add(self, **counters: float) -> None:
        """Atomically increment several counters in one critical section.

        Multi-counter updates that must stay mutually consistent (a panel's
        ``bytes_read`` + ``bytes_decoded``) go through one ``add`` so a
        concurrent snapshot or reset sees either both or neither.
        """
        with self._lock:
            for name, value in counters.items():
                self._counters[name] = self._counters.get(name, 0.0) + value

    def add_named(self, counters: Mapping[str, float]) -> None:
        """``add`` for names that are not valid Python identifiers."""
        with self._lock:
            for name, value in counters.items():
                self._counters[name] = self._counters.get(name, 0.0) + value

    def value(self, name: str, default: float = 0.0) -> float:
        with self._lock:
            return self._counters.get(name, default)

    # -- gauges --------------------------------------------------------------

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def max_gauge(self, name: str, value: float) -> None:
        """High-water-mark gauge: keep the maximum ever set."""
        with self._lock:
            cur = self._gauges.get(name)
            if cur is None or value > cur:
                self._gauges[name] = value

    def gauge(self, name: str, default: float = 0.0) -> float:
        with self._lock:
            return self._gauges.get(name, default)

    # -- series --------------------------------------------------------------

    def append(self, name: str, value: float) -> None:
        """Append to a bounded series; overflow is counted, not resized."""
        with self._lock:
            s = self._series.setdefault(name, [])
            if len(s) < self._series_cap:
                s.append(float(value))
            else:
                self._series_dropped[name] = self._series_dropped.get(name, 0) + 1

    def extend(self, name: str, values: Iterable[float]) -> None:
        with self._lock:
            for v in values:
                s = self._series.setdefault(name, [])
                if len(s) < self._series_cap:
                    s.append(float(v))
                else:
                    self._series_dropped[name] = (
                        self._series_dropped.get(name, 0) + 1
                    )

    def series(self, name: str) -> tuple[float, ...]:
        with self._lock:
            return tuple(self._series.get(name, ()))

    # -- snapshot / delta / reset --------------------------------------------

    def snapshot(self) -> MetricsSnapshot:
        """Atomic copy: counters, gauges and series lengths, all at once."""
        with self._lock:
            return MetricsSnapshot(
                counters=dict(self._counters),
                gauges=dict(self._gauges),
                series_len={k: len(v) for k, v in self._series.items()},
            )

    def delta(self, since: MetricsSnapshot) -> dict[str, float]:
        """Exact counter increments since ``since`` (zero deltas omitted)."""
        with self._lock:
            out = {}
            for name, cur in self._counters.items():
                d = cur - since.counters.get(name, 0.0)
                if d:
                    out[name] = d
            return out

    def series_delta(self, name: str, since: MetricsSnapshot) -> tuple[float, ...]:
        """Series entries appended since ``since``."""
        with self._lock:
            return tuple(self._series.get(name, [])[since.series_len.get(name, 0):])

    def reset(self, prefix: str | None = None) -> None:
        """Zero counters/gauges and drop series, atomically.

        With ``prefix``, only names starting with it are cleared -- the
        subsystem-scoped reset behind ``reset_stream_stats()`` /
        ``reset_program_cache_stats()``.  Entries are *removed* (not set to
        zero), so a snapshot after a reset is indistinguishable from a fresh
        registry for that prefix.
        """
        with self._lock:
            if prefix is None:
                self._counters.clear()
                self._gauges.clear()
                self._series.clear()
                self._series_dropped.clear()
                return
            for store in (self._counters, self._gauges, self._series,
                          self._series_dropped):
                for name in [k for k in store if k.startswith(prefix)]:
                    del store[name]

    def names(self) -> tuple[str, ...]:
        with self._lock:
            return tuple(
                sorted(set(self._counters) | set(self._gauges) | set(self._series))
            )


@dataclass
class Scope:
    """Scoped measurement: snapshot on entry, exact deltas on demand.

        with metrics.scoped() as sc:
            ... work ...
        phase_seconds = sc.delta().get("phase.solve.seconds", 0.0)
    """

    registry: MetricsRegistry
    start: MetricsSnapshot | None = field(default=None)

    def __enter__(self) -> "Scope":
        self.start = self.registry.snapshot()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None

    def delta(self) -> dict[str, float]:
        assert self.start is not None, "Scope used outside its with-block"
        return self.registry.delta(self.start)

    def series_delta(self, name: str) -> tuple[float, ...]:
        assert self.start is not None, "Scope used outside its with-block"
        return self.registry.series_delta(name, self.start)


REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-default registry."""
    return REGISTRY


def scoped(reg: MetricsRegistry | None = None) -> Scope:
    """A :class:`Scope` over ``reg`` (default: the process registry)."""
    return Scope(reg or REGISTRY)
