"""Unified observability: span tracing, metrics registry, structured reports.

Three parts (see the module docstrings for depth):

* :mod:`repro.obs.trace` -- thread-aware span tracer, Chrome trace-event
  export, disabled-by-default no-op fast path, cross-thread begin/end.
* :mod:`repro.obs.metrics` -- process-wide counters/gauges/series registry
  with atomic snapshot/delta/reset; backs the ``stream_stats()`` and
  ``program_cache_stats()`` facades in :mod:`repro.core.tiles`.
* :mod:`repro.obs.report` -- versioned RunReport JSON (+ validators) emitted
  by ``caddelag-run --run-report``.

:func:`phase` is the glue the five pipeline layers use: one call opens a
trace span (when tracing is on) AND accumulates the always-on
``phase.<name>.seconds`` / ``phase.<name>.calls`` registry counters the
per-transition breakdowns are cut from.
"""

from __future__ import annotations

import time
from contextlib import contextmanager

from repro.obs import metrics, trace
from repro.obs.metrics import (
    REGISTRY,
    MetricsRegistry,
    MetricsSnapshot,
    registry,
    scoped,
)
from repro.obs.trace import (
    Tracer,
    begin,
    disable_tracing,
    enable_tracing,
    end,
    span,
    tracer,
    tracing_enabled,
)

__all__ = [
    "metrics",
    "trace",
    "MetricsRegistry",
    "MetricsSnapshot",
    "REGISTRY",
    "registry",
    "scoped",
    "Tracer",
    "tracer",
    "enable_tracing",
    "disable_tracing",
    "tracing_enabled",
    "span",
    "begin",
    "end",
    "phase",
]


@contextmanager
def phase(name: str, **args):
    """Time one pipeline phase: a trace span + always-on registry counters.

    The yielded span supports ``fence(x)`` -- with tracing enabled under
    ``enable_tracing(fence=True)``, span exit blocks on ``x`` so both the
    span and the ``phase.<name>.seconds`` counter record an honest device
    wall (the counter is accumulated *after* the span exits, fence included).
    With tracing disabled the span is the shared null span and the counters
    measure dispatch + host work only; program-level walls remain honest via
    the block_until_ready at scoring boundaries.
    """
    t0 = time.perf_counter()
    sp = trace.span(f"phase.{name}", **args)
    sp.__enter__()
    try:
        yield sp
    finally:
        sp.__exit__(None, None, None)
        dt = time.perf_counter() - t0
        REGISTRY.add_named(
            {f"phase.{name}.seconds": dt, f"phase.{name}.calls": 1.0}
        )
