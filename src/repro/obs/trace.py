"""Thread-aware span tracer with Chrome trace-event export.

Spans measure named intervals on the monotonic clock
(``time.perf_counter_ns``) and export as Chrome trace-event JSON ("X"
complete events plus "M" thread-name metadata), loadable in Perfetto or
chrome://tracing.  Two APIs:

* ``with span("phase.solve", n=1024):`` — same-thread context manager;
  nesting falls out of the event intervals (the viewers render the stack).
* ``h = begin("prefetch.panel", ...)`` / ``end(h)`` — explicit pairing for
  spans that *cross threads*: the PanelPipeline producer opens the span when
  it starts fetching a panel, the consumer closes it when the panel is
  staged.  The exported event carries the **producer's** tid (recorded at
  ``begin``), so in the trace the panel's lifetime renders on the prefetch
  thread's track.

Tracing is **disabled by default** and the disabled path is a no-op fast
path: ``span()`` returns a shared null span (no allocation, no clock read,
no lock) and ``begin()`` returns handle ``0`` which ``end()`` ignores.
Enabling costs two clock reads plus one locked list-append per span.

Fencing: device work in jax is dispatched asynchronously, so a span that
only brackets dispatch under-reports the device wall.  When tracing is
enabled with ``enable_tracing(fence=True)``, a span exit on which
``sp.fence(x)`` was called runs ``jax.block_until_ready(x)`` *inside* the
span, making the recorded duration an honest device-phase wall.  With
tracing disabled (or ``fence=False``) no extra synchronization is
introduced — timings then measure dispatch plus host work, and program-level
walls stay honest via the existing block_until_ready at scoring boundaries.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any

__all__ = [
    "Tracer",
    "tracer",
    "enable_tracing",
    "disable_tracing",
    "tracing_enabled",
    "span",
    "begin",
    "end",
]


def _now_us() -> float:
    return time.perf_counter_ns() / 1000.0


class _NullSpan:
    """Shared no-op span: the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None

    def annotate(self, **args: Any) -> None:
        return None

    def fence(self, value: Any) -> None:
        return None


_NULL_SPAN = _NullSpan()


class _Span:
    """Live same-thread span; records one "X" event on exit."""

    __slots__ = ("_tracer", "name", "args", "t0", "tid", "_fence")

    def __init__(self, tracer_: "Tracer", name: str, args: dict[str, Any]):
        self._tracer = tracer_
        self.name = name
        self.args = args
        self.tid = threading.get_ident()
        self.t0 = 0.0
        self._fence = None

    def __enter__(self) -> "_Span":
        self.t0 = _now_us()
        return self

    def annotate(self, **args: Any) -> None:
        self.args.update(args)

    def fence(self, value: Any) -> None:
        """Register device values to block on at span exit (if fencing on)."""
        self._fence = value

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._fence is not None and self._tracer.fence_enabled:
            _block_until_ready(self._fence)
        self._tracer._record(
            self.name, self.t0, _now_us() - self.t0, self.tid, self.args
        )
        return None


def _block_until_ready(value: Any) -> None:
    try:
        import jax

        jax.block_until_ready(value)
    except Exception:
        # Non-jax payloads (store handles, host arrays) are already "ready".
        pass


class Tracer:
    """Span recorder; one process-global instance behind :func:`tracer`."""

    def __init__(self) -> None:
        self.enabled = False
        self.fence_enabled = False
        self._lock = threading.Lock()
        self._events: list[dict[str, Any]] = []
        self._thread_names: dict[int, str] = {}
        # Cross-thread spans in flight: handle -> (name, t0_us, producer_tid, args)
        self._pending: dict[int, tuple[str, float, int, dict[str, Any]]] = {}
        self._next_handle = 1

    # -- lifecycle -----------------------------------------------------------

    def enable(self, fence: bool = False) -> "Tracer":
        self.enabled = True
        self.fence_enabled = fence
        return self

    def disable(self) -> None:
        self.enabled = False
        self.fence_enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._thread_names.clear()
            self._pending.clear()

    # -- recording -----------------------------------------------------------

    def span(self, name: str, **args: Any):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args)

    def begin(self, name: str, **args: Any) -> int:
        """Open a cross-thread span; returns a handle (0 when disabled).

        The calling thread is recorded as the span's owner: the exported
        event lands on *this* thread's track even if another thread ends it.
        """
        if not self.enabled:
            return 0
        tid = threading.get_ident()
        t0 = _now_us()
        with self._lock:
            handle = self._next_handle
            self._next_handle += 1
            self._pending[handle] = (name, t0, tid, args)
            self._note_thread_locked(tid)
        return handle

    def end(self, handle: int, **args: Any) -> None:
        """Close a span opened by :func:`begin`; no-op for handle 0.

        Safe to call from any thread; extra ``args`` merge into the event
        (the ending thread's id is recorded as ``end_tid`` when it differs).
        """
        if handle == 0:
            return
        t1 = _now_us()
        end_tid = threading.get_ident()
        with self._lock:
            pending = self._pending.pop(handle, None)
            if pending is None:
                return
            name, t0, tid, ev_args = pending
            if args:
                ev_args = {**ev_args, **args}
            if end_tid != tid:
                ev_args = {**ev_args, "end_tid": end_tid}
            self._events.append(
                {
                    "name": name,
                    "ph": "X",
                    "ts": t0,
                    "dur": max(t1 - t0, 0.0),
                    "pid": os.getpid(),
                    "tid": tid,
                    "args": ev_args,
                }
            )

    def _record(
        self, name: str, t0: float, dur: float, tid: int, args: dict[str, Any]
    ) -> None:
        with self._lock:
            self._note_thread_locked(tid)
            self._events.append(
                {
                    "name": name,
                    "ph": "X",
                    "ts": t0,
                    "dur": max(dur, 0.0),
                    "pid": os.getpid(),
                    "tid": tid,
                    "args": args,
                }
            )

    def _note_thread_locked(self, tid: int) -> None:
        if tid not in self._thread_names:
            self._thread_names[tid] = threading.current_thread().name

    # -- export --------------------------------------------------------------

    def events(self) -> list[dict[str, Any]]:
        with self._lock:
            return [dict(e) for e in self._events]

    def to_chrome_trace(self) -> dict[str, Any]:
        """Chrome trace-event JSON object (Perfetto / chrome://tracing)."""
        with self._lock:
            pid = os.getpid()
            meta = [
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": pid,
                    "tid": tid,
                    "args": {"name": tname},
                }
                for tid, tname in sorted(self._thread_names.items())
            ]
            return {
                "traceEvents": meta + [dict(e) for e in self._events],
                "displayTimeUnit": "ms",
                "otherData": {"clock": "perf_counter", "unit": "us"},
            }

    def save(self, path: str) -> None:
        doc = self.to_chrome_trace()
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, path)


_TRACER = Tracer()


def tracer() -> Tracer:
    return _TRACER


def enable_tracing(fence: bool = False) -> Tracer:
    return _TRACER.enable(fence=fence)


def disable_tracing() -> None:
    _TRACER.disable()


def tracing_enabled() -> bool:
    return _TRACER.enabled


def span(name: str, **args: Any):
    """Open a span on the global tracer (null span when disabled)."""
    if not _TRACER.enabled:
        return _NULL_SPAN
    return _Span(_TRACER, name, args)


def begin(name: str, **args: Any) -> int:
    return _TRACER.begin(name, **args)


def end(handle: int, **args: Any) -> None:
    _TRACER.end(handle, **args)
