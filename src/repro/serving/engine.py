"""Batched serving engine: prefill + jit'd decode loop with sampling.

``ServeEngine`` owns jit'd ``prefill`` and ``decode_step`` closures with
explicit shardings (KV-cache sequence over "model" = flash-decode) and runs
batched requests: prompts are right-aligned into a fixed prompt window,
decoded greedily or with temperature sampling until max_new_tokens.

``make_serve_step`` exposes the single-token decode step that the dry-run
lowers for the ``decode_32k`` / ``long_500k`` cells.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import common as cm
from repro.models import lm


@dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


def _named(mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def _serve_rules(spec: lm.LMSpec, mesh: Mesh, rules=None):
    rules = rules or (cm.multipod_rules() if "pod" in mesh.axis_names else cm.DEFAULT_RULES)
    return cm.arch_rules(spec.cfg, rules)


def _token_sharding(spec: lm.LMSpec, mesh: Mesh, batch: int, rules=None) -> NamedSharding:
    """The decode step's declared token sharding (see make_serve_step)."""
    rules = cm.attach_axis_sizes(_serve_rules(spec, mesh, rules), mesh)
    return NamedSharding(
        mesh, cm.sanitize_spec(cm.logical_to_spec(("batch",), rules), (batch,), mesh)
    )


def make_serve_step(
    spec: lm.LMSpec,
    mesh: Mesh,
    *,
    batch: int,
    s_max: int,
    enc_len: int = 0,
    rules=None,
    donate_cache: bool = True,
):
    """Returns (jit'd decode_step, cache_shapes, cache_shardings, param_specs).

    decode_step(params, token (B,), cache) -> (logits (B, V), cache)
    Cache specs are divisibility-sanitized against the mesh; the KV sequence
    shards over "model" (flash-decode).
    """
    rules = _serve_rules(spec, mesh, rules)
    # decode moves tokens (KBs), never expert weights (GBs/layer):
    # and keeps ALL weights resident: experts 2-axis (model x data), dense
    # layers TP over "model" and replicated over "data" (no optimizer states
    # at inference, so FSDP's per-layer d-gather would be pure overhead).
    rules = {**rules, "moe_gathered": True, "embed_p": None, "embed_d": None}
    rules = cm.attach_axis_sizes(rules, mesh)
    pshape = jax.eval_shape(lambda k: lm.init_params(spec, k), jax.random.PRNGKey(0))
    pspecs = cm.sanitize_specs(lm.param_specs(spec, rules), pshape, mesh)

    cache_shapes = jax.eval_shape(
        lambda: lm.init_cache(spec, batch, s_max, enc_len=enc_len)
    )
    cspecs = cm.tree_specs(lm.cache_axes(spec), rules)
    if spec.is_encdec:
        cspecs = {**cspecs, "enc_out": cm.logical_to_spec(("batch", "seq", "embed"), rules)}
        cache_shapes = {
            **cache_shapes,
            "enc_out": jax.ShapeDtypeStruct((batch, enc_len, spec.cfg.d_model), spec.cfg.cdtype),
        }
    cspecs = cm.sanitize_specs(cspecs, cache_shapes, mesh)
    tok_spec = cm.sanitize_spec(cm.logical_to_spec(("batch",), rules), (batch,), mesh)

    def step(params, token, cache):
        return lm.decode_step(spec, params, token, cache, rules=rules)

    jit_step = jax.jit(
        step,
        in_shardings=(_named(mesh, pspecs), NamedSharding(mesh, tok_spec), _named(mesh, cspecs)),
        out_shardings=(None, _named(mesh, cspecs)),
        donate_argnums=(2,) if donate_cache else (),
    )
    return jit_step, cache_shapes, _named(mesh, cspecs), pspecs


def make_prefill(spec: lm.LMSpec, mesh: Mesh, s_max: int, *, rules=None):
    rules = cm.attach_axis_sizes(_serve_rules(spec, mesh, rules), mesh)
    pshape = jax.eval_shape(lambda k: lm.init_params(spec, k), jax.random.PRNGKey(0))
    pspecs = cm.sanitize_specs(lm.param_specs(spec, rules), pshape, mesh)

    def pf(params, batch):
        return lm.prefill(spec, params, batch, s_max, rules=rules)

    return jax.jit(pf, in_shardings=(_named(mesh, pspecs), None)), pspecs


class ServeEngine:
    """Simple batched request driver (greedy / temperature sampling)."""

    def __init__(self, spec: lm.LMSpec, mesh: Mesh, params, s_max: int, batch: int = 0,
                 cfg: ServeConfig = ServeConfig()):
        self.spec, self.mesh, self.params, self.cfg = spec, mesh, params, cfg
        self.s_max = s_max
        self.decode, _, _, _ = make_serve_step(
            spec, mesh, batch=batch or 1, s_max=s_max, donate_cache=True
        )
        self.prefill, _ = make_prefill(spec, mesh, s_max)
        # The decode step declares a (possibly data-sharded) token in_sharding;
        # sampled tokens come off an eager argmax/categorical as *replicated*
        # arrays, which pjit rejects on multi-device meshes (equivalent only on
        # 1x1).  Re-lay every sampled token out explicitly before decode.
        self._tok_sharding = _token_sharding(spec, mesh, batch or 1)

    def generate(self, prompts: np.ndarray, frames: np.ndarray | None = None) -> np.ndarray:
        """prompts (B, S_prompt) int32 -> generated tokens (B, max_new)."""
        batch = {"tokens": jnp.asarray(prompts)}
        if frames is not None:
            batch["frames"] = jnp.asarray(frames)
        with self.mesh:
            logits, cache = self.prefill(self.params, batch)
            key = jax.random.PRNGKey(self.cfg.seed)
            out = []
            tok = self._sample(logits, key)
            for i in range(self.cfg.max_new_tokens):
                out.append(np.asarray(tok))
                logits, cache = self.decode(self.params, tok, cache)
                key, sub = jax.random.split(key)
                tok = self._sample(logits, sub)
        return np.stack(out, axis=1)

    def _sample(self, logits, key):
        if self.cfg.temperature <= 0.0:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            tok = jax.random.categorical(
                key, logits / self.cfg.temperature, axis=-1
            ).astype(jnp.int32)
        return jax.device_put(tok, self._tok_sharding)
