from repro.serving.engine import ServeConfig, ServeEngine, make_prefill, make_serve_step

__all__ = ["ServeConfig", "ServeEngine", "make_prefill", "make_serve_step"]
