"""Sharded, atomic, async checkpointing with elastic re-mesh on restore.

Layout (one directory per step):
    ckpt_dir/step_000123/
        manifest.json        # step, leaf shapes/dtypes, user extra dict
        leaf_000000.npy ...  # one file per pytree leaf (flatten order)

Write protocol: everything lands in ``step_X.tmp`` first, then a single
atomic ``rename`` commits it -- a crashed writer can never corrupt the
latest-complete checkpoint, and ``latest_step`` only ever sees committed
directories.  ``AsyncCheckpointer`` runs serialization on a daemon thread
(training continues; ``wait()`` joins before the next save or exit).

Restore is *elastic*: leaves are loaded host-side and re-``device_put`` with
the *current* mesh's NamedShardings, so a job checkpointed on 512 chips can
resume on 256 (or on this CPU container) -- the re-mesh is just a different
sharding at device_put time.  The tree structure comes from the caller's
``template`` (an ``eval_shape`` of init), so no pytree serialization is
needed and configs remain the single source of truth.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np


def save(ckpt_dir: str, step: int, tree, *, extra: dict | None = None) -> str:
    """Synchronous atomic save; returns the committed directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = jax.tree.leaves(tree)
    manifest = {"step": step, "n_leaves": len(leaves), "extra": extra or {}, "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        path = f"leaf_{i:06d}.npy"
        np.save(os.path.join(tmp, path), arr)
        manifest["leaves"].append(
            {"path": path, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


class AsyncCheckpointer:
    """Fire-and-forget saves on a daemon thread; at most one in flight."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, ckpt_dir: str, step: int, tree, *, extra: dict | None = None):
        self.wait()
        # device_get on the caller thread (arrays may be donated right after)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(ckpt_dir, step, host_tree, extra=extra)
            except BaseException as e:  # surfaced at next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_")
        and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template, *, shardings=None):
    """Load a checkpoint into the structure of ``template``.

    ``template``: pytree (e.g. ``jax.eval_shape`` of init) fixing structure
    and dtypes.  ``shardings``: optional matching pytree of NamedShardings --
    the elastic re-mesh target; leaves are device_put with them.
    Returns (tree, extra, step).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    t_leaves, treedef = jax.tree.flatten(template)
    if len(t_leaves) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, template has {len(t_leaves)}"
        )
    leaves = []
    for entry, tl in zip(manifest["leaves"], t_leaves):
        arr = np.load(os.path.join(d, entry["path"]))
        dtype = tl.dtype if hasattr(tl, "dtype") else arr.dtype
        leaves.append(np.asarray(arr, dtype))
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, manifest["extra"], manifest["step"]
