"""Straggler watchdog + failure-injection harness for the restart loop.

On a real pod, per-step wall times come from the host; a straggling chip
(thermal throttle, flaky ICI link) shows up as a step-time spike on every
host because steps are globally synchronous.  The watchdog keeps an EMA of
step time and flags steps slower than ``factor`` x EMA; the training driver
logs offenders and (beyond ``max_flags``) requests a checkpoint-and-remesh
cycle -- the v5e analogue of cordoning a bad node.

``FailureInjector`` deterministically raises at a chosen step so tests can
prove the checkpoint/restart path is bit-exact.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class StragglerWatchdog:
    factor: float = 2.5
    decay: float = 0.9
    warmup_steps: int = 3
    ema: float | None = None
    flags: list = field(default_factory=list)
    _seen: int = 0

    def observe(self, step: int, dt: float) -> bool:
        """Record one step time; returns True if this step is a straggler."""
        self._seen += 1
        if self._seen <= self.warmup_steps:
            # warmup: seed the EMA, never flag (first steps include compile)
            self.ema = dt if self.ema is None else self.decay * self.ema + (1 - self.decay) * dt
            return False
        is_slow = self.ema is not None and dt > self.factor * self.ema
        if is_slow:
            self.flags.append((step, dt, self.ema))
        else:
            self.ema = self.decay * self.ema + (1 - self.decay) * dt
        return is_slow


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.dt = time.perf_counter() - self.t0
        return False


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raises InjectedFailure when training reaches ``fail_at_step`` (once)."""

    fail_at_step: int | None = None
    fired: bool = False

    def check(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step and not self.fired:
            self.fired = True
            raise InjectedFailure(f"injected node failure at step {step}")
