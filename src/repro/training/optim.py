"""Hand-rolled sharded optimizers: AdamW and Adafactor, + LR schedules.

Optimizer states inherit the parameter PartitionSpecs (they are elementwise),
so FSDP-sharded params get FSDP-sharded moments for free.  Adafactor stores
row/col factored second moments for >=2-D params -- the large-MoE default
(llama4-maverick: full AdamW moments would be 6.2 TB fp32).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1


def lr_schedule(cfg: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptConfig, grads, state, params):
    c = state["count"] + 1
    lr = lr_schedule(cfg, c)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** c.astype(jnp.float32))
        vh = v / (1 - b2 ** c.astype(jnp.float32))
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": c}


def adamw_state_specs(param_specs):
    from jax.sharding import PartitionSpec as P

    return {
        "m": param_specs,
        "v": param_specs,
        "count": P(),
    }


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, beta1=0 -- PaLM-style memory diet)
# ---------------------------------------------------------------------------


def adafactor_init(params):
    def factored(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row (all but last)
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(factored, params), "count": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, grads, state, params):
    c = state["count"] + 1
    lr = lr_schedule(cfg, c)
    decay = 1.0 - (c.astype(jnp.float32) + 1.0) ** -0.8  # tau = step^-0.8

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = decay * v["vr"] + (1 - decay) * g2.mean(axis=-1)
            vc = decay * v["vc"] + (1 - decay) * g2.mean(axis=-2)
            denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
            vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
            nv = {"vr": vr, "vc": vc}
        else:
            vhat = decay * v["v"] + (1 - decay) * g2
            nv = {"v": vhat}
        update = g * jax.lax.rsqrt(vhat + 1e-30)
        # update clipping (RMS <= 1) stabilizes warmup, per Adafactor paper
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        step = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), nv

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    new_p, new_v = [], []
    for g, v, p in zip(flat_g, flat_v, flat_p):
        np_, nv_ = upd(g, v, p)
        new_p.append(np_)
        new_v.append(nv_)
    return treedef.unflatten(new_p), {"v": treedef.unflatten(new_v), "count": c}


def adafactor_state_specs(param_specs, params_shape):
    """Factored states drop the last (vr) / second-last (vc) spec entry."""
    from jax.sharding import PartitionSpec as P

    def spec_for(ps, p):
        ps_t = tuple(ps) if ps is not None else ()
        ps_t = ps_t + (None,) * (p.ndim - len(ps_t))
        if p.ndim >= 2:
            return {"vr": P(*ps_t[:-1]), "vc": P(*(ps_t[:-2] + ps_t[-1:]))}
        return {"v": P(*ps_t)}

    v = jax.tree.map(spec_for, param_specs, params_shape)
    return {"v": v, "count": P()}


# ---------------------------------------------------------------------------
# unified front
# ---------------------------------------------------------------------------


def make_optimizer(cfg: OptConfig):
    if cfg.name == "adamw":
        return adamw_init, partial(adamw_update, cfg)
    if cfg.name == "adafactor":
        return adafactor_init, partial(adafactor_update, cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
