"""Jit'd train-step builders: grad-accum, clipping, optional cross-pod
int8 error-feedback gradient compression.

``make_train_step`` returns a function
    (params, opt_state, batch, [ef_state]) -> (params, opt_state, metrics)
already wrapped in ``jax.jit`` with in/out shardings derived from the model's
logical axes, ready for ``.lower(...).compile()`` in the dry-run.

Microbatching: the global batch is split into ``accum`` microbatches scanned
sequentially; grads are averaged in fp32.  XLA overlaps the FSDP all-gathers
of layer i+1 with the compute of layer i inside each microbatch (scan over
layers), which is the compute/comm overlap story for the roofline.

Cross-pod compression (optional, multi-pod mesh only): the backward pass
computes *pod-local* grads inside a shard_map that is manual over "pod" and
auto over ("data", "model"); the cross-pod all-reduce then happens on int8
quantized grads with error-feedback residuals -- 4x less ICI traffic on the
slowest (cross-pod) links at <1% quality cost (error feedback keeps the
quantization bias out of the trajectory).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.tiles import shard_map
from repro.models import common as cm
from repro.models import lm
from repro.training import optim as opt_mod


def _split_microbatches(batch, accum: int):
    """(B, ...) -> (accum, B/accum, ...) for every array in the batch."""
    return jax.tree.map(
        lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
    )


def make_loss_and_grad(spec: lm.LMSpec, rules, accum: int = 1):
    def loss_fn(params, batch):
        loss, metrics = lm.loss_fn(spec, params, batch, rules=rules)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accum_grads(params, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        micro = _split_microbatches(batch, accum)

        def body(carry, mb):
            loss_acc, grads_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / accum, grads_acc, grads
            )
            return (loss_acc + loss / accum, grads_acc), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), metrics = lax.scan(body, (jnp.zeros(()), zeros), micro)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    return accum_grads


# ---------------------------------------------------------------------------
# int8 error-feedback compression for the cross-pod gradient sync
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compressed_pod_allreduce(grads, ef_state, axis: str = "pod"):
    """Mean-all-reduce over ``axis`` with int8 + error feedback.

    Must run inside a shard_map manual over ``axis``.  ef_state is the
    per-pod residual pytree (same shapes as grads, fp32).
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        new_e = g32 - deq  # residual stays pod-local
        synced = lax.pmean(deq, axis)
        return synced.astype(g.dtype), new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def make_train_step(
    spec: lm.LMSpec,
    mesh: Mesh,
    opt_cfg: opt_mod.OptConfig,
    *,
    rules=None,
    accum: int = 1,
    donate: bool = True,
):
    """Returns (jit_step, param_specs, opt_specs, batch_spec).

    Specs are divisibility-sanitized against the mesh (jit in_shardings must
    divide exactly), and the rules passed to the model carry the mesh axis
    sizes so activation constraints self-sanitize too.
    """
    rules = rules or (cm.multipod_rules() if "pod" in mesh.axis_names else cm.DEFAULT_RULES)
    rules = cm.arch_rules(spec.cfg, rules)
    rules = cm.attach_axis_sizes(rules, mesh)
    pshape = jax.eval_shape(partial(lm.init_params, spec), jax.random.PRNGKey(0))
    pspecs = cm.sanitize_specs(lm.param_specs(spec, rules), pshape, mesh)
    opt_init, opt_update = opt_mod.make_optimizer(opt_cfg)
    accum_grads = make_loss_and_grad(spec, rules, accum)
    batch_spec = cm.logical_to_spec(("batch", "seq"), rules)

    def step(params, opt_state, batch):
        loss, metrics, grads = accum_grads(params, batch)
        grads, gnorm = opt_mod.clip_by_global_norm(grads, opt_cfg.clip_norm)
        params, opt_state = opt_update(grads, opt_state, params)
        metrics = {**metrics, "loss": loss, "grad_norm": gnorm}
        return params, opt_state, metrics

    # optimizer state specs mirror (sanitized) param specs elementwise;
    # adafactor factored states drop the last/second-last entry, which keeps
    # divisibility (same dims as the param prefix).
    if opt_cfg.name == "adamw":
        ospecs = opt_mod.adamw_state_specs(pspecs)
    else:
        ospecs = opt_mod.adafactor_state_specs(pspecs, pshape)

    jit_step = jax.jit(
        step,
        in_shardings=(
            _named(mesh, pspecs),
            _named(mesh, ospecs),
            None,  # batch: caller-placed (batch_spec returned for that)
        ),
        out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs), None),
        donate_argnums=(0, 1) if donate else (),
    )
    return jit_step, pspecs, ospecs, batch_spec


def _named(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def make_compressed_train_step(
    spec: lm.LMSpec,
    mesh: Mesh,
    opt_cfg: opt_mod.OptConfig,
    *,
    rules=None,
    accum: int = 1,
):
    """Multi-pod train step with int8 error-feedback cross-pod grad sync.

    The whole loss+grad+update runs inside a shard_map that is MANUAL over
    "pod" and AUTO over ("data","model"): each pod computes grads on its own
    batch shard (no implicit cross-pod psum -- params are pod-replicated),
    the sync happens explicitly on int8-quantized grads (4x less traffic on
    the slowest links), and error-feedback residuals (per-pod state with a
    leading pod axis) carry the rounding into the next step.

    step(params, opt_state, batch, ef_state) ->
        (params, opt_state, metrics, ef_state)

    .. warning:: EXPERIMENTAL on the CPU backend: XLA's SPMD partitioner
       aborts (C++ CHECK, spmd_partitioner_util.cc:504) partitioning gathers
       inside partial-manual regions -- the same class of issue as XLA's
       b/433785288, slated for the Shardy partitioner.  The compressed
       collective itself is validated in full-manual shard_map
       (tests/test_sharding.py::test_compressed_pod_allreduce).
    """
    if "pod" not in mesh.axis_names:
        raise ValueError("compressed sync needs a 'pod' mesh axis")
    rules = rules or cm.multipod_rules()
    rules = cm.arch_rules(spec.cfg, rules)
    # inside the pod-manual region the pod axis is gone from auto sharding:
    inner_rules = dict(rules)
    inner_rules["batch"] = tuple(a for a in rules["batch"] if a != "pod") or ("data",)
    inner_rules["batch_inner"] = inner_rules["batch"]
    # XLA SPMD crashes partitioning sharded-operand gathers inside
    # partial-manual regions (spmd_partitioner_util.cc:504); keep the
    # embedding table replicated inside this step (documented memory cost).
    inner_rules["vocab"] = None
    inner_rules["embed_d"] = None
    inner_rules = cm.attach_axis_sizes(inner_rules, mesh)
    pshape = jax.eval_shape(partial(lm.init_params, spec), jax.random.PRNGKey(0))
    pspecs = cm.sanitize_specs(lm.param_specs(spec, inner_rules), pshape, mesh)
    opt_init, opt_update = opt_mod.make_optimizer(opt_cfg)
    accum_grads = make_loss_and_grad(spec, inner_rules, accum)
    n_pods = mesh.shape["pod"]

    def local(params, opt_state, batch, ef):
        ef = jax.tree.map(lambda e: e[0], ef)  # strip the pod-shard axis
        loss, metrics, grads = accum_grads(params, batch)
        grads, ef = compressed_pod_allreduce(grads, ef, axis="pod")
        grads, gnorm = opt_mod.clip_by_global_norm(grads, opt_cfg.clip_norm)
        params, opt_state = opt_update(grads, opt_state, params)
        loss = lax.pmean(loss, "pod")
        metrics = jax.tree.map(lambda m: lax.pmean(m, "pod"), metrics)
        metrics = {**metrics, "loss": loss, "grad_norm": gnorm}
        ef = jax.tree.map(lambda e: e[None], ef)  # restore the pod axis
        return params, opt_state, metrics, ef

    ef_spec = jax.tree.map(lambda _: P("pod"), pshape)
    step = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(), P("pod"), ef_spec),
        out_specs=(P(), P(), P(), ef_spec),
        axis_names={"pod"},
        check=False,
    )

    def ef_init(params):
        return jax.tree.map(
            lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params
        )

    return jax.jit(step, donate_argnums=(0, 1, 3)), ef_init, pspecs


def init_state(spec: lm.LMSpec, mesh: Mesh, opt_cfg: opt_mod.OptConfig, seed: int = 0, *, rules=None):
    """Initialize params + optimizer state directly sharded on the mesh."""
    rules = rules or (cm.multipod_rules() if "pod" in mesh.axis_names else cm.DEFAULT_RULES)
    rules = cm.arch_rules(spec.cfg, rules)
    rules = cm.attach_axis_sizes(rules, mesh)
    pshape = jax.eval_shape(partial(lm.init_params, spec), jax.random.PRNGKey(0))
    pspecs = cm.sanitize_specs(lm.param_specs(spec, rules), pshape, mesh)
    opt_init, _ = opt_mod.make_optimizer(opt_cfg)

    with mesh:
        params = jax.jit(
            partial(lm.init_params, spec), out_shardings=_named(mesh, pspecs)
        )(jax.random.PRNGKey(seed))
        opt_state = jax.jit(opt_init)(params)
    return params, opt_state
