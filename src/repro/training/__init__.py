"""Training substrate: optimizers, train step, checkpointing, fault tolerance."""

from repro.training.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.training.optim import OptConfig, make_optimizer
from repro.training.train_step import init_state, make_train_step
from repro.training.watchdog import FailureInjector, InjectedFailure, StepTimer, StragglerWatchdog

__all__ = [
    "AsyncCheckpointer",
    "FailureInjector",
    "InjectedFailure",
    "OptConfig",
    "StepTimer",
    "StragglerWatchdog",
    "init_state",
    "latest_step",
    "make_optimizer",
    "make_train_step",
    "restore",
    "save",
]
