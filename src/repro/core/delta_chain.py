"""Incremental delta-chain updates: skip the O(n^3) rebuild on small drift.

A slowly-drifting transition changes the chain operator by a *small-norm*
perturbation: Online Anomaly Detection Systems Using Incremental Commute Time
(arXiv:1107.3894) shows commute-time quantities admit incremental updates
under such perturbations, and the Rademacher-sketch machinery already used by
``edge_projection`` (Khoa & Chawla, arXiv:1111.4541) gives the low-rank
compression primitive.  This module implements that path for the squaring
chain:

1. **Sketch** ``dS = S~' - S~`` against a counter-generated Rademacher test
   matrix (never materializing dS): a randomized range-finder compresses it
   to a rank-r factorization ``U0 V0^T``.  The same sketch yields the *drift
   monitor* ``||dS W||_F / ||S~ W||_F`` for free.
2. **Propagate** the correction through the squaring recurrence.  With
   ``T_l = T_{l-1}^2`` and ``P_l = P_{l-1}(I + T_l)`` (all T_l symmetric,
   powers of S commute):

       dT_l = [T U, U] [V, T V + V (U^T V)]^T               (rank 2r)
       dP_l = [E, P Ut + E (F^T Ut)] [F + T_l F, Vt]^T      (rank 2r)

   where (U, V) = dT_{l-1}, (E, F) = dP_{l-1}, (Ut, Vt) = dT_l -- every
   product against the *base* chain is a skinny n x r panel GEMM through
   :func:`repro.core.distmatrix.matmul_rowblock` (streams store-backed base
   levels through the panel pipeline; resident bases use one eager dot), so
   a level costs O(n^2 r) instead of the rebuild's O(n^3).  Each level
   recompresses 2r -> r via an exact QR + small-SVD factor truncation.
3. **Correct the operator.**  ``P1' = diag(s) P1 diag(s) + E~ F~^T`` is
   *exact* (s = sqrt(deg) * 1/sqrt(deg'), E~ = D'^{-1/2} E); ``dP2 =
   P1' L' - P1 L`` is compressed by a two-pass range-finder on its implicit
   forward/adjoint applies (the base ``L`` mat-vec is reconstructed from the
   retained T_0 = S~, so no base adjacency is kept).  The corrected
   :class:`~repro.core.chain.ChainOperator` carries ``(p1_scale, u1, v1,
   u2, v2)`` -- every solver method and the fused streamed kernel pass apply
   them as cheap rank-r epilogues around the unchanged base mat-vec.

All dense-factor algebra here runs eagerly (host numpy for the O(n r^2)
QR/SVD pieces, ``matmul_rowblock`` for the n^2 passes), so the delta path
adds ZERO tile-program traces; the only new compiled program is the
corrected resident solve loop, keyed once per correction rank.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import laplacian as lap
from repro.core import rng as crng
from repro.core.chain import ChainOperator, chain_product
from repro.core.distmatrix import DistContext, matmul_rowblock
from repro.core.tiles import is_streamable
from repro.obs.metrics import REGISTRY as _OBS_REGISTRY

# Range-finder oversampling: the sketch width is delta_rank + DELTA_OVERSAMPLE
# columns; the extra columns absorb the tail so the leading r directions are
# captured accurately (Halko/Martinsson/Tropp's standard few-column margin).
DELTA_OVERSAMPLE = 2


# ---------------------------------------------------------------------------
# logical GEMM accounting (the counters the >= 3x acceptance bar reads)
# ---------------------------------------------------------------------------


class _GemmLedger:
    """Logical FLOP/byte counts for chain-phase GEMM passes.

    One convention everywhere (fp32, counted at dispatch, not measured -- the
    point is a stable apples-to-apples ratio between the rebuild and the
    delta path):

    * ``flops``: a full (n, n) x (n, n) GEMM is ``2 n^3``; a skinny
      (n, n) x (n, w) pass is ``2 n^2 w``.
    * ``bytes``: operand + result traffic -- ``3 n^2 * 4`` for the full GEMM,
      ``(n^2 + 2 n w) * 4`` for a skinny pass.  Note a skinny pass still
      *reads* its n^2 operand once, so this metric shrinks only ~linearly
      with pass count, not with width.
    * ``scratch``: bytes of chain scratch *materialized* -- the full build
      writes a fresh n^2 matrix per GEMM (the T/P levels, P1, P2, all of
      which the out-of-core build spills to the scratch store), ``n^2 * 4``
      each; a skinny pass writes only its (n, w) result block, ``n w * 4``.
      This is the residency/spill axis the incremental path collapses.
    """

    def __init__(self) -> None:
        self.flops = 0.0
        self.bytes = 0.0
        self.scratch = 0.0

    def skinny(self, n: int, w: int) -> None:
        self.flops += 2.0 * n * n * w
        self.bytes += (n * n + 2.0 * n * w) * 4.0
        self.scratch += n * w * 4.0


def full_build_gemm_cost(n: int, d_len: int) -> tuple[float, float, float]:
    """(flops, bytes, scratch) of one full chain build.

    ``2 (d-1) + 1`` dense n x n GEMMs (d-1 squarings, d-1 P updates, one
    P1 @ L); scratch additionally counts the S~ assembly, so ``2 d`` fresh
    n^2 matrices are materialized overall.
    """
    gemms = 2 * (d_len - 1) + 1
    return (
        gemms * 2.0 * n**3,
        gemms * 3.0 * n * n * 4.0,
        (gemms + 1) * n * n * 4.0,
    )


# ---------------------------------------------------------------------------
# base-chain retention
# ---------------------------------------------------------------------------


@dataclass
class BaseChain:
    """A full chain build plus the retained per-level factors deltas need.

    ``t_levels`` holds T_0 .. T_{d-1} (T_0 = S~); ``p_levels`` holds
    P_1 .. P_{d-2} (P_0 = I + T_0 is applied implicitly, the final P_{d-1}
    is never needed).  Arrays or store-backed handles, matching the build.
    ``op`` is the base operator with ``shared_base=True`` stamped on it, so
    the sequence engine's per-snapshot ``release_scratch()`` cannot retire
    scratch that corrected operators still stream; :meth:`release` is the
    one place the base scratch actually dies.
    """

    op: ChainOperator
    t_levels: list = field(default_factory=list)
    p_levels: list = field(default_factory=list)
    d_len: int = 1
    deflate: bool = True
    released: bool = False

    def release(self) -> None:
        """Retire the base: operator scratch plus every retained level.

        Idempotent -- a second release is a no-op, never a double-free (the
        regression the shared-base lifecycle audit guards).
        """
        if self.released:
            return
        self.released = True
        self.op.shared_base = False
        self.op.release_scratch()
        for buf in (*self.t_levels, *self.p_levels):
            store = getattr(buf, "store", None)
            if store is not None and hasattr(buf, "snap_id"):
                try:
                    store.remove_snapshot(buf.snap_id)
                except (OSError, ValueError, KeyError) as e:
                    warnings.warn(
                        f"BaseChain.release: could not remove retained level "
                        f"{buf.snap_id!r} ({e!r})",
                        RuntimeWarning,
                        stacklevel=2,
                    )
        self.t_levels, self.p_levels = [], []


def build_base_chain(
    ctx: DistContext, a, cfg, *, use_kernel: bool = False
) -> BaseChain:
    """Full chain build that also retains the levels delta updates multiply
    against.  Counts one ``chain.full_rebuilds`` (the drift monitor's
    fallback lands here too, so rebuild-vs-incremental is one registry pair).
    """
    sink: dict = {}
    op = chain_product(
        ctx,
        a,
        cfg.d,
        schedule=cfg.schedule,
        dtype=cfg.dtype,
        deflate=cfg.deflate,
        fuse_l=cfg.fuse_l,
        use_kernel=use_kernel,
        oocore=cfg.oocore,
        oocore_work=cfg.oocore_dir,
        oocore_panel_rows=cfg.oocore_panel_rows,
        tile_codec=cfg.tile_codec,
        prefetch_depth=cfg.prefetch_depth,
        use_gemm_kernel=cfg.use_gemm_kernel,
        level_sink=sink,
    )
    op.shared_base = True
    _OBS_REGISTRY.add_named({"chain.full_rebuilds": 1.0})
    return BaseChain(
        op=op,
        t_levels=list(sink.get("t", ())),
        p_levels=list(sink.get("p", ())),
        d_len=cfg.d,
        deflate=cfg.deflate,
    )


# ---------------------------------------------------------------------------
# small host-side factor algebra
# ---------------------------------------------------------------------------


def truncate_factors(
    u: np.ndarray, v: np.ndarray, r: int
) -> tuple[np.ndarray, np.ndarray]:
    """Best rank-r recompression of ``u @ v.T`` (exact, O(n r^2)).

    QR both factors, SVD the small core: ``u v^T = qu (ru rv^T) qv^T``;
    keeping the top r singular triplets of the core is the optimal rank-r
    approximation of the product itself.
    """
    qu, ru = np.linalg.qr(u.astype(np.float64))
    qv, rv = np.linalg.qr(v.astype(np.float64))
    w, s, zt = np.linalg.svd(ru @ rv.T)
    rr = min(int(r), s.size)
    u_t = qu @ (w[:, :rr] * s[:rr])
    v_t = qv @ zt[:rr].T
    return u_t.astype(np.float32), v_t.astype(np.float32)


def _rademacher_omega(n: int, m: int, seed: int) -> np.ndarray:
    """(n, m) +/-1 test matrix from the counter-based hash (zero stored
    randomness, deterministic across hosts -- same contract as the edge
    projection's Rademacher field)."""
    rows = jnp.arange(n, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(m, dtype=jnp.uint32)[None, :]
    h = crng.hash_u32(np.uint32(int(seed) & 0xFFFFFFFF), rows, cols)
    return np.asarray(1.0 - 2.0 * (h >> 31).astype(jnp.float32), np.float32)


# ---------------------------------------------------------------------------
# the incremental update
# ---------------------------------------------------------------------------


class _Passes:
    """Skinny-GEMM passes against big operands, with ledger accounting."""

    def __init__(self, ctx: DistContext, depth, ledger: _GemmLedger):
        self.ctx = ctx
        self.depth = depth
        self.ledger = ledger

    def mm(self, mat, x_np: np.ndarray) -> np.ndarray:
        """mat @ x for an (n, w) host operand; mat is resident or a handle."""
        n, w = int(mat.shape[0]), int(x_np.shape[1])
        self.ledger.skinny(n, w)
        x = self.ctx.put_rowblock(jnp.asarray(x_np, jnp.float32))
        out = matmul_rowblock(self.ctx, mat, x, prefetch_depth=self.depth)
        return np.asarray(out, np.float32)


def try_delta_update(
    ctx: DistContext, base: BaseChain, a, cfg
) -> ChainOperator | None:
    """Corrected operator for snapshot ``a`` against ``base``, or ``None``.

    ``None`` means the sketched drift ``||dS W||_F / ||S~ W||_F`` exceeded
    ``cfg.delta_budget`` and the caller must rebuild.  Deltas are always
    measured against the *last full rebuild* (never chained delta-on-delta),
    so the same budget bounds both per-transition drift and accumulated
    drift, and incremental error cannot compound across transitions.
    """
    n = int(a.shape[0])
    r = int(cfg.delta_rank)
    m = r + DELTA_OVERSAMPLE
    depth = cfg.prefetch_depth
    ledger = _GemmLedger()
    ps = _Passes(ctx, depth, ledger)

    t_lv, p_lv = base.t_levels, base.p_levels
    if len(t_lv) != base.d_len:
        raise ValueError(
            f"base chain retained {len(t_lv)} T levels for d={base.d_len}; "
            f"was it built with build_base_chain()?"
        )

    # -- current snapshot's degree data (needed by the corrected op anyway) --
    deg_new = lap.degrees(ctx, a, prefetch_depth=depth)
    vol_new = lap.volume(ctx, deg_new)
    deg_n = np.asarray(deg_new, np.float64)
    vol_n = float(vol_new)
    inv_sqrt_n = np.where(deg_n > 0, 1.0 / np.sqrt(np.maximum(deg_n, 1e-30)), 0.0)
    deg_b = np.asarray(base.op.deg, np.float64)
    vol_b = float(base.op.vol)
    sqrt_b = np.sqrt(np.maximum(deg_b, 0.0))

    def s_new(x: np.ndarray) -> np.ndarray:
        """S~' x from the raw snapshot: D'^{-1/2} A' D'^{-1/2} x (- u' u'^T x)."""
        y = inv_sqrt_n[:, None] * ps.mm(a, (inv_sqrt_n[:, None] * x).astype(np.float32))
        if base.deflate:
            u = np.sqrt(np.maximum(deg_n, 0.0) / max(vol_n, 1e-30))
            y = y - u[:, None] * (u @ x)
        return y.astype(np.float32)

    # -- 1. sketch dS and measure drift -------------------------------------
    omega = _rademacher_omega(n, m, cfg.seed + 0x5EED)
    s_base_w = ps.mm(t_lv[0], omega)  # S~ W (base, retained T_0)
    s_new_w = s_new(omega)  # S~' W (implicit, from the raw snapshot)
    dy = s_new_w - s_base_w
    base_norm = max(float(np.linalg.norm(s_base_w)), 1e-30)
    drift = float(np.linalg.norm(dy)) / base_norm
    _OBS_REGISTRY.append("chain.drift", drift)
    _OBS_REGISTRY.set_gauge("chain.drift_last", drift)
    if drift > float(cfg.delta_budget):
        _OBS_REGISTRY.add_named({"chain.drift_fallbacks": 1.0})
        return None

    # Range-finder: dS ~= Q (dS Q)^T (dS symmetric).  Zero drift (identical
    # snapshot) short-circuits to an empty-correction operator via rank-0
    # factors -- the truncation below handles the degenerate SVD fine.
    q, _ = np.linalg.qr(dy.astype(np.float64))
    q = q.astype(np.float32)
    w0 = s_new(q) - ps.mm(t_lv[0], q)  # dS Q
    u_t, v_t = truncate_factors(q, w0, r)  # dT_0 = dS ~= u_t v_t^T

    # -- 2. propagate through the squaring recurrence ------------------------
    e_f, f_f = u_t.copy(), v_t.copy()  # dP_0 = dS (P_0 = I + T_0)
    for lvl in range(1, base.d_len):
        # dT_lvl from dT_{lvl-1}: one width-2r pass against base T_{lvl-1}
        uv = ps.mm(t_lv[lvl - 1], np.concatenate([u_t, v_t], axis=1))
        tu, tv = uv[:, : u_t.shape[1]], uv[:, u_t.shape[1] :]
        u2r = np.concatenate([tu, u_t], axis=1)
        v2r = np.concatenate([v_t, tv + v_t @ (u_t.T @ v_t)], axis=1)
        ut_new, vt_new = truncate_factors(u2r, v2r, r)
        # dP_lvl: P_{lvl-1} @ Ut (P_0 applied implicitly as I + T_0)
        if lvl == 1:
            pu = ut_new + ps.mm(t_lv[0], ut_new)
        else:
            pu = ps.mm(p_lv[lvl - 2], ut_new)
        tf = ps.mm(t_lv[lvl], f_f)  # T_lvl @ F
        e2r = np.concatenate([e_f, pu + e_f @ (f_f.T @ ut_new)], axis=1)
        f2r = np.concatenate([f_f + tf, vt_new], axis=1)
        e_f, f_f = truncate_factors(e2r, f2r, r)
        u_t, v_t = ut_new, vt_new

    # -- 3. corrected P1 (exact): diag(s) P1 diag(s) + E~ F~^T ---------------
    p1_scale = (sqrt_b * inv_sqrt_n).astype(np.float32)
    u1 = (inv_sqrt_n[:, None] * e_f).astype(np.float32)
    v1 = (inv_sqrt_n[:, None] * f_f).astype(np.float32)

    def p1_corr(x: np.ndarray) -> np.ndarray:
        """P1' x through the base P1 plus the exact correction."""
        y = p1_scale[:, None] * ps.mm(
            base.op.p1, (p1_scale[:, None] * x).astype(np.float32)
        )
        return (y + u1 @ (v1.T @ x)).astype(np.float32)

    def l_new(x: np.ndarray) -> np.ndarray:
        """L' x = deg' . x - A' x from the raw snapshot."""
        return (deg_n[:, None] * x - ps.mm(a, x)).astype(np.float32)

    def l_base(x: np.ndarray) -> np.ndarray:
        """Base L x reconstructed from retained T_0 (no base adjacency kept):
        A = D^{1/2} (T_0 [+ u u^T]) D^{1/2} with u = sqrt(deg / V_G)."""
        ax = sqrt_b[:, None] * ps.mm(t_lv[0], (sqrt_b[:, None] * x).astype(np.float32))
        if base.deflate:
            du = deg_b / max(np.sqrt(max(vol_b, 1e-30)), 1e-30)  # sqrt(d) . u
            ax = ax + du[:, None] * (du @ x)
        return (deg_b[:, None] * x - ax).astype(np.float32)

    # -- 4. dP2 = P1' L' - P1 L via a two-pass range-finder ------------------
    omega2 = _rademacher_omega(n, m, cfg.seed + 0xD2)
    fwd = p1_corr(l_new(omega2)) - ps.mm(base.op.p2, omega2)
    q2, _ = np.linalg.qr(fwd.astype(np.float64))
    q2 = q2.astype(np.float32)
    # adjoint on Q: dP2^T q = L'(P1' q) - L(P1 q); the two base-P1 products
    # share one width-2m pass over P1.
    both = ps.mm(
        base.op.p1, np.concatenate([p1_scale[:, None] * q2, q2], axis=1)
    )
    p1q_scaled, p1q = both[:, : q2.shape[1]], both[:, q2.shape[1] :]
    p1c_q = p1_scale[:, None] * p1q_scaled + u1 @ (v1.T @ q2)
    v2_full = l_new(p1c_q) - l_base(p1q)
    u2, v2 = truncate_factors(q2, v2_full, r)

    _OBS_REGISTRY.add_named({
        "chain.incremental_updates": 1.0,
        "chain.gemm_flops": ledger.flops,
        "chain.gemm_bytes": ledger.bytes,
        "chain.scratch_bytes": ledger.scratch,
        "chain.delta_gemm_flops": ledger.flops,
        "chain.delta_gemm_bytes": ledger.bytes,
    })

    rb = ctx.sharding(ctx.rowblock_spec)
    return ChainOperator(
        p1=base.op.p1,
        p2=base.op.p2,
        deg=deg_new,
        vol=vol_new,
        prefetch_depth=base.op.prefetch_depth,
        # Keep the base interval bound: corrected spectra move by O(||dS||)
        # and both Chebyshev (Manteuffel adaptation, PR 8) and CG are robust
        # to a slightly stale rho; re-measuring would cost power iterations
        # per transition, defeating the delta path's point.
        rho=base.op.rho,
        use_gemm_kernel=base.op.use_gemm_kernel,
        p1_scale=jax.device_put(
            jnp.asarray(p1_scale), ctx.sharding(jax.sharding.PartitionSpec(None))
        ),
        u1=jax.device_put(jnp.asarray(u1), rb),
        v1=jax.device_put(jnp.asarray(v1), rb),
        u2=jax.device_put(jnp.asarray(u2), rb),
        v2=jax.device_put(jnp.asarray(v2), rb),
        shared_base=True,
    )
