"""Solver contract: :class:`SolverSpec` (what to run) / :class:`SolveReport`
(what happened).

The spec is deliberately tiny and hashable -- it selects a *method* from the
registry in :mod:`repro.core.solvers.driver` and a *stopping rule*:

* ``tolerance`` -- stop when the relative preconditioned residual
  ``||Z^(b - L y)|| / ||Z^ b||`` drops below it (the solver's natural,
  free-to-measure convergence metric: for Richardson it IS the step just
  taken).  Khoa & Chawla (arXiv:1111.4541) frame the commute-time solve as
  solve-to-epsilon rather than solve-for-q-iterations; this is that knob.
* ``max_iters`` -- a hard cap on refinement steps (one P2 mat-vec each).
* ``delta`` -- the paper's accuracy parameter: Algorithm 2 runs
  ``q = ceil(log 1/delta)`` Richardson iterations.  When no explicit cap is
  given, the cap is derived from delta exactly that way.

Every solve returns a :class:`SolveReport` alongside the solution, so
consumers (the sequence engine, the CLI) can surface per-transition solver
telemetry -- iterations, final residual, scratch bytes streamed -- instead of
assuming worst-case behaviour.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

METHODS = ("richardson", "chebyshev", "cg")

# Paper default: delta = 1e-4 gives q = ceil(ln 1e4) = 10, matching the
# CommuteConfig default q.
DEFAULT_DELTA = 1e-4

# Safety cap when only a tolerance is given: a tolerance the operator cannot
# reach (rho too close to 1) must terminate, and the report says so.
TOLERANCE_ITER_CAP = 300


def iters_from_delta(delta: float) -> int:
    """The paper's iteration count: q = ceil(log 1/delta), total iterations
    (the initial ``chi = Z^ b`` application counts as the first)."""
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    return max(1, math.ceil(math.log(1.0 / delta)))


@dataclass(frozen=True)
class SolverSpec:
    """Which iterative method to run, and when to stop.

    ``max_iters`` counts *refinement steps* (P2 mat-vecs after the initial
    ``y0 = chi``); the paper's q corresponds to ``max_iters + 1``.  Precedence
    for the step bound: explicit ``max_iters`` > ``delta``-derived
    ``q(delta) - 1`` > ``TOLERANCE_ITER_CAP`` (tolerance-only specs) > the
    caller's fixed q.
    """

    method: str = "richardson"
    tolerance: float | None = None  # relative pseudo-residual target
    max_iters: int | None = None  # cap on refinement steps (P2 mat-vecs)
    delta: float | None = None  # paper delta; derives the cap when max_iters unset

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"unknown solver {self.method!r}; want one of {METHODS}")
        if self.tolerance is not None and self.tolerance <= 0:
            raise ValueError(f"tolerance must be > 0, got {self.tolerance}")
        if self.max_iters is not None and self.max_iters < 0:
            raise ValueError(f"max_iters must be >= 0, got {self.max_iters}")
        if self.delta is not None and not 0.0 < self.delta < 1.0:
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")

    def max_steps(self, fixed_q: int | None = None) -> int:
        """Resolved refinement-step bound for this spec (see class docstring)."""
        if self.max_iters is not None:
            return self.max_iters
        if self.delta is not None:
            return max(1, iters_from_delta(self.delta) - 1)
        if self.tolerance is not None:
            return TOLERANCE_ITER_CAP
        if fixed_q is not None:
            if fixed_q < 1:
                raise ValueError("q must be >= 1")
            return fixed_q - 1
        return max(1, iters_from_delta(DEFAULT_DELTA) - 1)


@dataclass
class SolveReport:
    """Telemetry from one driver solve (one batch of k_RP right-hand sides).

    ``residual`` is the relative preconditioned residual
    ``||Z^(b - L y)||_F / ||Z^ b||_F`` of the last *measured* iterate (the
    stopping metric); ``bytes_read`` / ``panels`` are the scratch-store bytes
    served and panels staged during this solve (zero for resident operators
    -- nothing streams).
    """

    method: str
    iterations: int  # refinement steps taken (P2 mat-vecs)
    residual: float  # NaN when the run measured no residual (zero iterations)
    converged: bool  # residual <= tolerance; always False when no residual was measured
    tolerance: float | None
    max_iters: int  # the resolved step bound the run was given
    streamed: bool  # True when P1/P2 were store-backed (out-of-core solve)
    rho: float | None = None  # Chebyshev interval bound the run started from
    bytes_read: int = 0  # scratch bytes served during the solve
    panels: int = 0  # panels staged during the solve
    bytes_h2d: int = 0  # host-to-device bytes staged during the solve
    residuals: tuple = ()  # per-iteration residual series (stopping metric)
    # Chebyshev interval after Manteuffel-style adaptation (== rho when the
    # measured contraction never missed the predicted rate); None for methods
    # that carry no interval.
    rho_final: float | None = None
    warm_start: bool = False  # y0 seeded from a previous solution

    def summary(self) -> str:
        """One-line telemetry, e.g. for the CLI's per-transition printout."""
        tol = f" tol={self.tolerance:.1e}" if self.tolerance is not None else ""
        conv = "" if self.converged else " NOT-CONVERGED"
        io = f", {self.bytes_read / 1e6:.1f} MB scratch" if self.streamed else ""
        warm = " warm" if self.warm_start else ""
        return (
            f"{self.method}{warm}: {self.iterations} its{tol}, "
            f"res {self.residual:.1e}{conv}{io}"
        )
