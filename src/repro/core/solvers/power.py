"""Power-iteration estimate of rho(S~^{2^d}) -- the Richardson contraction.

The preconditioned Richardson iteration contracts with the iteration matrix
``G = I - Z^ L``, which (by the telescoping identity ``(I - S~) P = I -
S~^{2^d}``) is ``D^{-1/2} S~^{2^d} D^{1/2}`` -- similar to the symmetric
``S~^{2^d}``, so its spectrum is real, and for d >= 1 the exponent ``2^d`` is
even, so it is also nonnegative: ``spec(G) in [0, rho]`` on the 1-orthogonal
subspace with ``rho = rho(S~^{2^d}) = lambda_2^{2^d} < 1``.

``rho`` is exactly what the Chebyshev accelerator needs (the eigenvalue
interval ``[0, rho]`` of the underlying stationary iteration) and what turns
the paper's worst-case ``q = ceil(log 1/delta)`` into a measured bound --
von Luxburg et al. (arXiv:1003.1266) show the spectral regime, not the
iteration count, is what governs commute-time estimate quality.  Estimating
it costs a handful of ``G v`` mat-vecs against the already-built P2, so the
chain build computes it once and caches it on the operator
(:class:`repro.core.chain.ChainOperator.rho`).

All ops here are eager (no tile-program bodies, no jitted closures), so the
estimate adds zero entries to the program cache and zero body retraces.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.distmatrix import DistContext, matmul_rowblock
from repro.core.tiles import is_streamable

DEFAULT_POWER_ITERS = 16


def estimate_rho(
    ctx: DistContext,
    p2,
    *,
    iters: int = DEFAULT_POWER_ITERS,
    seed: int = 0,
    prefetch_depth: int | None = None,
) -> float:
    """Spectral-radius estimate of ``G = I - P2`` on the 1-orthogonal subspace.

    Plain power iteration with per-step mean deflation (the Laplacian
    nullspace direction is projected out, exactly as the solver's
    ``deflate_constant`` does), normalized every step; the returned value is
    the final norm ratio ``||G v|| / ||v||``, clamped to ``[0, 0.999]``.

    ``p2`` may be a store-backed handle (an out-of-core chain's P2): the
    mat-vecs then stream, and a :class:`repro.store.CachingHandle` wrap makes
    the whole estimate cost ONE real scratch pass -- the remaining iterations
    replay decoded panels from host RAM.
    """
    if iters < 1:
        raise ValueError(f"power iters must be >= 1, got {iters}")
    n = int(p2.shape[0])
    rng = np.random.default_rng(seed)
    v0 = rng.normal(size=(n, 1)).astype(np.float32)
    v0 -= v0.mean(axis=0, keepdims=True)
    v0 /= max(float(np.linalg.norm(v0)), 1e-30)
    v = ctx.put_rowblock(v0)

    handle = p2
    if is_streamable(p2):
        from repro.store import CachingHandle  # deferred: optional oocore path

        handle = CachingHandle(p2)

    # All iterations stay on device (the norm is a device scalar); the single
    # host sync is the final float() below, so the estimate costs mat-vec
    # dispatches, not per-step round-trips.
    nrm = None
    for _ in range(iters):
        gv = v - matmul_rowblock(ctx, handle, v, prefetch_depth=prefetch_depth)
        gv = gv - jnp.mean(gv.astype(jnp.float32), axis=0, keepdims=True)
        nrm = jnp.sqrt(jnp.sum(gv.astype(jnp.float32) ** 2))
        v = ctx.constrain(
            (gv / jnp.maximum(nrm, 1e-30)).astype(jnp.float32), ctx.rowblock_spec
        )
    rho = float(nrm)  # ||G v|| with ||v|| == 1
    if not np.isfinite(rho) or rho < 1e-12:
        # G annihilated the iterate along the way (e.g. a long chain on a
        # well-separated graph): the contraction is effectively zero.
        return 0.0
    return float(min(rho, 0.999))
