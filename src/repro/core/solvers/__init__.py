"""Pluggable iterative-solver subsystem for the chain-operator solve.

Public API re-exports: :class:`SolverSpec` / :class:`SolveReport` (the
contract), :func:`solve` (the unified driver owning resident-vs-streamed
branching), :func:`estimate_rho` (the power-iteration contraction estimate
cached on :class:`repro.core.chain.ChainOperator`).
"""

from repro.core.solvers.base import (
    DEFAULT_DELTA,
    METHODS,
    TOLERANCE_ITER_CAP,
    SolveReport,
    SolverSpec,
    iters_from_delta,
)
from repro.core.solvers.driver import deflate_constant, solve
from repro.core.solvers.power import estimate_rho

__all__ = [
    "DEFAULT_DELTA",
    "METHODS",
    "TOLERANCE_ITER_CAP",
    "SolveReport",
    "SolverSpec",
    "deflate_constant",
    "estimate_rho",
    "iters_from_delta",
    "solve",
]
