"""Unified solve driver: one place that owns resident-vs-streamed branching.

Every consumer of the chain operator (the commute-time embedding, the legacy
``estimate_solution`` shim, benchmarks) solves through :func:`solve`:

* **resident** operators run a single cached ``jax.jit(lax.while_loop)``
  program per (method, mesh, geometry): the tolerance, the step cap, the
  Chebyshev interval bound and the warm-start iterate all enter as
  *operands*, so a steady-state ``SequenceDetector.push`` -- or a tolerance
  change between solves, or switching between cold and warm starts -- adds
  zero traces and zero program-cache misses;
* **streamed** operators (store-backed P1/P2 from an out-of-core chain) run a
  host Python loop -- a traced loop body cannot fetch panels -- reusing the
  :class:`repro.store.CachingHandle` iteration batching (stream the scratch
  once per ``solver_batch`` iterations, replay from host RAM) and the panel
  pipeline's ``prefetch_depth`` staging.

Both paths stop on the same metric: the relative preconditioned residual
``||Z^(b - L y)||_F / ||Z^ b||_F``, which is free to measure (for Richardson
it *is* the step just taken) and bounds the true error by ``1/(1 - rho)``.
The denominator is always ``||Z^ b||`` -- in particular it does NOT become
``||Z^(b - L y0)||`` under a warm start, so a tolerance keeps exactly the
same meaning whether the solve starts cold (``y0 = chi``) or from a previous
snapshot's solution.  Adding a method means adding one iteration rule here;
the registry below is the whole surface.

Methods:

* ``richardson`` -- the paper's Algorithm 2 iteration ``y <- y + Z^(b - L y)``,
  now with residual-targeted stopping instead of always paying the worst-case
  ``q = ceil(log 1/delta)``.
* ``chebyshev`` -- classical Chebyshev semi-iterative acceleration (Golub &
  Varga; Hageman & Young form) of the same stationary iteration.  Using the
  power-iteration bound ``spec(G) in [0, rho]`` cached on the operator
  (:mod:`repro.core.solvers.power`), the three-term recurrence

      y_{k+1} = p_{k+1} [ gamma (G y_k + chi) + (1 - gamma) y_k ]
                + (1 - p_{k+1}) y_{k-1}

  with ``gamma = 2/(2 - rho)``, ``sigma = rho/(2 - rho)``, ``p_1 = 1``,
  ``p_2 = (1 - sigma^2/2)^{-1}``, ``p_{k+1} = (1 - sigma^2 p_k / 4)^{-1}``
  reaches a given residual in ~sqrt-fewer iterations than Richardson (error
  ~``2 r^k`` with ``r = sigma / (1 + sqrt(1 - sigma^2)) < rho``) -- and
  out-of-core, iterations are streamed passes over the P2 scratch, so the
  same factor comes off ``stream_stats().bytes_read``.  With ``rho -> 0`` the
  recurrence degenerates exactly to Richardson.  The interval adapts
  Manteuffel-style during the solve (see ``_rho_from_rate``): when the
  measured contraction misses the asymptotic rate the current interval
  predicts, the bound was an underestimate (power iteration converges to rho
  from below) -- the interval grows and the recurrence restarts from the
  current iterate.  This retires the old static ``RHO_GAP_SAFETY`` margin.
* ``cg`` -- conjugate gradients on the deflated SPD subspace, after Khoa &
  Chawla's solve-to-epsilon framing (arXiv:1111.4541).  The preconditioned
  operator is ``P2 = Z^ L = I - D^{-1/2} S~^{2^d} D^{1/2}``, so
  ``D^{1/2} P2 D^{-1/2} = I - S~^{2^d}`` is symmetric with spectrum in
  ``[1 - rho, 1]`` on the deflated subspace: CG with *degree-weighted* inner
  products ``<u, v>_D = u^T D v`` (the operator's ``deg`` vector) is exact
  CG on that SPD form.  One P2 mat-vec per iteration -- streamed, one pass
  over the P2 scratch, batched through ``CachingHandle`` and routed through
  the fused stream-GEMM kernel exactly like the stationary methods.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from jax.sharding import PartitionSpec as P

from repro.core.distmatrix import DistContext, matmul_rowblock
from repro.core.solvers.base import SolveReport, SolverSpec
from repro.obs import trace as obs_trace
from repro.obs.metrics import REGISTRY as _OBS_REGISTRY
from repro.core.tiles import (
    _axes_index,
    cached_program,
    is_streamable,
    program_cache_stats,
    shard_map,
    stream_stats,
)

RHO_MAX = 0.999

# Manteuffel-style interval adaptation (chebyshev).  The Chebyshev
# pseudo-residual is NOT monotone -- it oscillates with a short period even
# when the interval is correct -- so the observed contraction is measured as
# the *geometric mean* since the last (re)start, c = (res/res_anchor)^(1/kr),
# never step-to-step, and only after RHO_ADAPT_MIN_STEPS steps (enough to
# span an oscillation cycle).  When that smoothed rate misses the predicted
# asymptotic rate by more than RHO_ADAPT_SLACK, an eigenvalue of G sticks out
# of [0, rho]: grow the interval and restart the recurrence from the current
# iterate.  The growth is the SMALLER of the rate-implied bound (exact
# inverse of the predicted-rate formula, the right answer for a mild miss)
# and a gap-halving step (bounds the jump when the iteration has fully
# stalled and the measured ratio ~1 would otherwise slam the interval
# straight to RHO_MAX).
RHO_ADAPT_SLACK = 1.2
RHO_ADAPT_MIN_STEPS = 4
# No adaptation once the relative residual approaches the float32 noise
# floor: a roundoff-dominated stall there reads as c ~ 1 -- indistinguishable
# from a missed rate -- and growing the interval on it wrecks an
# already-converged iteration.  Conservative (two decades above f32 eps):
# a genuine interval underestimate shows up while residuals are still large.
RHO_ADAPT_RES_FLOOR = 1e-5

# Fixed-size residual-history buffer carried through the resident while_loop
# (a traced loop cannot append to a Python list).  Comfortably above
# TOLERANCE_ITER_CAP (300), so in practice the full per-iteration residual
# series survives; a longer run wraps the ring -- the driver un-rotates it so
# SolveReport.residuals is always the chronological tail.
RES_HIST_CAP = 512


def deflate_constant(ctx: DistContext, y: jax.Array) -> jax.Array:
    """Remove the all-ones (Laplacian nullspace) component from each column.

    Solutions of L z = y are defined up to a constant shift, which cancels in
    commute distances; removing it keeps bf16/fp32 iterates from drifting.
    The result is constrained to the row-sharded layout so the mean-subtract
    (an all-reduce over rows) can't silently regather the operand.
    """
    mean = jnp.mean(y.astype(jnp.float32), axis=0, keepdims=True)
    out = (y.astype(jnp.float32) - mean).astype(y.dtype)
    return ctx.constrain(out, ctx.rowblock_spec)


def _frob(x: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.sum(x.astype(jnp.float32) ** 2))


def _cheb_weight(k, p_prev, sigma2):
    """p_{k+1} of the Chebyshev three-term recurrence (k is the 0-based step
    counter since the last restart: step 0 uses p_1 = 1, step 1 uses p_2,
    then the general rule)."""
    return jnp.where(
        k == 0,
        jnp.float32(1.0),
        jnp.where(
            k == 1,
            1.0 / (1.0 - 0.5 * sigma2),
            1.0 / (1.0 - 0.25 * sigma2 * p_prev),
        ),
    ).astype(jnp.float32)


def _cheb_rate(sigma2):
    """Predicted asymptotic per-step contraction of the Chebyshev recurrence
    on [0, rho]: r = sigma / (1 + sqrt(1 - sigma^2))."""
    return jnp.sqrt(sigma2) / (1.0 + jnp.sqrt(jnp.maximum(1.0 - sigma2, 0.0)))


def _rho_from_rate(c):
    """Invert the rate formula: the interval bound whose predicted asymptotic
    contraction equals the measured per-step ratio ``c``.  Inverse pair:
    c = sigma/(1+sqrt(1-sigma^2)) <=> sigma = 2c/(1+c^2), and
    sigma = rho/(2-rho) <=> rho = 2 sigma/(1+sigma)."""
    sigma = 2.0 * c / (1.0 + c * c)
    return 2.0 * sigma / (1.0 + sigma)


def _unrotate_hist(hist: np.ndarray, iters: int) -> list[float]:
    """Chronological residual series from the while_loop's ring buffer.

    The loop writes step k at index ``k mod RES_HIST_CAP``; once
    ``iters > RES_HIST_CAP`` the buffer has wrapped and the oldest surviving
    entry sits at ``iters mod RES_HIST_CAP`` -- rotate so the returned series
    is the last ``RES_HIST_CAP`` residuals in order.
    """
    cap = hist.shape[0]
    if iters <= cap:
        out = hist[:iters]
    else:
        s = iters % cap
        out = np.concatenate([hist[s:], hist[:s]])
    return [float(r) for r in out]


# ---------------------------------------------------------------------------
# resident path: one cached while_loop program per (method, ctx, geometry)
# ---------------------------------------------------------------------------


def _resident_program(ctx: DistContext, method: str, deflate: bool, chi,
                      corr_rank: int | None = None):
    """The jitted adaptive loop.  Stopping operands (tol, max_steps, rho) and
    the warm-start iterate y0 are traced, so one compiled program serves
    every tolerance/cap/rho and both cold (y0 = chi) and warm starts.

    ``corr_rank`` selects the delta-corrected variant: the incremental
    low-rank factors (u2, v2) become *operands* of the same while_loop
    program (P2' y = P2 y + u2 (v2^T y)), so a steady-state incremental
    sequence compiles the corrected program once per correction rank and
    every later corrected push is a cache hit.  Uncorrected solves keep the
    historical program (and its bitwise behaviour) untouched.
    """

    def build():
        def matvec(p2, y, u2, v2):
            # identical op sequence to matmul_rowblock's resident branch
            out = jnp.dot(p2, y.astype(jnp.float32), preferred_element_type=jnp.float32)
            if corr_rank is not None:
                out = out + jnp.dot(
                    u2, jnp.dot(v2.T, y.astype(jnp.float32)),
                    preferred_element_type=jnp.float32,
                )
            return ctx.constrain(out.astype(y.dtype), ctx.rowblock_spec)

        def metric_deflate(delta):
            # Measure the residual on the solve's invariant subspace: the
            # iterate is deflated every step, so a nullspace (constant)
            # component of chi - P2 y is noise that never decays -- it
            # must not keep an otherwise-converged solve running.
            if deflate:
                delta = delta - jnp.mean(
                    delta.astype(jnp.float32), axis=0, keepdims=True
                )
            return delta

        def run(p2, u2, v2, chi, y0, tol, max_steps, rho):
            den = jnp.maximum(_frob(chi), 1e-30)

            def cond(carry):
                _, _, k, _, _, _, _, _, res = carry
                return jnp.logical_and(k < max_steps, res > tol)

            def body(carry):
                y, y_prev, k, kr, res_anchor, p_prev, rho_c, hist, _ = carry
                gamma = 2.0 / (2.0 - rho_c)
                sigma2 = (rho_c / (2.0 - rho_c)) ** 2
                gy = y - matvec(p2, y, u2, v2) + chi  # G y + chi; gy - y is the residual
                if method == "richardson":
                    y_new, p_new = gy, p_prev
                else:
                    p_new = _cheb_weight(kr, p_prev, sigma2)
                    y_new = p_new * (gamma * gy + (1.0 - gamma) * y) + (1.0 - p_new) * y_prev
                    y_new = ctx.constrain(y_new.astype(chi.dtype), ctx.rowblock_spec)
                if deflate:
                    y_new = deflate_constant(ctx, y_new)
                res = _frob(metric_deflate(gy - y)) / den
                hist = lax.dynamic_update_index_in_dim(
                    hist, res, jnp.mod(k, RES_HIST_CAP), 0
                )
                # the contraction anchor: the residual at the last (re)start
                res_anchor = jnp.where(kr == 0, res, res_anchor)
                kr_new = kr + jnp.int32(1)
                if method == "chebyshev":
                    # Manteuffel-style adaptation on the geometric-mean
                    # contraction since the last restart (the pseudo-residual
                    # oscillates; per-step ratios false-trigger).
                    c_avg = jnp.power(
                        res / jnp.maximum(res_anchor, jnp.float32(1e-30)),
                        1.0 / jnp.maximum(kr.astype(jnp.float32), 1.0),
                    )
                    pred = _cheb_rate(sigma2)
                    miss = jnp.logical_and(
                        kr >= RHO_ADAPT_MIN_STEPS,
                        jnp.logical_and(
                            c_avg > jnp.minimum(pred * RHO_ADAPT_SLACK, 0.999),
                            res > jnp.float32(RHO_ADAPT_RES_FLOOR),
                        ),
                    )
                    implied = _rho_from_rate(jnp.minimum(c_avg, 0.9995))
                    gap_half = 1.0 - 0.5 * (1.0 - rho_c)
                    rho_new = jnp.minimum(
                        jnp.minimum(implied, gap_half), jnp.float32(RHO_MAX)
                    )
                    grow = jnp.logical_and(miss, rho_new > rho_c)
                    rho_c = jnp.where(grow, rho_new, rho_c).astype(jnp.float32)
                    # restart: kr = 0 makes the next step use p_1 = 1, which
                    # zeroes the y_prev term -- a fresh start from y_new.
                    kr_new = jnp.where(grow, jnp.int32(0), kr_new)
                return (
                    y_new, y, k + jnp.int32(1), kr_new, res_anchor, p_new,
                    rho_c, hist, res,
                )

            init = (
                y0, y0, jnp.int32(0), jnp.int32(0), jnp.float32(jnp.inf),
                jnp.float32(1.0), rho,
                jnp.zeros((RES_HIST_CAP,), jnp.float32), jnp.float32(jnp.inf),
            )
            y, _, k, _, _, _, rho_c, hist, res = lax.while_loop(cond, body, init)
            return y, k, res, hist, rho_c

        def run_cg(p2, u2, v2, chi, y0, w, tol, max_steps):
            den = jnp.maximum(_frob(chi), 1e-30)
            wcol = jnp.maximum(w.astype(jnp.float32), 0.0).reshape(-1, 1)
            wsum = jnp.maximum(jnp.sum(wcol), 1e-30)

            def wdot(u, v):
                return jnp.sum(wcol * u * v, axis=0, keepdims=True)

            def dproj(x):
                # project onto range(P2) = {u : 1^T D u = 0}: remove the
                # deg-weighted mean (the D-geometry's nullspace direction)
                return x - jnp.sum(wcol * x, axis=0, keepdims=True) / wsum

            r0 = chi.astype(jnp.float32) - matvec(
                p2, y0.astype(jnp.float32), u2, v2
            ).astype(jnp.float32)
            if deflate:
                r0 = dproj(r0)
            r0 = ctx.constrain(r0, ctx.rowblock_spec)

            def cond(carry):
                _, _, _, _, k, res, _ = carry
                return jnp.logical_and(k < max_steps, res > tol)

            def body(carry):
                y, r, p, rz, k, _, hist = carry
                q = matvec(p2, p, u2, v2)
                if deflate:
                    q = ctx.constrain(dproj(q), ctx.rowblock_spec)
                pq = wdot(p, q)
                alpha = jnp.where(pq > 0, rz / jnp.maximum(pq, 1e-30), 0.0)
                y_new = (y.astype(jnp.float32) + alpha * p).astype(chi.dtype)
                if deflate:
                    y_new = deflate_constant(ctx, y_new)
                y_new = ctx.constrain(y_new, ctx.rowblock_spec)
                r_new = r - alpha * q
                if deflate:
                    r_new = dproj(r_new)
                r_new = ctx.constrain(r_new, ctx.rowblock_spec)
                rz_new = wdot(r_new, r_new)
                beta = jnp.where(rz > 0, rz_new / jnp.maximum(rz, 1e-30), 0.0)
                p_new = ctx.constrain(r_new + beta * p, ctx.rowblock_spec)
                res = _frob(metric_deflate(r_new)) / den
                hist = lax.dynamic_update_index_in_dim(
                    hist, res, jnp.mod(k, RES_HIST_CAP), 0
                )
                return (y_new, r_new, p_new, rz_new, k + jnp.int32(1), res, hist)

            init = (
                y0, r0, r0, wdot(r0, r0), jnp.int32(0), jnp.float32(jnp.inf),
                jnp.zeros((RES_HIST_CAP,), jnp.float32),
            )
            y, _, _, _, k, res, hist = lax.while_loop(cond, body, init)
            return y, k, res, hist

        return jax.jit(run_cg if method == "cg" else run)

    key = (
        "solve_driver", method, ctx, deflate, tuple(chi.shape),
        np.dtype(chi.dtype).name, RES_HIST_CAP, corr_rank,
    )
    return cached_program(key, build)


# ---------------------------------------------------------------------------
# streamed path: host loop (a traced body cannot fetch panels)
# ---------------------------------------------------------------------------


def _kernel_panel_program(ctx, ph: int, n: int, k: int, panel_dtype: str,
                          fused: bool):
    """Cached shard_map program for one streamed panel of the kernel path.

    The panel arrives matrix-sharded in its *stored* form (uint16 bf16 bit
    patterns, or fp32 for raw scratch); ``y`` (and ``chi``, fused) ride
    replicated so every device can slice both its column window (the GEMM
    operand) and the panel's global row window (the epilogue operands --
    panel row-sharding does not coincide with the solver's rowblock
    sharding, so a sliced-from-replicated read is the only layout-safe way
    in).  ``fused=True`` is one solve iteration over the panel: mat-vec +
    ``gy = chi + y - P2 y`` + deflated-residual moments, single kernel pass
    where the mesh has one column shard, kernel mat-vec + psum + jnp
    epilogue otherwise.  ``fused=False`` is the plain mat-vec (the chi
    build and the CG direction product).  The row origin is traced, so one
    program serves every panel.
    """

    def build():
        from repro.kernels.ops import fused_panel_matvec, stream_gemm

        R, C = ctx.n_row_shards, ctx.n_col_shards
        pr, pc = ph // R, n // C

        def local(r0, p_blk, y_rep, *rest):
            program_cache_stats().note_trace()
            row0 = r0 + _axes_index(ctx, ctx.row_axes) * pr
            if C == 1:
                y_cols = y_rep
            else:
                c = _axes_index(ctx, ctx.col_axes)
                y_cols = lax.dynamic_slice(y_rep, (c * pc, jnp.int32(0)), (pc, k))
            if not fused:
                mv = stream_gemm(p_blk, y_cols)
                if C > 1:
                    mv = lax.psum(mv, ctx.col_axes)
                return mv
            (chi_rep,) = rest
            y_rows = lax.dynamic_slice(y_rep, (row0, jnp.int32(0)), (pr, k))
            chi_rows = lax.dynamic_slice(chi_rep, (row0, jnp.int32(0)), (pr, k))
            if C == 1:
                gy, cs, ss = fused_panel_matvec(p_blk, y_cols, chi_rows, y_rows)
            else:
                mv = lax.psum(stream_gemm(p_blk, y_cols), ctx.col_axes)
                gy = chi_rows + y_rows - mv
                delta = chi_rows - mv
                cs = jnp.sum(delta, axis=0, keepdims=True)
                ss = jnp.sum(delta * delta).reshape(1, 1)
            if R > 1:
                cs = lax.psum(cs, ctx.row_axes)
                ss = lax.psum(ss, ctx.row_axes)
            return gy, cs, ss

        out_specs = P(ctx.row_axes, None)
        if fused:
            out_specs = (out_specs, P(None, None), P(None, None))
        in_specs = (P(), ctx.matrix_spec, P(None, None))
        if fused:
            in_specs = in_specs + (P(None, None),)
        return jax.jit(
            shard_map(
                local, mesh=ctx.mesh, in_specs=in_specs, out_specs=out_specs
            )
        )

    key = ("kernel_panel_matvec", ctx, ph, n, k, panel_dtype, fused)
    return cached_program(key, build)


def _kernel_stream_pass(ctx, handle, y, chi, *, depth, fused):
    """One pass over a store-backed operator through the Pallas kernel path.

    Panels stream in stored form (``encoded=True`` pipeline: bf16 scratch
    ships uint16 bit patterns, half the H2D bytes, decoded in VMEM by the
    kernel).  ``fused=True`` returns ``(gy, colsum, sumsq)`` for one whole
    solve iteration -- ``gy = chi + y - P2 y`` row-sharded plus the residual
    moments of ``delta = chi - P2 y`` reduced over all n rows -- so the
    iteration costs exactly this one pass over the stream.  ``fused=False``
    returns the plain mat-vec (the chi build / CG direction product).
    Per-panel outputs are host-concatenated (eager concatenate on
    partially-replicated shards is unsafe on jax 0.4.x) and re-put with the
    solver's rowblock sharding.
    """
    from repro.store import PanelPipeline  # deferred: optional path

    n = int(handle.shape[0])
    k = int(y.shape[1])
    ph = int(np.lcm(int(handle.panel_rows), ctx.n_row_shards))
    if n % ph:
        raise ValueError(f"panel height {ph} does not tile n={n}")
    st = stream_stats()
    st.add(calls=1)
    sharding = ctx.sharding(ctx.matrix_spec)
    y_rep = ctx.constrain(y.astype(jnp.float32), P(None, None))
    chi_rep = (
        ctx.constrain(chi.astype(jnp.float32), P(None, None)) if fused else None
    )
    parts = []
    cs_total, ss_total = None, 0.0
    prog = None
    with PanelPipeline(
        [handle], range(0, n, ph), ph, depth=depth, sharding=sharding,
        stats=st, encoded=True,
    ) as pipe:
        for r0, (panel,) in pipe:
            if prog is None:
                prog = _kernel_panel_program(
                    ctx, ph, n, k, str(panel.dtype), fused
                )
            if fused:
                gy_p, cs, ss = prog(jnp.int32(r0), panel, y_rep, chi_rep)
                cs_np = np.asarray(cs, np.float64)[0]
                cs_total = cs_np if cs_total is None else cs_total + cs_np
                ss_total += float(np.asarray(ss)[0, 0])
            else:
                gy_p = prog(jnp.int32(r0), panel, y_rep)
            st._note_live(pipe.device_live_bytes + gy_p.nbytes)
            parts.append(np.asarray(gy_p))
    out = jax.device_put(
        np.concatenate(parts, axis=0), ctx.sharding(ctx.rowblock_spec)
    )
    if fused:
        return out, cs_total, ss_total
    return out


def _solve_streamed(
    ctx, p2_handle, chi, y0, method, deflate, tol, max_steps, rho,
    solver_batch, prefetch_depth, use_kernel=False, w=None, u2=None, v2=None,
):
    p2, cached = p2_handle, None
    if solver_batch > 1 and is_streamable(p2_handle):
        from repro.store import CachingHandle  # deferred: optional path

        p2 = cached = CachingHandle(p2_handle)
    den = max(float(_frob(chi)), 1e-30)
    n_rows = int(chi.shape[0])
    passes = 0

    def low_rank(x):
        """The delta correction u2 (v2^T x): device-resident factors, eager
        skinny products -- never touches the panel stream."""
        return jnp.dot(
            u2, jnp.dot(v2.T, x.astype(jnp.float32)),
            preferred_element_type=jnp.float32,
        )

    def stream_matvec(x):
        """One P2' @ x pass over the stream (kernel path when enabled): the
        base stream plus the rank-r correction epilogue when present."""
        nonlocal passes
        if cached is not None and passes and passes % solver_batch == 0:
            cached.refresh()  # batch boundary: next pass re-streams the store
        passes += 1
        if use_kernel:
            mv = _kernel_stream_pass(ctx, p2, x, None, depth=prefetch_depth,
                                     fused=False)
            mv = mv.astype(jnp.float32)
        else:
            mv = matmul_rowblock(
                ctx, p2, x, prefetch_depth=prefetch_depth
            ).astype(jnp.float32)
        if u2 is not None:
            mv = mv + low_rank(x)
        return ctx.constrain(mv, ctx.rowblock_spec)

    def metric(delta):
        if deflate:
            delta = delta - jnp.mean(
                delta.astype(jnp.float32), axis=0, keepdims=True
            )
        return float(_frob(delta)) / den

    res_hist: list[float] = []

    if method == "cg":
        wcol = jnp.maximum(
            jnp.asarray(w, jnp.float32).reshape(-1, 1), 0.0
        )
        wsum = max(float(jnp.sum(wcol)), 1e-30)

        def wdot(u, v):
            return jnp.sum(wcol * u * v, axis=0, keepdims=True)

        def dproj(x):
            m = jnp.sum(wcol * x, axis=0, keepdims=True) / wsum
            return ctx.constrain(x - m, ctx.rowblock_spec)

        y = y0
        r = chi.astype(jnp.float32) - stream_matvec(y0.astype(jnp.float32))
        if deflate:
            r = dproj(r)
        p_dir = r
        rz = wdot(r, r)
        k, res = 0, math.inf
        while k < max_steps and res > tol:
            q = stream_matvec(p_dir)
            if deflate:
                q = dproj(q)
            pq = wdot(p_dir, q)
            alpha = jnp.where(pq > 0, rz / jnp.maximum(pq, 1e-30), 0.0)
            y = (y.astype(jnp.float32) + alpha * p_dir).astype(chi.dtype)
            if deflate:
                y = deflate_constant(ctx, y)
            y = ctx.constrain(y, ctx.rowblock_spec)
            r = r - alpha * q
            if deflate:
                r = dproj(r)
            rz_new = wdot(r, r)
            beta = jnp.where(rz > 0, rz_new / jnp.maximum(rz, 1e-30), 0.0)
            p_dir = ctx.constrain(r + beta * p_dir, ctx.rowblock_spec)
            rz = rz_new
            res = metric(r)
            k += 1
            res_hist.append(float(res))
        return y, k, res, res_hist, None

    rho_c = float(rho)
    gamma = 2.0 / (2.0 - rho_c)
    sigma2 = (rho_c / (2.0 - rho_c)) ** 2

    y, y_prev, p_prev = y0, y0, 1.0
    k, kr, res, res_anchor = 0, 0, math.inf, math.inf
    while k < max_steps and res > tol:
        if use_kernel:
            # One fused pass over the P2 stream: gy AND the residual moments
            # of delta = chi - P2 y come out of the same kernel traversal, so
            # each iteration reads the scratch exactly once.
            if cached is not None and passes and passes % solver_batch == 0:
                cached.refresh()
            passes += 1
            gy, cs, ss = _kernel_stream_pass(
                ctx, p2, y, chi, depth=prefetch_depth, fused=True
            )
            if u2 is not None:
                # The fused kernel computed gy and the residual moments for
                # the *base* P2; fold in the rank-r term and recompute the
                # moments from delta = gy' - y (= chi - P2' y) -- a cheap
                # eager epilogue, still one pass over the stream.
                gy = gy.astype(jnp.float32) - low_rank(y)
                delta = gy - y.astype(jnp.float32)
                cs = np.asarray(jnp.sum(delta, axis=0), np.float64)
                ss = float(jnp.sum(delta * delta))
            gy = ctx.constrain(gy.astype(chi.dtype), ctx.rowblock_spec)
            num2 = ss - float(np.sum(cs * cs)) / n_rows if deflate else ss
            res = math.sqrt(max(num2, 0.0)) / den
        else:
            gy = y - stream_matvec(y).astype(chi.dtype) + chi
        if method == "richardson":
            y_new = gy
        else:
            # same weight rule as the traced path; host scalars here
            p_new = float(_cheb_weight(kr, p_prev, sigma2))
            y_new = p_new * (gamma * gy + (1.0 - gamma) * y) + (1.0 - p_new) * y_prev
            y_new = ctx.constrain(y_new.astype(chi.dtype), ctx.rowblock_spec)
            p_prev = p_new
        if deflate:
            y_new = deflate_constant(ctx, y_new)
        if not use_kernel:
            res = metric(gy - y)  # residual, minus its never-decaying nullspace part
        if kr == 0:
            res_anchor = res  # contraction anchor: residual at the (re)start
        kr += 1
        if (
            method == "chebyshev"
            and kr - 1 >= RHO_ADAPT_MIN_STEPS
            and res > RHO_ADAPT_RES_FLOOR
        ):
            # geometric-mean contraction since the restart (see the constants
            # block: per-step ratios false-trigger on the oscillation)
            pred = float(_cheb_rate(jnp.float32(sigma2)))
            c_avg = (res / max(res_anchor, 1e-30)) ** (1.0 / max(kr - 1, 1))
            if c_avg > min(pred * RHO_ADAPT_SLACK, 0.999):
                implied = _rho_from_rate(min(c_avg, 0.9995))
                rho_new = min(implied, 1.0 - 0.5 * (1.0 - rho_c), RHO_MAX)
                if rho_new > rho_c:
                    rho_c = rho_new
                    gamma = 2.0 / (2.0 - rho_c)
                    sigma2 = (rho_c / (2.0 - rho_c)) ** 2
                    kr = 0  # restart: next step uses p_1 = 1 from y_new
        y_prev, y = y, y_new
        k += 1
        res_hist.append(float(res))
    return y, k, res, res_hist, rho_c


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------


def solve(
    ctx: DistContext,
    op,
    b: jax.Array,
    spec: SolverSpec | None = None,
    *,
    fixed_q: int | None = None,
    deflate: bool = True,
    solver_batch: int = 1,
    prefetch_depth: int | None = None,
    use_gemm_kernel: bool | None = None,
    y0: jax.Array | None = None,
) -> tuple[jax.Array, SolveReport]:
    """x* ~= L^+ b for each column of the row-sharded (n, k) ``b``.

    ``op`` is any chain operator (duck-typed: ``p1``/``p2`` arrays or
    store-backed handles, optional ``prefetch_depth``/``rho``/``deg``
    metadata).  ``fixed_q`` feeds the legacy fixed-iteration default: with no
    tolerance, cap or delta on the spec, the driver runs exactly
    ``fixed_q - 1`` refinement steps -- bit-compatible with the historical
    Richardson loop.  ``solver_batch``/``prefetch_depth`` are the streamed
    path's I/O knobs (ignored resident -- nothing streams); see
    :func:`repro.core.solver.estimate_solution` for their semantics.

    ``y0`` warm-starts the iteration: the previous snapshot's solution (same
    shape as ``b``'s solution) replaces the cold ``y0 = chi`` start, so a
    slowly-drifting sequence's first residual starts at ~|dA| instead of
    ~1.  The iterate is deflated on entry (a stale nullspace component must
    not survive into the new solve) and the stopping denominator stays
    ``||Z^ b||`` -- tolerances mean the same thing warm or cold.

    ``use_gemm_kernel`` routes the streamed iterations (and the chi build,
    where P1 is also a handle) through the fused Pallas stream-GEMM path:
    panels ship in stored form and each iteration is a single fused pass
    over the P2 stream (mat-vec + update + residual moments).  ``None``
    (default) inherits the flag the out-of-core chain build stamped on the
    operator; resident solves ignore it.

    Returns ``(solution, SolveReport)``; the report carries iterations, the
    final relative preconditioned residual, and the scratch-store traffic of
    this solve.  A run that never measured a residual (``max_iters=0``)
    reports ``residual=nan, converged=False``.
    """
    spec = spec or SolverSpec()
    if solver_batch < 1:
        raise ValueError("solver_batch must be >= 1")
    depth = prefetch_depth if prefetch_depth is not None else getattr(
        op, "prefetch_depth", None
    )
    max_steps = spec.max_steps(fixed_q)
    tol = 0.0 if spec.tolerance is None else float(spec.tolerance)

    rho = None
    if spec.method == "chebyshev":
        rho_raw = getattr(op, "rho", None)
        if rho_raw is None:
            from repro.core.solvers.power import estimate_rho

            rho_raw = estimate_rho(ctx, op.p2, prefetch_depth=depth)
            if hasattr(op, "rho"):
                op.rho = rho_raw  # cache: later solves on this operator reuse it
        # Start from the raw power-iteration estimate (it converges to rho
        # from below); Manteuffel-style adaptation during the solve grows the
        # interval if the estimate's lag shows up as a missed contraction.
        rho = min(RHO_MAX, max(0.0, float(rho_raw)))

    w = None
    if spec.method == "cg":
        w = getattr(op, "deg", None)
        if w is None:
            # No degree metadata on the operator: fall back to the Euclidean
            # inner product (exact only for uniform degrees).
            w = jnp.ones((int(b.shape[0]),), jnp.float32)

    # Incremental-chain correction factors (None on a plain base operator).
    # p1_scale/u1/v1 turn the chi build into the exact corrected
    # P1' b = s * (P1 (s * b)) + u1 (v1^T b); u2/v2 add the rank-r ΔP2
    # term to every mat-vec of the iteration.
    p1_scale = getattr(op, "p1_scale", None)
    u1 = getattr(op, "u1", None)
    v1 = getattr(op, "v1", None)
    u2 = getattr(op, "u2", None)
    v2 = getattr(op, "v2", None)
    corr_rank = None if u2 is None else int(u2.shape[1])

    streamed = is_streamable(op.p1) or is_streamable(op.p2)
    use_k = bool(
        use_gemm_kernel
        if use_gemm_kernel is not None
        else getattr(op, "use_gemm_kernel", False)
    )
    st = stream_stats()
    read0, panels0, h2d0 = st.bytes_read, st.panels, st.bytes_h2d
    warm = y0 is not None

    with obs_trace.span(
        "solve", method=spec.method, streamed=streamed, warm=warm
    ) as sp:
        b = ctx.constrain(b, ctx.rowblock_spec)
        b_in = b
        if p1_scale is not None:
            scale_col = p1_scale.astype(jnp.float32).reshape(-1, 1)
            b_in = ctx.constrain(
                (b.astype(jnp.float32) * scale_col).astype(b.dtype),
                ctx.rowblock_spec,
            )
        if streamed and use_k and is_streamable(op.p1):
            chi = _kernel_stream_pass(
                ctx, op.p1, b_in, None, depth=depth, fused=False
            )
            chi = ctx.constrain(chi.astype(b.dtype), ctx.rowblock_spec)
        else:
            chi = matmul_rowblock(ctx, op.p1, b_in, prefetch_depth=depth)
        if p1_scale is not None:
            chi = (
                chi.astype(jnp.float32) * scale_col
                + jnp.dot(
                    u1, jnp.dot(v1.T, b.astype(jnp.float32)),
                    preferred_element_type=jnp.float32,
                )
            ).astype(b.dtype)
            chi = ctx.constrain(chi, ctx.rowblock_spec)
        if deflate:
            chi = deflate_constant(ctx, chi)

        if warm:
            if tuple(y0.shape) != tuple(chi.shape):
                raise ValueError(
                    f"warm start y0 shape {tuple(y0.shape)} does not match "
                    f"the solution shape {tuple(chi.shape)}"
                )
            y_start = ctx.constrain(y0.astype(chi.dtype), ctx.rowblock_spec)
            if deflate:
                y_start = deflate_constant(ctx, y_start)
        else:
            y_start = chi  # historical cold start: y0 = chi = Z^ b

        rho_final = rho
        if streamed:
            y, iters, res, res_hist, rho_final = _solve_streamed(
                ctx, op.p2, chi, y_start, spec.method, deflate, tol, max_steps,
                rho or 0.0, solver_batch, depth,
                use_kernel=use_k and is_streamable(op.p2), w=w, u2=u2, v2=v2,
            )
            if spec.method != "chebyshev":
                rho_final = rho
        else:
            prog = _resident_program(ctx, spec.method, deflate, chi, corr_rank)
            if spec.method == "cg":
                y, k_arr, res_arr, hist_arr = prog(
                    op.p2, u2, v2, chi, y_start, jnp.asarray(w),
                    jnp.float32(tol), jnp.int32(max_steps),
                )
            else:
                y, k_arr, res_arr, hist_arr, rho_arr = prog(
                    op.p2, u2, v2, chi, y_start, jnp.float32(tol),
                    jnp.int32(max_steps), jnp.float32(rho or 0.0),
                )
                if spec.method == "chebyshev":
                    rho_final = float(rho_arr)
            iters, res = int(k_arr), float(res_arr)
            res_hist = _unrotate_hist(np.asarray(hist_arr), iters)
        if iters == 0:
            # The loop never ran (max_iters=0): no residual was ever
            # measured -- report that honestly rather than inf/converged.
            res = float("nan")
        sp.annotate(iterations=iters, residual=res)
        sp.fence(y)

    st = stream_stats()
    report = SolveReport(
        method=spec.method,
        iterations=iters,
        residual=res,
        converged=(not math.isnan(res))
        and ((spec.tolerance is None) or res <= spec.tolerance),
        tolerance=spec.tolerance,
        max_iters=max_steps,
        streamed=streamed,
        rho=rho,
        bytes_read=st.bytes_read - read0,
        bytes_h2d=st.bytes_h2d - h2d0,
        panels=st.panels - panels0,
        residuals=tuple(res_hist),
        rho_final=rho_final,
        warm_start=warm,
    )
    _OBS_REGISTRY.add_named({
        "solver.solves": 1.0,
        "solver.iterations": float(iters),
        "solver.not_converged": 0.0 if report.converged else 1.0,
        "solver.warm_starts": 1.0 if warm else 0.0,
    })
    _OBS_REGISTRY.extend("solver.residuals", res_hist)
    return y, report
