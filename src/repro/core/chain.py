"""Peng-Spielman inverse-chain product (paper Algorithm 2, ChainProduct).

P = (I + S)(I + S^2)(I + S^4) ... (I + S^{2^{d-1}})  ~=  (I - S)^{-1}
(the product telescopes: (I - S) P = I - S^{2^d}), giving the approximate
Laplacian pseudo-inverse  Z^ = D^{-1/2} P D^{-1/2}.

Erratum vs the paper: Alg. 2 line 8 writes P1 = D^{-1/2} P; the right
inverse needs the symmetric sandwich D^{-1/2} P D^{-1/2} (their EstimateSolution
only converges with the latter).  We implement the correct sandwich.

Cost: exactly 2(d-1) + 1 dense n x n GEMMs (T <- T@T and P <- P@T + P per
level, one more for P2 = Z^ @ L) -- the paper's hot spot, distributed with the
schedule chosen in :mod:`repro.core.distmatrix`.  ``fuse_l=True`` instead forms
P2 = Z^ D - Z^ A via a column scale plus one GEMM on the *original* adjacency,
saving the materialization of L (a beyond-paper memory optimization).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from jax.sharding import PartitionSpec as P

from repro.core import laplacian as lap
from repro.core.distmatrix import DistContext, add_scaled_identity, matmul
from repro.core.tiles import is_streamable, sharded_zeros, stream_stats, tile_map
from repro.obs.metrics import REGISTRY as _OBS_REGISTRY

# Build counting: chain_product is the O(n^3) hot spot, so the sequence engine
# (and its tests) track exactly how many times it runs.  The storage is the
# obs metrics registry (``chain.builds``, alongside ``chain.gemm_flops`` /
# ``chain.gemm_bytes`` and the incremental-update counters from
# :mod:`repro.core.delta_chain`) so rebuild-vs-incremental counts flow through
# RunReport and bench registry deltas like every other metric; these two
# functions are the legacy facade over it.
_BUILD_BASE = 0.0  # registry value at the last reset_chain_build_count()


def chain_build_count() -> int:
    """Number of chain operators built since process start (or last reset)."""
    return int(_OBS_REGISTRY.value("chain.builds") - _BUILD_BASE)


def reset_chain_build_count() -> None:
    global _BUILD_BASE
    _BUILD_BASE = _OBS_REGISTRY.value("chain.builds")


@jax.tree_util.register_pytree_node_class
@dataclass
class ChainOperator:
    """Precomputed pieces so every Richardson iteration is mat-vec only.

    ``p1`` / ``p2`` are resident sharded arrays, or store-backed snapshot
    handles when the operator was built out-of-core
    (:func:`repro.core.oochain.chain_product_oocore`) -- the solver streams
    handle-backed operators per panel.  ``prefetch_depth`` and ``rho`` ride
    along as static metadata: the staging depth every downstream consumer of
    a store-backed operator inherits, and the power-iteration estimate of
    ``rho(S~^{2^d})`` (the Richardson contraction / Chebyshev interval bound,
    see :mod:`repro.core.solvers.power`) computed once at chain build so the
    solve driver never re-measures it.
    """

    p1: jax.Array  # (n, n)  Z^ = D^{-1/2} P D^{-1/2}  (array or store handle)
    p2: jax.Array  # (n, n)  Z^ @ L                    (array or store handle)
    deg: jax.Array  # (n,)
    vol: jax.Array  # scalar V_G
    # Optional incremental low-rank correction (repro.core.delta_chain): the
    # operator then represents P1' = diag(p1_scale) P1 diag(p1_scale) + u1 v1^T
    # and P2' = P2 + u2 v2^T around the *base* p1/p2 buffers.  The solve
    # driver applies them as rank-r epilogues in every mat-vec; None means an
    # ordinary (uncorrected) operator.
    p1_scale: jax.Array | None = None  # (n,)
    u1: jax.Array | None = None  # (n, r)
    v1: jax.Array | None = None  # (n, r)
    u2: jax.Array | None = None  # (n, r)
    v2: jax.Array | None = None  # (n, r)
    prefetch_depth: int = 2  # panel-pipeline staging depth for streamed consumers
    rho: float | None = None  # rho(S~^{2^d}) power-iteration estimate (build-time)
    # Streamed consumers route mat-vecs through the fused Pallas stream-GEMM
    # kernel path (stored-width panel shipping + in-kernel decode + fused
    # solve epilogue); set by the out-of-core build, inherited by solve().
    use_gemm_kernel: bool = False
    # True when p1/p2 belong to a live delta_chain.BaseChain shared with other
    # operators: release_scratch() is then a no-op -- BaseChain.release() is
    # the single owner of that scratch (prevents a corrected operator's
    # retirement from freeing panels the base or its siblings still stream).
    shared_base: bool = False

    def tree_flatten(self):
        return (
            self.p1, self.p2, self.deg, self.vol,
            self.p1_scale, self.u1, self.v1, self.u2, self.v2,
        ), (self.prefetch_depth, self.rho, self.use_gemm_kernel, self.shared_base)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(
            *children,
            prefetch_depth=aux[0], rho=aux[1], use_gemm_kernel=aux[2],
            shared_base=aux[3],
        )

    def release_scratch(self) -> None:
        """Retire store-backed P1 / P2 from their scratch store (no-op for
        resident operators).  Call once the operator will not be used again;
        every consumer that builds oocore operators internally
        (``detect_anomalies``, ``SequenceDetector``) does this itself.

        A failed removal (a wedged scratch dir, a concurrently-removed
        snapshot) is *warned*, never raised: scoring already succeeded and
        the scratch is disposable -- but a silently growing scratch dir must
        be diagnosable, so only the expected store errors are swallowed.

        Operators sharing a delta-chain base (``shared_base=True``) skip the
        removal entirely: their p1/p2 are the base's buffers, owned and
        eventually retired by ``BaseChain.release()``.
        """
        if self.shared_base:
            return
        for buf in (self.p1, self.p2):
            store = getattr(buf, "store", None)
            if store is not None and hasattr(buf, "snap_id"):
                try:
                    store.remove_snapshot(buf.snap_id)
                except (OSError, ValueError, KeyError) as e:
                    warnings.warn(
                        f"release_scratch: could not remove snapshot "
                        f"{buf.snap_id!r} from its scratch store ({e!r}); "
                        f"the scratch dir may be accumulating orphans",
                        RuntimeWarning,
                        stacklevel=2,
                    )


def _col_scale_body(tile, blk, v):
    return blk.astype(jnp.float32) * v[tile.cols][None, :]


def _matmul_panels_from_store(
    ctx: DistContext, m: jax.Array, h, out_dtype, prefetch_depth: int | None = None
) -> jax.Array:
    """M @ A with A streamed from the store: per-panel GEMM accumulation.

    M @ A = sum_K M[:, K] @ A[K, :] over row panels K of the stored adjacency
    -- each term is one resident (n, ph) x (ph, n) GEMM against a panel
    prefetched from host/disk by the panel pipeline, so A is never fully
    device-resident and the fetch/decode overlaps the GEMMs.  (Used by the
    ``fuse_l`` build; the panel-accumulation order makes this path
    close-but-not-bitwise vs the resident ``fuse_l`` GEMM.)
    """
    from repro.store import PanelPipeline  # deferred: core->store only on this path

    n = h.shape[0]
    ph = int(np.lcm(int(h.panel_rows), ctx.n_row_shards))
    sharding = ctx.sharding(ctx.matrix_spec)
    st = stream_stats()
    acc = sharded_zeros((n, n), jnp.float32, sharding)
    with PanelPipeline(
        [h], range(0, n, ph), ph, depth=prefetch_depth, sharding=sharding, stats=st
    ) as pipe:
        for r0, (panel,) in pipe:
            m_cols = lax.dynamic_slice(m, (0, r0), (n, ph))
            acc = acc + jnp.dot(
                m_cols.astype(jnp.float32), panel.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
    return ctx.constrain(acc.astype(out_dtype), ctx.matrix_spec)


def chain_product(
    ctx: DistContext,
    a: jax.Array,
    d_len: int,
    *,
    schedule: str = "cannon",
    dtype=jnp.float32,
    deflate: bool = True,
    fuse_l: bool = False,
    use_kernel: bool = False,
    oocore: bool = False,
    oocore_work=None,
    oocore_panel_rows: int | None = None,
    tile_codec: str = "raw",
    prefetch_depth: int | None = None,
    use_gemm_kernel: bool = False,
    level_sink: dict | None = None,
) -> ChainOperator:
    """Build the chain operator from ``a``: a resident sharded adjacency or a
    store-backed snapshot handle.

    ``level_sink`` (a caller-provided dict) opts into retaining the chain's
    intermediate levels for incremental delta updates
    (:mod:`repro.core.delta_chain`): on return ``level_sink["t"]`` holds
    T_0 .. T_{d-1} and ``level_sink["p"]`` holds P_0 .. P_{d-2} (arrays
    resident, store handles out-of-core -- the oocore build then skips the
    usual intermediate-snapshot removal for retained levels; the caller owns
    their lifetime via ``BaseChain.release()``).

    With a handle, every consumer of A streams: the degree pass, the
    normalized-adjacency build (S, the first chain GEMM's operand, assembled
    per-tile from store panels) and the Laplacian build each make one pass
    over the stored tiles, so the raw n x n adjacency is never device-resident
    -- only the (already required) chain matrices are.  With the default
    ``fuse_l=False`` the streamed build is bitwise identical to the resident
    one (all A-consuming passes are elementwise or row-parallel); the opt-in
    ``fuse_l=True`` path instead accumulates Z^ @ A per panel, whose reduction
    order differs from the resident single GEMM -- allclose, not bitwise.

    ``oocore=True`` removes the remaining n^2 device term: the squaring chain
    itself runs against store-backed working matrices
    (:func:`repro.core.oochain.chain_product_oocore`), spilling S / T / P
    through ``oocore_work`` (a TileStore, a directory, or None for host-RAM
    scratch) so peak device residency is O(n * panel); the returned operator
    holds store-backed P1 / P2 that the solver streams.  Allclose, not
    bitwise, vs the resident build.  ``schedule`` / ``use_kernel`` / ``dtype``
    govern the resident GEMMs only and are ignored out-of-core (the scratch
    and operator are always fp32).

    ``tile_codec`` / ``prefetch_depth`` are the panel-I/O knobs and matter
    only where panels actually stream: the scratch store encoding and the
    panel-pipeline staging depth of the out-of-core build (and of the
    streamed ``fuse_l`` GEMM with a handle-backed ``a``).

    ``use_gemm_kernel`` (out-of-core only; ignored resident, where
    ``use_kernel`` already selects the Pallas tile bodies) runs the chain's
    GEMM steps through the fused streaming kernel with stored-width panel
    shipping, and marks the returned operator so streamed solves inherit the
    kernel path -- see :func:`repro.core.oochain.chain_product_oocore`.
    """
    if d_len < 1:
        raise ValueError("chain length d must be >= 1")
    # Logical GEMM cost of a full build -- 2(d-1)+1 dense n x n GEMMs at
    # 2 n^3 FLOPs / 3 n^2 fp32 operands each (the same convention the delta
    # path's skinny-pass ledger uses, so the registry ratio is meaningful).
    n_nodes = int(a.shape[0])
    n_gemms = 2 * (d_len - 1) + 1
    _OBS_REGISTRY.add_named({
        "chain.builds": 1.0,
        "chain.gemm_flops": n_gemms * 2.0 * float(n_nodes) ** 3,
        "chain.gemm_bytes": n_gemms * 3.0 * float(n_nodes) ** 2 * 4.0,
        # Scratch materialized: one fresh n^2 matrix per GEMM plus the S~
        # assembly (the matrices an out-of-core build spills to the store).
        "chain.scratch_bytes": (n_gemms + 1) * float(n_nodes) ** 2 * 4.0,
    })
    if oocore:
        from repro.core.oochain import chain_product_oocore

        return chain_product_oocore(
            ctx,
            a,
            d_len,
            dtype=dtype,
            deflate=deflate,
            fuse_l=fuse_l,
            work=oocore_work,
            panel_rows=oocore_panel_rows,
            tile_codec=tile_codec,
            prefetch_depth=prefetch_depth,
            use_gemm_kernel=use_gemm_kernel,
            level_sink=level_sink,
        )
    mm = partial(matmul, ctx, schedule=schedule, out_dtype=dtype, use_kernel=use_kernel)

    deg = lap.degrees(ctx, a, prefetch_depth=prefetch_depth)
    vol = lap.volume(ctx, deg)
    s = lap.normalized_adjacency(
        ctx, a, deg, deflate=deflate, dtype=dtype, prefetch_depth=prefetch_depth
    )

    t = s
    p = add_scaled_identity(ctx, s, 1.0)  # I + S
    t_levels, p_levels = [t], []
    for _ in range(1, d_len):
        p_levels.append(p)  # P_{lvl-1}, multiplied against by dP_lvl
        t = mm(t, t)  # S^{2^k}
        t_levels.append(t)
        p = jnp.add(mm(p, t), p)  # P (I + T) = P T + P, no identity materialized
    if level_sink is not None:
        level_sink["t"] = t_levels
        level_sink["p"] = p_levels[1:]  # P_0 = I + T_0 is applied implicitly

    inv_sqrt = jnp.where(deg > 0, jax.lax.rsqrt(jnp.maximum(deg, 1e-30)), 0.0)
    p1 = tile_map(
        ctx,
        lap._sym_scale_body,
        p,
        inv_sqrt,
        in_specs=(ctx.matrix_spec, P(None)),
        out_dtype=dtype,
    )
    if fuse_l:
        # P2 = Z^ (D - A) = (Z^ col-scaled by d) - Z^ @ A
        p1d = tile_map(
            ctx, _col_scale_body, p1, deg, in_specs=(ctx.matrix_spec, P(None)), out_dtype=dtype
        )
        if is_streamable(a):
            p2 = jnp.subtract(
                p1d, _matmul_panels_from_store(ctx, p1, a, dtype, prefetch_depth)
            )
        else:
            p2 = jnp.subtract(p1d, mm(p1, a.astype(dtype)))
    else:
        l_mat = lap.laplacian(ctx, a, deg, dtype=dtype, prefetch_depth=prefetch_depth)
        p2 = mm(p1, l_mat)
    # Measure the Richardson contraction rho(S~^{2^d}) once, while P2 is hot:
    # a handful of eager skinny mat-vecs against the 2(d-1)+1 n^3 GEMMs above.
    # The solve driver reads it for Chebyshev intervals and telemetry.
    from repro.core.solvers.power import estimate_rho

    rho = estimate_rho(ctx, p2, prefetch_depth=prefetch_depth)
    return ChainOperator(p1=p1, p2=p2, deg=deg, vol=vol, rho=rho)
