"""Out-of-core Peng-Spielman chain product: the squaring chain against
store-backed working matrices.

The resident :func:`repro.core.chain.chain_product` keeps S, T, P, P1, P2 as
n x n device-resident arrays -- five n^2 buffers, the HBM bound on n once the
raw adjacency itself is streamed (the PR-2 snapshot store).  This module runs
the same recurrence

    T <- T @ T          P <- P @ T + P

entirely against a :class:`repro.store.TileStore`-backed scratch: every GEMM
is a walk over output row panels, each computed as a panel-accumulated sum

    C[I, :] = init[I, :] + sign * sum_K  L[I, K] @ R[K, :]

with L[I, K] sliced on the host from the left operand's row panel and R[K, :]
streamed host -> device one panel at a time.  Peak device residency per GEMM
is one accumulator panel + one streamed panel + one (panel x panel) block --
O(n * panel), never O(n^2).  The unary passes (S build, +I, the D^{-1/2}
sandwich, the Laplacian) stream one panel at a time through jitted
module-level panel programs: the row origin is a traced operand, so each
program compiles once per geometry and serves every panel of every snapshot.

Numerics: per-panel accumulation orders the GEMM reductions differently from
the resident single dot, so an out-of-core chain is *allclose* (fp32
accumulation throughout), not bitwise, vs the resident build -- the same
contract as the streamed ``fuse_l`` path, and the blockwise-solve tolerance
argument of Khoa & Chawla (arXiv:1111.4541) for approximate commute-time
embeddings.  Working matrices are stored fp32 regardless of the chain dtype.

The returned :class:`~repro.core.chain.ChainOperator` carries *store-backed*
P1 / P2 handles; :func:`repro.core.distmatrix.matmul_rowblock` and the
Richardson solver stream them per panel, so the whole pipeline -- ingest,
chain build, solve, scoring -- is panel-bounded end-to-end.  All panel
traffic is accounted in :func:`repro.core.tiles.stream_stats`.
"""

from __future__ import annotations

import uuid
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import laplacian as lap
from repro.obs import trace as obs_trace
from repro.core.chain import ChainOperator
from repro.core.distmatrix import DistContext
from repro.core.tiles import (
    cached_program,
    is_streamable,
    program_cache_stats,
    shard_map,
    sharded_zeros,
    stream_stats,
)

# ---------------------------------------------------------------------------
# panel programs (module-level jit: compiled once per geometry, the row
# origin is traced so one program serves every panel)
# ---------------------------------------------------------------------------


@jax.jit
def _s_panel_deflated(blk, r0, inv_sqrt, deg, vol):
    ph = blk.shape[0]
    isr = lax.dynamic_slice(inv_sqrt, (r0,), (ph,))
    s = blk.astype(jnp.float32) * isr[:, None] * inv_sqrt[None, :]
    dr = lax.dynamic_slice(deg, (r0,), (ph,))
    u_r = jnp.sqrt(jnp.maximum(dr, 0.0) / vol)
    u_c = jnp.sqrt(jnp.maximum(deg, 0.0) / vol)
    return s - u_r[:, None] * u_c[None, :]


@jax.jit
def _s_panel_plain(blk, r0, inv_sqrt):
    ph = blk.shape[0]
    isr = lax.dynamic_slice(inv_sqrt, (r0,), (ph,))
    return blk.astype(jnp.float32) * isr[:, None] * inv_sqrt[None, :]


@jax.jit
def _plus_eye_panel(blk, r0):
    ph, n = blk.shape
    rows = r0 + jnp.arange(ph)
    cols = jnp.arange(n)
    return blk + (rows[:, None] == cols[None, :]).astype(blk.dtype)


@jax.jit
def _l_panel(blk, r0, deg):
    ph, n = blk.shape
    rows = r0 + jnp.arange(ph)
    eye = (rows[:, None] == jnp.arange(n)[None, :]).astype(jnp.float32)
    dr = lax.dynamic_slice(deg, (r0,), (ph,))
    return eye * dr[:, None] - blk.astype(jnp.float32)


@jax.jit
def _col_scale_panel(blk, v):
    return blk.astype(jnp.float32) * v[None, :]


@jax.jit
def _gemm_step(acc, block, right):
    """acc + block @ right, fp32 accumulate (one K-term of a panel GEMM)."""
    return acc + jnp.dot(
        block.astype(jnp.float32), right.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


@jax.jit
def _gemm_step_neg(acc, block, right):
    return acc - jnp.dot(
        block.astype(jnp.float32), right.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


@jax.jit
def _decode_bits_panel(u):
    """bf16 bit-pattern panel (uint16) -> fp32 on device (exact widening,
    same values the host codec would have produced)."""
    return lax.bitcast_convert_type(u, jnp.bfloat16).astype(jnp.float32)


def _kernel_gemm_program(ctx, positive: bool, blk_dtype: str, right_dtype: str,
                         ph: int, n: int):
    """Cached shard_map GEMM step through the fused Pallas kernel.

    SUMMA-style: each device all-gathers the block's column shards and the
    right panel's row shards (at *stored* width -- uint16 gathers move half
    the ICI bytes too), then runs one ``stream_gemm`` with the accumulator as
    the fused init: ``acc + sign * block @ right`` in a single kernel, bf16
    bit patterns widened in VMEM.  Cached per (ctx, sign, operand dtypes,
    geometry) so steady-state chain builds add zero traces.
    """

    def build():
        from repro.kernels.ops import stream_gemm

        def local(acc, blk, right):
            program_cache_stats().note_trace()
            a_pan = blk
            if ctx.n_col_shards > 1:
                a_pan = lax.all_gather(a_pan, ctx.col_axes, axis=1, tiled=True)
            b_pan = right
            if ctx.n_row_shards > 1:
                b_pan = lax.all_gather(b_pan, ctx.row_axes, axis=0, tiled=True)
            return stream_gemm(a_pan, b_pan, acc, sign=1.0 if positive else -1.0)

        return jax.jit(
            shard_map(
                local,
                mesh=ctx.mesh,
                in_specs=(ctx.matrix_spec, ctx.matrix_spec, ctx.matrix_spec),
                out_specs=ctx.matrix_spec,
            )
        )

    key = ("oo_gemm_kernel", ctx, positive, blk_dtype, right_dtype, ph, n)
    return cached_program(key, build)


# ---------------------------------------------------------------------------
# host-side panel plumbing
# ---------------------------------------------------------------------------


def _auto_grid(n: int, quantum: int) -> int:
    """Default working-store grid: panels of >= 32 rows, >= 2 per side.

    Finer grids bound residency tighter but pay per-panel dispatch and tile
    I/O on every GEMM step; 32-row panels keep the inner GEMM MXU-shaped.
    Small n falls back to the finest quantum-aligned grid.
    """
    for g in (8, 4, 2):
        if n % g == 0 and (n // g) % quantum == 0 and n // g >= 32:
            return g
    for g in (16, 8, 4, 2, 1):
        if n % g == 0 and (n // g) % quantum == 0:
            return g
    raise ValueError(f"n={n} is not divisible by the panel quantum {quantum}")


# ---------------------------------------------------------------------------
# the out-of-core chain build
# ---------------------------------------------------------------------------


def chain_product_oocore(
    ctx: DistContext,
    a,
    d_len: int,
    *,
    dtype=jnp.float32,
    deflate: bool = True,
    fuse_l: bool = False,
    work=None,
    panel_rows: int | None = None,
    tile_codec: str = "raw",
    prefetch_depth: int | None = None,
    use_gemm_kernel: bool = False,
    level_sink: dict | None = None,
) -> ChainOperator:
    """Build the chain operator with store-backed working matrices.

    ``level_sink`` retains the intermediate chain levels as *live* scratch
    snapshots for incremental delta updates (see
    :func:`repro.core.chain.chain_product`): the usual eager removal of
    T/P intermediates is skipped for retained levels, ``level_sink["t"]``
    gets the T_0 .. T_{d-1} handles and ``level_sink["p"]`` the
    P_1 .. P_{d-2} handles, and the caller owns their lifetime
    (``delta_chain.BaseChain.release()`` removes them).

    ``a`` is a resident sharded adjacency or a store-backed snapshot handle
    (handles keep even the input off-core).  ``work`` is the scratch
    :class:`~repro.store.TileStore` -- a store instance, a directory path, or
    ``None`` for a host-RAM-backed scratch (device residency is bounded
    either way; the directory form additionally bounds host RAM).
    ``panel_rows`` overrides the streaming unit.

    All panel fetches go through :class:`repro.store.PanelPipeline`: a
    background thread keeps up to ``prefetch_depth`` panels per operand
    decoded and staged ahead of the GEMM/unary passes, so scratch reads (and
    codec decode) overlap device compute.  ``tile_codec`` selects the scratch
    tile encoding when this call creates the scratch store (``raw`` default;
    ``bf16`` halves scratch bytes at a per-level rounding of the working
    matrices, ``zstd`` compresses losslessly where the backend is installed)
    -- a caller-supplied ``work`` store keeps whatever codec it was created
    with.

    Every snapshot id in the scratch is prefixed with a fresh nonce, so one
    scratch store (or directory) can serve many builds -- including resumed
    processes -- without id collisions; intermediates are removed as soon as
    the recurrence no longer needs them, and only P1 / P2 survive the build
    (retired via ``ChainOperator.release_scratch`` by ``detect_anomalies``
    and by ``SequenceDetector`` as the operator leaves the two-snapshot
    window).  ``dtype`` is accepted for signature parity but ignored: the
    scratch and the returned operator are always fp32.

    ``use_gemm_kernel=True`` routes every chain GEMM step through the fused
    Pallas streaming kernel (:mod:`repro.kernels.stream_gemm`): operand
    panels ship in their *stored* form where the codec is device-decodable
    (bf16 bit patterns, half the H2D bytes, widened in VMEM) and the
    accumulate folds into the kernel.  Allclose vs the XLA step (same codec);
    interpret mode off-TPU.  The flag rides on the returned operator so the
    solve driver inherits the kernel path for its streamed iterations.
    """
    from repro.store import (  # deferred: core->store only on this path
        DEFAULT_PREFETCH_DEPTH,
        PanelPipeline,
        TileStore,
    )

    if d_len < 1:
        raise ValueError("chain length d must be >= 1")
    n = int(a.shape[0])
    R, C = ctx.n_row_shards, ctx.n_col_shards
    src_quantum = int(a.panel_rows) if is_streamable(a) else 1
    quantum = int(np.lcm.reduce(np.asarray([R, C, src_quantum], np.int64)))
    if work is None:
        work = TileStore.create(None, n=n, grid=_auto_grid(n, quantum), codec=tile_codec)
    elif isinstance(work, (str, Path)):
        work = TileStore.create(
            work, n=n, grid=_auto_grid(n, quantum), codec=tile_codec
        )
    if work.n != n:
        raise ValueError(f"working store holds n={work.n}, adjacency is n={n}")
    ph = int(panel_rows or np.lcm(work.tile_rows, quantum))
    if n % ph or ph % work.tile_rows or ph % quantum:
        raise ValueError(
            f"panel_rows={ph} must divide n={n} and align to store tiles "
            f"({work.tile_rows}) and the mesh/source quantum ({quantum})"
        )
    tag = f"w{uuid.uuid4().hex[:8]}."
    origins = list(range(0, n, ph))

    st = stream_stats()
    st.add(calls=1)
    sharding = ctx.sharding(ctx.matrix_spec)
    rep = ctx.sharding(P(None))

    deg = lap.degrees(ctx, a, prefetch_depth=prefetch_depth)
    vol = lap.volume(ctx, deg)
    deg_r = jax.device_put(deg, rep)
    inv_sqrt_r = jnp.where(deg_r > 0, lax.rsqrt(jnp.maximum(deg_r, 1e-30)), 0.0)

    def put_panel(host, decoded_nbytes: int | None = None):
        dev = jax.device_put(np.ascontiguousarray(np.asarray(host)), sharding)
        inc = {"panels": 1, "bytes_h2d": dev.nbytes}
        if decoded_nbytes is not None and decoded_nbytes > dev.nbytes:
            # Encoded (stored-width) put: the gap vs a host-decoded transfer.
            inc["bytes_h2d_saved"] = decoded_nbytes - dev.nbytes
        st.add(**inc)
        return dev

    def stream(source, walk=None, *, device: bool, encoded: bool = False):
        """A prefetching pipeline over row panels of one operand."""
        return PanelPipeline(
            [source],
            walk if walk is not None else origins,
            ph,
            depth=prefetch_depth,
            sharding=sharding if device else None,
            stats=st,
            encoded=encoded,
        )

    def unary_pass(out_id: str, source, fn, *args):
        """Stream panels through a jitted panel program into the store."""
        with obs_trace.span("oochain.unary", out=out_id), \
                work.writer(out_id) as w, stream(source, device=True) as pipe:
            for r0, (blk,) in pipe:
                # Resident sources bypass the pipeline's staging (and its
                # residency accounting): count the panel we just put ourselves.
                blk = blk if is_streamable(source) else put_panel(blk)
                live = pipe.device_live_bytes if is_streamable(source) else blk.nbytes
                out = fn(blk, jnp.int32(r0), *args)
                st._note_live(live + out.nbytes)
                w.put_row_panel(r0, np.asarray(out))
        return work.snapshot(out_id)

    def oo_gemm(out_id: str, left_h, right_h, *, init: str = "zero", sign: float = 1.0,
                col_scale=None):
        """C[I, :] = init_I + sign * sum_K left[I, K] @ right[K, :] into the store.

        ``init``: "zero", "left" (C = left + ...; the P @ T + P fusion) or
        "left_colscale" (C = left * col_scale - ...; the fuse_l P2 build).
        The left row panel stays on the host; only its (ph, ph) K-blocks, the
        streamed right panels and the accumulator are ever device-resident.
        Both operands are prefetched: the left panels one GEMM row ahead
        (host ring), the right panels along the full nested K-walk (device
        staging), so neither fetch serializes with the MXU.

        On the kernel path (``use_gemm_kernel``) both streams ship stored-
        form panels (bf16 -> uint16 bits) and each K step is one fused
        ``stream_gemm`` with the accumulator as init -- the decode moves into
        VMEM and the stored-vs-decoded H2D gap lands in ``bytes_h2d_saved``.
        """
        step = _gemm_step if sign > 0 else _gemm_step_neg
        nested = [k0 for _ in origins for k0 in origins]  # right walk, per row
        dec_panel = ph * n * 4  # fp32 bytes a host-decoded panel would ship
        with obs_trace.span("oochain.gemm", out=out_id, panels=len(origins)), \
                work.writer(out_id) as w, \
                stream(left_h, device=False, encoded=use_gemm_kernel) as lpipe, \
                stream(right_h, nested, device=True, encoded=use_gemm_kernel) as rpipe:
            right_iter = iter(rpipe)
            for r0, (left_host,) in lpipe:
                left_host = np.asarray(left_host)
                left_enc = left_host.dtype == np.uint16
                if init in ("left", "left_colscale"):
                    lp = put_panel(left_host, dec_panel if left_enc else None)
                    accp = _decode_bits_panel(lp) if left_enc else lp.astype(jnp.float32)
                    acc = accp if init == "left" else _col_scale_panel(accp, col_scale)
                else:
                    acc = sharded_zeros((ph, n), jnp.float32, sharding)
                for k0 in origins:
                    _, (right,) = next(right_iter)
                    if is_streamable(right_h):
                        right_live = rpipe.device_live_bytes
                    else:  # resident: our put_panel, not pipeline staging
                        right = put_panel(right)
                        right_live = right.nbytes
                    block = put_panel(
                        left_host[:, k0 : k0 + ph],
                        ph * ph * 4 if left_enc else None,
                    )
                    if use_gemm_kernel:
                        prog = _kernel_gemm_program(
                            ctx, sign > 0, str(block.dtype), str(right.dtype), ph, n
                        )
                        acc = prog(acc, block, right)
                    else:
                        acc = step(acc, block, right)
                    st._note_live(acc.nbytes + block.nbytes + right_live)
                w.put_row_panel(r0, np.asarray(acc))
        return work.snapshot(out_id)

    # S (= T at level 0) and P0 = I + S, in one pass over A.  Level ids use a
    # "lvl" infix so they can never collide with the final P1 / P2 outputs.
    s_id, p_id = tag + "Tlvl0", tag + "Plvl0"
    with obs_trace.span("oochain.s_build", n=n, panels=len(origins)), \
            work.writer(s_id) as ws, work.writer(p_id) as wp, \
            stream(a, device=True) as apipe:
        for r0, (blk,) in apipe:
            blk = blk if is_streamable(a) else put_panel(blk)
            a_live = apipe.device_live_bytes if is_streamable(a) else blk.nbytes
            if deflate:
                s_blk = _s_panel_deflated(blk, jnp.int32(r0), inv_sqrt_r, deg_r, vol)
            else:
                s_blk = _s_panel_plain(blk, jnp.int32(r0), inv_sqrt_r)
            p_blk = _plus_eye_panel(s_blk, jnp.int32(r0))
            st._note_live(a_live + s_blk.nbytes + p_blk.nbytes)
            ws.put_row_panel(r0, np.asarray(s_blk))
            wp.put_row_panel(r0, np.asarray(p_blk))
    t_h, p_h = work.snapshot(s_id), work.snapshot(p_id)

    # The squaring chain, every operand store-backed.  With a level_sink the
    # intermediates survive the build as live scratch snapshots (the delta
    # path streams skinny GEMMs against them); without one they are removed
    # as soon as the recurrence no longer needs them, as before.
    retain = level_sink is not None
    t_levels, p_levels = [t_h], []
    for lvl in range(1, d_len):
        p_levels.append(p_h)
        t_new = oo_gemm(f"{tag}Tlvl{lvl}", t_h, t_h)
        p_new = oo_gemm(f"{tag}Plvl{lvl}", p_h, t_new, init="left")
        t_levels.append(t_new)
        if not retain:
            work.remove_snapshot(t_h.snap_id)
            work.remove_snapshot(p_h.snap_id)
        t_h, p_h = t_new, p_new

    # the P1 sandwich is the same row/col scaling as the undeflated S build
    p1_h = unary_pass(tag + "P1", p_h, _s_panel_plain, inv_sqrt_r)
    if fuse_l:
        p2_h = oo_gemm(tag + "P2", p1_h, a, init="left_colscale", sign=-1.0,
                       col_scale=deg_r)
    else:
        l_h = unary_pass(tag + "L", a, _l_panel, deg_r)
        p2_h = oo_gemm(tag + "P2", p1_h, l_h)
        work.remove_snapshot(l_h.snap_id)
    if retain:
        # T_0..T_{d-1} and P_1..P_{d-2} stay live for the delta path; the
        # final P (never multiplied against) and the implicit P_0 = I + T_0
        # are not needed and die now.
        work.remove_snapshot(p_h.snap_id)
        if p_levels:
            work.remove_snapshot(p_levels[0].snap_id)
        level_sink["t"] = t_levels
        level_sink["p"] = p_levels[1:]
    else:
        work.remove_snapshot(t_h.snap_id)
        work.remove_snapshot(p_h.snap_id)

    # Measure the Richardson contraction rho(S~^{2^d}) once at build: the
    # power iteration wraps the store-backed P2 in a CachingHandle, so the
    # whole estimate costs one real scratch pass (replays from host RAM for
    # the rest).  The solve driver reads it for Chebyshev intervals.
    from repro.core.solvers.power import estimate_rho

    with obs_trace.span("oochain.estimate_rho", n=n):
        rho = estimate_rho(ctx, p2_h, prefetch_depth=prefetch_depth)
    return ChainOperator(
        p1=p1_h, p2=p2_h, deg=deg, vol=vol,
        prefetch_depth=prefetch_depth or DEFAULT_PREFETCH_DEPTH,
        rho=rho,
        use_gemm_kernel=use_gemm_kernel,
    )
