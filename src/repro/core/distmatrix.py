"""Distributed dense block matrices on a TPU mesh.

This is the JAX/TPU re-think of the paper's Spark RDD block matrix
(``((row_id, col_id), M)``): an ``n x n`` matrix is one ``jax.Array`` whose
NamedSharding tiles it into a beta x beta grid over the device mesh -- rows
over ``row_axes`` ("data", and "pod" when multi-pod), columns over
``col_axes`` ("model").

Three matmul *schedules* mirror the paper's design space:

- ``xla``     -- leave the collective schedule to XLA SPMD.  This is the
                 analogue of Spark's built-in ``BlockMatrix.multiply``: simple,
                 but it replicates a full operand panel per device
                 (all-gather), the moral equivalent of the shuffle.
- ``summa``   -- explicit one-panel-per-device SUMMA under shard_map:
                 all-gather A along the column axis (row panel) and B along the
                 row axis (column panel), one local GEMM.  Predictable, but
                 O(n^2/R + n^2/C) resident bytes per chip.
- ``cannon``  -- systolic Cannon rings under shard_map: pre-skew with
                 collective_permute, then R steps of (local GEMM + neighbor
                 shift).  O(n^2/P) resident bytes per chip and only
                 nearest-neighbor ICI traffic -- this is the TPU-native
                 "shuffle-free" streaming the paper builds on Lustre.  The
                 next-step permute is issued *before* the local GEMM so XLA's
                 latency-hiding scheduler overlaps communication with compute
                 (double buffering).

All schedules accumulate in fp32 (MXU-faithful) regardless of storage dtype.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.tiles import (
    cached_program,
    is_streamable,
    pcast_varying,
    program_cache_stats,
    shard_map,
    tile_map,
    tile_stream,
)

SCHEDULES = ("xla", "summa", "cannon")


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) if axes else 1


@dataclass(frozen=True)
class DistContext:
    """Mesh + axis-naming context for distributed block matrices."""

    mesh: Mesh
    row_axes: tuple[str, ...] = ("data",)
    col_axes: tuple[str, ...] = ("model",)

    @property
    def n_row_shards(self) -> int:
        return _axes_size(self.mesh, self.row_axes)

    @property
    def n_col_shards(self) -> int:
        return _axes_size(self.mesh, self.col_axes)

    @property
    def matrix_spec(self) -> P:
        return P(self.row_axes, self.col_axes)

    @property
    def rowblock_spec(self) -> P:
        """(n, k) tall-skinny operands: rows sharded, columns replicated."""
        return P(self.row_axes, None)

    @property
    def vector_spec(self) -> P:
        return P(self.row_axes)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x: jax.Array, spec: P) -> jax.Array:
        return lax.with_sharding_constraint(x, self.sharding(spec))

    def put_matrix(self, x) -> jax.Array:
        return jax.device_put(jnp.asarray(x), self.sharding(self.matrix_spec))

    def put_rowblock(self, x) -> jax.Array:
        return jax.device_put(jnp.asarray(x), self.sharding(self.rowblock_spec))


def make_context(
    mesh: Mesh,
    row_axes: Sequence[str] = ("data",),
    col_axes: Sequence[str] = ("model",),
) -> DistContext:
    return DistContext(mesh=mesh, row_axes=tuple(row_axes), col_axes=tuple(col_axes))


def trivial_context() -> DistContext:
    """Single-device 1x1 mesh context (tests / laptop runs)."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return DistContext(mesh=Mesh(dev, ("data", "model")))


# ---------------------------------------------------------------------------
# matmul schedules
# ---------------------------------------------------------------------------


def _local_dot(a: jax.Array, b: jax.Array, use_kernel: bool) -> jax.Array:
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.block_matmul(a, b, out_dtype=jnp.float32)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def _matmul_xla(ctx: DistContext, a, b, out_dtype):
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return ctx.constrain(out.astype(out_dtype), ctx.matrix_spec)


def _matmul_summa(ctx: DistContext, a, b, out_dtype, use_kernel=False):
    def build():
        row_ax, col_ax = ctx.row_axes, ctx.col_axes

        def local(a_blk, b_blk):
            program_cache_stats().note_trace()
            # Row panel of A (gather along column axis), column panel of B.
            a_panel = lax.all_gather(a_blk, col_ax, axis=1, tiled=True)
            b_panel = lax.all_gather(b_blk, row_ax, axis=0, tiled=True)
            return _local_dot(a_panel, b_panel, use_kernel).astype(out_dtype)

        return jax.jit(
            shard_map(
                local,
                mesh=ctx.mesh,
                in_specs=(ctx.matrix_spec, ctx.matrix_spec),
                out_specs=ctx.matrix_spec,
            )
        )

    key = ("summa", ctx, np.dtype(out_dtype).name, use_kernel)
    return cached_program(key, build)(a, b)


def _cannon_perms(R: int, C: int):
    """Static permutation tables over the flattened (rows..., cols...) axes."""
    skew_a = [(r * C + c, r * C + ((c - r) % C)) for r in range(R) for c in range(C)]
    skew_b = [(r * C + c, ((r - c) % R) * C + c) for r in range(R) for c in range(C)]
    shift_a = [(r * C + c, r * C + ((c - 1) % C)) for r in range(R) for c in range(C)]
    shift_b = [(r * C + c, ((r - 1) % R) * C + c) for r in range(R) for c in range(C)]
    return skew_a, skew_b, shift_a, shift_b


def _matmul_cannon(ctx: DistContext, a, b, out_dtype, use_kernel=False):
    R, C = ctx.n_row_shards, ctx.n_col_shards
    if R != C:
        raise ValueError(
            f"cannon schedule needs a square device grid, got {R}x{C}; "
            "use schedule='summa' (or make the pod axis an outer sequence axis)"
        )
    def build():
        axes = ctx.row_axes + ctx.col_axes
        skew_a, skew_b, shift_a, shift_b = _cannon_perms(R, C)

        def local(a_blk, b_blk):
            program_cache_stats().note_trace()
            a_blk = lax.ppermute(a_blk, axes, skew_a)
            b_blk = lax.ppermute(b_blk, axes, skew_b)
            # pcast-to-varying: the accumulator must carry the same
            # (data, model)-varying type as the per-step GEMM output.
            acc0 = pcast_varying(jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32), axes)

            def body(_, carry):
                acc, a_cur, b_cur = carry
                # Issue next-step permutes first: independent of the GEMM below, so
                # the latency-hiding scheduler overlaps ICI transfer with the MXU.
                a_nxt = lax.ppermute(a_cur, axes, shift_a)
                b_nxt = lax.ppermute(b_cur, axes, shift_b)
                acc = acc + _local_dot(a_cur, b_cur, use_kernel)
                return acc, a_nxt, b_nxt

            acc, _, _ = lax.fori_loop(0, R, body, (acc0, a_blk, b_blk))
            return acc.astype(out_dtype)

        return jax.jit(
            shard_map(
                local,
                mesh=ctx.mesh,
                in_specs=(ctx.matrix_spec, ctx.matrix_spec),
                out_specs=ctx.matrix_spec,
            )
        )

    key = ("cannon", ctx, np.dtype(out_dtype).name, use_kernel)
    return cached_program(key, build)(a, b)


def matmul(
    ctx: DistContext,
    a: jax.Array,
    b: jax.Array,
    *,
    schedule: str = "xla",
    out_dtype=None,
    use_kernel: bool = False,
) -> jax.Array:
    """C = A @ B over the mesh with the chosen collective schedule."""
    out_dtype = out_dtype or a.dtype
    if schedule == "xla":
        return _matmul_xla(ctx, a, b, out_dtype)
    if schedule == "summa":
        return _matmul_summa(ctx, a, b, out_dtype, use_kernel)
    if schedule == "cannon":
        return _matmul_cannon(ctx, a, b, out_dtype, use_kernel)
    raise ValueError(f"unknown schedule {schedule!r}; want one of {SCHEDULES}")


def _rowblock_body(tile, blk, x):
    return jnp.dot(
        blk.astype(jnp.float32),
        x[tile.cols].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def matmul_rowblock(
    ctx: DistContext,
    m: jax.Array,
    x: jax.Array,
    *,
    prefetch_depth: int | None = None,
) -> jax.Array:
    """(n x n) @ (n x k) with k << n: the Richardson mat-vec workhorse.

    m is matrix-sharded; x is row-sharded and tiny, so XLA's reduce-scatter /
    all-gather pair on the k-columns is cheap.  Always accumulates fp32.

    ``m`` may also be a store-backed snapshot handle (an out-of-core chain's
    P1 / P2): the mat-vec then streams row panels of m against the small
    replicated x (``prefetch_depth`` panels staged ahead by the panel
    pipeline), so the operator matrix is never device-resident -- the solver
    inherits the panel residency bound of the chain build.
    """
    if is_streamable(m):
        xr = ctx.constrain(x, P(None, None))
        out = tile_stream(
            ctx,
            _rowblock_body,
            m,
            xr,
            in_specs=(ctx.matrix_spec, P(None, None)),
            reduce="cols",
            out_spec=ctx.rowblock_spec,
            prefetch_depth=prefetch_depth,
        )
        return ctx.constrain(out.astype(x.dtype), ctx.rowblock_spec)
    out = jnp.dot(m, x.astype(jnp.float32), preferred_element_type=jnp.float32)
    return ctx.constrain(out.astype(x.dtype), ctx.rowblock_spec)


# ---------------------------------------------------------------------------
# blockwise constructors -- the "never load the graph" builders
# ---------------------------------------------------------------------------


def build_from_nodes(
    ctx: DistContext,
    feats: jax.Array,
    kernel_fn: Callable[[jax.Array, jax.Array], jax.Array],
    *,
    dtype=jnp.float32,
    zero_diagonal: bool = True,
) -> jax.Array:
    """Materialize A[i, j] = kernel_fn(feats[i], feats[j]) directly *sharded*.

    Each device computes only its local (n/R, n/C) tile from the (small)
    replicated node-feature table -- the n x n graph never exists centrally.
    This is how the climate graph (259200 nodes, 6.7e10 edges) is built.
    """
    n = feats.shape[0]
    R, C = ctx.n_row_shards, ctx.n_col_shards
    if n % R or n % C:
        raise ValueError(f"n={n} must divide the {R}x{C} shard grid")

    def tile_fn(tile, f):
        blk = kernel_fn(f[tile.rows], f[tile.cols]).astype(dtype)
        if zero_diagonal:
            blk = jnp.where(tile.diag_mask(), jnp.zeros((), dtype), blk)
        return blk

    return tile_map(ctx, tile_fn, feats, grid=(n, n), in_specs=(P(None, None),))


def blockwise_unary(
    ctx: DistContext,
    fn: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
    x: jax.Array,
    *,
    out_dtype=None,
    prefetch_depth: int | None = None,
) -> jax.Array:
    """Apply ``fn(block, global_rows, global_cols) -> block`` tile-locally.

    ``x`` may be a store-backed snapshot handle (see :mod:`repro.store`): the
    transform then *streams* -- each row panel is fetched from host/disk
    (``prefetch_depth`` panels staged ahead), transformed, and written into
    the sharded output, so the raw input is never device-resident (this is
    how the chain build materializes S and L without ever loading A).
    """
    out_dtype = out_dtype or x.dtype
    body = lambda tile, blk: fn(blk, tile.rows, tile.cols)
    if is_streamable(x):
        return tile_stream(ctx, body, x, out_dtype=out_dtype, prefetch_depth=prefetch_depth)
    return tile_map(ctx, body, x, out_dtype=out_dtype)


def _add_scaled_identity_body(tile, blk, s):
    return blk + s * tile.diag_mask().astype(blk.dtype)


def add_scaled_identity(ctx: DistContext, x: jax.Array, scale=1.0) -> jax.Array:
    """x + scale * I without materializing I (used for P <- P @ T + P etc.).

    The scale rides along as a scalar operand (not a closure constant) so the
    tile program is compiled once per mesh/geometry, not once per call.
    Resident operands only: every caller applies this to an already-resident
    chain matrix (the out-of-core chain has its own panel program).
    """
    s = jnp.asarray(scale, x.dtype)
    return tile_map(ctx, _add_scaled_identity_body, x, s, in_specs=(ctx.matrix_spec, P()))
