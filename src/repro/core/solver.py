"""Richardson-preconditioned SDD solve (paper Algorithm 2, EstimateSolution).

Given the precomputed chain operator Z^ ~= L^+, refine x ~ L^+ b with

    chi      = Z^ b
    y_{k+1}  = y_k - (Z^ L) y_k + chi        (q = ceil(log 1/delta) iterations)

i.e. classic preconditioned Richardson: y <- y + Z^(b - L y).  Convergence on
the 1-orthogonal subspace is governed by rho(S~^{2^d}) = lambda_2^{2^d} < 1.

All right-hand sides are batched: b is (n, k_RP) and every iteration is one
skinny GEMM -- the paper's key refactor (chain precomputed once, iterations are
mat-vec) carries over verbatim and is what makes k_RP solves cheap.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.chain import ChainOperator
from repro.core.distmatrix import DistContext, matmul_rowblock
from repro.core.tiles import is_streamable


def deflate_constant(ctx: DistContext, y: jax.Array) -> jax.Array:
    """Remove the all-ones (Laplacian nullspace) component from each column.

    Solutions of L z = y are defined up to a constant shift, which cancels in
    commute distances; removing it keeps bf16/fp32 iterates from drifting.
    The result is constrained to the row-sharded layout so the mean-subtract
    (an all-reduce over rows) can't silently regather the operand.
    """
    mean = jnp.mean(y.astype(jnp.float32), axis=0, keepdims=True)
    out = (y.astype(jnp.float32) - mean).astype(y.dtype)
    return ctx.constrain(out, ctx.rowblock_spec)


def estimate_solution(
    ctx: DistContext,
    op: ChainOperator,
    b: jax.Array,
    q_iters: int,
    *,
    deflate: bool = True,
) -> jax.Array:
    """x* ~= L^+ b for each of the k columns of b (row-sharded (n, k))."""
    if q_iters < 1:
        raise ValueError("q must be >= 1")
    b = ctx.constrain(b, ctx.rowblock_spec)
    chi = matmul_rowblock(ctx, op.p1, b)
    if deflate:
        chi = deflate_constant(ctx, chi)

    if is_streamable(op.p1) or is_streamable(op.p2):
        # Out-of-core operator: the mat-vec streams store panels on the host,
        # so the iteration must stay a Python loop (a traced lax.scan body
        # cannot fetch panels).  q is small; each step re-streams P2 once.
        y = chi
        for _ in range(q_iters - 1):
            y = y - matmul_rowblock(ctx, op.p2, y) + chi
            if deflate:
                y = deflate_constant(ctx, y)
        return y

    def body(y, _):
        y = y - matmul_rowblock(ctx, op.p2, y) + chi
        if deflate:
            y = deflate_constant(ctx, y)
        return y, None

    y, _ = lax.scan(body, chi, None, length=q_iters - 1)
    return y


def residual_norm(ctx: DistContext, l_mat: jax.Array, x: jax.Array, b: jax.Array) -> jax.Array:
    """||L x - b||_F / ||b||_F -- the solver's acceptance metric in tests."""
    r = matmul_rowblock(ctx, l_mat, x) - b
    num = jnp.sqrt(jnp.sum(r.astype(jnp.float32) ** 2))
    den = jnp.sqrt(jnp.sum(b.astype(jnp.float32) ** 2))
    return num / jnp.maximum(den, 1e-30)
