"""Richardson-preconditioned SDD solve (paper Algorithm 2, EstimateSolution).

Given the precomputed chain operator Z^ ~= L^+, refine x ~ L^+ b with

    chi      = Z^ b
    y_{k+1}  = y_k - (Z^ L) y_k + chi        (q = ceil(log 1/delta) iterations)

i.e. classic preconditioned Richardson: y <- y + Z^(b - L y).  Convergence on
the 1-orthogonal subspace is governed by rho(S~^{2^d}) = lambda_2^{2^d} < 1.

All right-hand sides are batched: b is (n, k_RP) and every iteration is one
skinny GEMM -- the paper's key refactor (chain precomputed once, iterations are
mat-vec) carries over verbatim and is what makes k_RP solves cheap.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.chain import ChainOperator
from repro.core.distmatrix import DistContext, matmul_rowblock
from repro.core.tiles import is_streamable


def deflate_constant(ctx: DistContext, y: jax.Array) -> jax.Array:
    """Remove the all-ones (Laplacian nullspace) component from each column.

    Solutions of L z = y are defined up to a constant shift, which cancels in
    commute distances; removing it keeps bf16/fp32 iterates from drifting.
    The result is constrained to the row-sharded layout so the mean-subtract
    (an all-reduce over rows) can't silently regather the operand.
    """
    mean = jnp.mean(y.astype(jnp.float32), axis=0, keepdims=True)
    out = (y.astype(jnp.float32) - mean).astype(y.dtype)
    return ctx.constrain(out, ctx.rowblock_spec)


def estimate_solution(
    ctx: DistContext,
    op: ChainOperator,
    b: jax.Array,
    q_iters: int,
    *,
    deflate: bool = True,
    solver_batch: int = 1,
    prefetch_depth: int | None = None,
) -> jax.Array:
    """x* ~= L^+ b for each of the k columns of b (row-sharded (n, k)).

    Out-of-core operators (store-backed P1/P2) stream their panels through
    the panel pipeline; ``prefetch_depth`` (default: the operator's build
    depth) sets the staging depth.  ``solver_batch=b`` batches the Richardson
    iterations against the *scratch store*: P2 is streamed from the store
    once per batch of b iterations and its decoded panels are replayed from
    a host-RAM cache for the remaining b-1 (see
    :class:`repro.store.CachingHandle`), cutting solve-phase scratch reads
    ~b x.  The replayed panels are bitwise identical to re-streamed ones, so
    batching never changes the solution; host cost is one decoded P2 (n^2
    bytes) while the solve runs.  Ignored for resident operators (nothing
    streams).
    """
    if q_iters < 1:
        raise ValueError("q must be >= 1")
    if solver_batch < 1:
        raise ValueError("solver_batch must be >= 1")
    depth = prefetch_depth if prefetch_depth is not None else getattr(
        op, "prefetch_depth", None
    )
    b = ctx.constrain(b, ctx.rowblock_spec)
    chi = matmul_rowblock(ctx, op.p1, b, prefetch_depth=depth)
    if deflate:
        chi = deflate_constant(ctx, chi)

    if is_streamable(op.p1) or is_streamable(op.p2):
        # Out-of-core operator: the mat-vec streams store panels on the host,
        # so the iteration must stay a Python loop (a traced lax.scan body
        # cannot fetch panels).  q is small; each batch of solver_batch
        # steps streams P2 from the store once and replays it from host RAM.
        p2, cached = op.p2, None
        if solver_batch > 1 and is_streamable(op.p2):
            from repro.store import CachingHandle  # deferred: optional path

            p2 = cached = CachingHandle(op.p2)
        y = chi
        for it in range(q_iters - 1):
            if cached is not None and it and it % solver_batch == 0:
                cached.refresh()  # batch boundary: next pass re-streams the store
            y = y - matmul_rowblock(ctx, p2, y, prefetch_depth=depth) + chi
            if deflate:
                y = deflate_constant(ctx, y)
        return y

    def body(y, _):
        y = y - matmul_rowblock(ctx, op.p2, y) + chi
        if deflate:
            y = deflate_constant(ctx, y)
        return y, None

    y, _ = lax.scan(body, chi, None, length=q_iters - 1)
    return y


def residual_norm(ctx: DistContext, l_mat: jax.Array, x: jax.Array, b: jax.Array) -> jax.Array:
    """||L x - b||_F / ||b||_F -- the solver's acceptance metric in tests."""
    r = matmul_rowblock(ctx, l_mat, x) - b
    num = jnp.sqrt(jnp.sum(r.astype(jnp.float32) ** 2))
    den = jnp.sqrt(jnp.sum(b.astype(jnp.float32) ** 2))
    return num / jnp.maximum(den, 1e-30)
