"""Richardson-preconditioned SDD solve (paper Algorithm 2, EstimateSolution).

Given the precomputed chain operator Z^ ~= L^+, refine x ~ L^+ b with

    chi      = Z^ b
    y_{k+1}  = y_k - (Z^ L) y_k + chi        (q = ceil(log 1/delta) iterations)

i.e. classic preconditioned Richardson: y <- y + Z^(b - L y).  Convergence on
the 1-orthogonal subspace is governed by rho(S~^{2^d}) = lambda_2^{2^d} < 1.

All right-hand sides are batched: b is (n, k_RP) and every iteration is one
skinny GEMM -- the paper's key refactor (chain precomputed once, iterations are
mat-vec) carries over verbatim and is what makes k_RP solves cheap.

This module is now a thin compatibility shim over the pluggable solver
subsystem (:mod:`repro.core.solvers`): the unified :func:`~repro.core.solvers.solve`
driver owns the resident/streamed branching, tolerance-targeted stopping and
the Chebyshev accelerator; ``estimate_solution`` maps the historical
fixed-``q`` Richardson call onto it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.chain import ChainOperator
from repro.core.distmatrix import DistContext, matmul_rowblock
from repro.core.solvers import SolverSpec, solve
from repro.core.solvers.driver import deflate_constant  # re-export (back-compat)

__all__ = ["deflate_constant", "estimate_solution", "residual_norm"]


def estimate_solution(
    ctx: DistContext,
    op: ChainOperator,
    b: jax.Array,
    q_iters: int,
    *,
    deflate: bool = True,
    solver_batch: int = 1,
    prefetch_depth: int | None = None,
) -> jax.Array:
    """x* ~= L^+ b for each of the k columns of b (row-sharded (n, k)).

    Fixed-iteration Richardson through the unified solve driver
    (:func:`repro.core.solvers.solve`): ``y0 = chi`` then ``q_iters - 1``
    refinement steps, exactly the historical loop.  Callers that want
    tolerance-targeted stopping, the Chebyshev accelerator, or the
    :class:`~repro.core.solvers.SolveReport` telemetry should call the driver
    directly with a :class:`~repro.core.solvers.SolverSpec`.

    Out-of-core operators (store-backed P1/P2) stream their panels through
    the panel pipeline; ``prefetch_depth`` (default: the operator's build
    depth) sets the staging depth.  ``solver_batch=b`` batches the iterations
    against the *scratch store*: P2 is streamed from the store once per batch
    of b iterations and its decoded panels are replayed from a host-RAM cache
    for the remaining b-1 (see :class:`repro.store.CachingHandle`), cutting
    solve-phase scratch reads ~b x without changing the solution (replayed
    panels are bitwise identical).  Ignored for resident operators (nothing
    streams).
    """
    if q_iters < 1:
        raise ValueError("q must be >= 1")
    if solver_batch < 1:
        raise ValueError("solver_batch must be >= 1")
    y, _ = solve(
        ctx,
        op,
        b,
        SolverSpec(method="richardson"),
        fixed_q=q_iters,
        deflate=deflate,
        solver_batch=solver_batch,
        prefetch_depth=prefetch_depth,
    )
    return y


def residual_norm(
    ctx: DistContext,
    l_mat,
    x: jax.Array,
    b: jax.Array,
    *,
    prefetch_depth: int | None = None,
) -> jax.Array:
    """||L x - b||_F / ||b||_F -- the solver's acceptance metric.

    ``l_mat`` may be a resident sharded Laplacian or a store-backed snapshot
    handle: the mat-vec routes through :func:`matmul_rowblock`, whose
    streamed branch fetches row panels via the panel pipeline
    (``prefetch_depth`` staged ahead), so tolerance-targeted stopping can be
    validated end-to-end out-of-core without materializing L on device.
    """
    r = matmul_rowblock(ctx, l_mat, x, prefetch_depth=prefetch_depth) - b
    num = jnp.sqrt(jnp.sum(r.astype(jnp.float32) ** 2))
    den = jnp.sqrt(jnp.sum(b.astype(jnp.float32) ** 2))
    return num / jnp.maximum(den, 1e-30)
