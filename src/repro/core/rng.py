"""Counter-based (stateless) RNG for edge-space random projection.

The Spielman-Srivastava projection needs a Rademacher vector q of length
m = n^2 (one entry per edge).  Materializing q is exactly the
"larger-than-memory" trap the paper avoids with Spark streaming; the TPU-native
equivalent is to *never store q at all*: every entry is a pure integer hash of
(seed, i, j, projection_column), so any device can (re)generate any tile of the
edge randomness on the fly, bit-exactly, with no communication and no storage.

The hash is a splitmix32-style finalizer over uint32 lanes.  It is written in
plain jnp ops so the identical code runs inside a Pallas kernel body, in the
pure-jnp oracle, and under vmap/jit -- the kernel and the reference are
bit-identical by construction.

Antisymmetry convention: the incidence matrix orients every edge {i, j} (i<j)
from head i to tail j, so q contributes +q_e to row i and -q_e to row j.  We
encode this as an antisymmetric matrix Q with Q[i, j] = -Q[j, i] and
Q[i, i] = 0, generated from the canonical (min, max) pair.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# numpy scalars (not jnp arrays): they fold into jaxprs as literals, so the
# hash can run inside Pallas kernel bodies without captured-constant errors.
_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)
_GOLD = np.uint32(0x9E3779B9)


def splitmix32(h: jax.Array) -> jax.Array:
    """splitmix32 finalizer; uniform uint32 -> uint32 bijection."""
    with np.errstate(over="ignore"):  # uint32 wraparound is the point
        h = jnp.asarray(h).astype(jnp.uint32) if not isinstance(h, np.uint32) else h
        h = (h ^ (h >> np.uint32(16))) * _M1
        h = (h ^ (h >> np.uint32(15))) * _M2
        return h ^ (h >> np.uint32(16))


def _u32(x) -> jax.Array | np.uint32:
    """Python ints fold to numpy literals (Pallas-safe); arrays are cast."""
    if isinstance(x, (int, np.integer)):
        return np.uint32(x & 0xFFFFFFFF)
    return jnp.asarray(x).astype(jnp.uint32)


def hash_u32(*parts: jax.Array) -> jax.Array:
    """Combine integer streams into one uniform uint32 stream."""
    h = np.uint32(0x243F6A88)  # pi fractional bits
    with np.errstate(over="ignore"):  # uint32 wraparound is the point
        for p in parts:
            h = splitmix32(h ^ (_u32(p) * _GOLD + _GOLD))
    return h


def edge_rademacher(
    seed: jax.Array | int,
    rows: jax.Array,
    cols: jax.Array,
    col_id: jax.Array | int,
) -> jax.Array:
    """Antisymmetric Rademacher field Q[i, j] in {-1, 0, +1} (0 on diagonal).

    ``rows``/``cols`` are (broadcastable) global index arrays; ``col_id`` is the
    projection-column counter.  Q[i, j] = -Q[j, i]; entries for i<j are iid
    +/-1 with p=1/2, keyed on (seed, min, max, col_id).
    """
    rows = jnp.asarray(rows)
    cols = jnp.asarray(cols)
    lo = jnp.minimum(rows, cols)
    hi = jnp.maximum(rows, cols)
    h = hash_u32(_u32(seed), lo, hi, _u32(col_id))
    base = 1.0 - 2.0 * (h >> 31).astype(jnp.float32)  # +/-1 from top bit
    orient = jnp.where(rows < cols, 1.0, -1.0).astype(jnp.float32)
    return jnp.where(rows == cols, 0.0, base * orient)


def uniform01(seed: jax.Array | int, *parts: jax.Array) -> jax.Array:
    """Uniform float32 in [0, 1) keyed on integer counters."""
    h = hash_u32(jnp.asarray(seed, jnp.uint32), *parts)
    return h.astype(jnp.float32) * jnp.float32(2.0**-32)
