"""Query-scale read path: serve top-k / nearest-neighbor / pairwise reads
from a persisted embedding artifact.

The write path (chain build + solve) costs O(n^3) GEMM work per snapshot;
once :class:`~repro.store.embstore.EmbeddingStore` holds the committed
(Z, vol, deg) sketch, every read is O(n k_RP) streamed through the fused
distance/top-k kernel (:mod:`repro.kernels.emb_query`):

* :func:`top_anomalies_from_store` -- the k most anomalous nodes "now",
  scored by commute distance to the volume centroid ``zbar`` (the ranking is
  identical to mean commute distance to all nodes: the cross terms collapse
  to a per-query constant).  ``corrected=True`` swaps in the von Luxburg
  amplified score ``C/vol - 1/deg_i - 1/deg_j`` (arXiv 1003.1266) -- on
  large dense graphs raw commute times degenerate to the degree term, and
  the corrected scorer subtracts exactly that.
* :func:`nearest_neighbors` -- the k closest nodes to one node, self
  excluded in-kernel.
* :func:`commute_block` -- the (rows x cols) distance block for a handful of
  node pairs, indices validated (no silent clamping gathers).

All streamed queries are panel-bounded: Z travels in row panels through
:class:`~repro.store.PanelPipeline` (encoded shipping: a bf16 artifact
crosses H2D at stored width and widens in VMEM), device residency is two
panels plus the O(q topk) running state, and the per-query top-k merge runs
inside the kernel -- no n-length score vector, let alone an n x n block, is
ever materialized.  Every query runs under a ``phase("query")`` span and
accounts ``query.{panels,bytes_read,latency_ms,calls}`` in the process
metrics registry.

``caddelag-query`` (:func:`main`) is the CLI entry over a store directory.
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass

import numpy as np

from repro.core.embedding import validate_node_indices
from repro.obs import REGISTRY, phase

__all__ = [
    "QueryResult",
    "commute_block",
    "main",
    "nearest_neighbors",
    "rank_auc",
    "top_anomalies_from_store",
]


@dataclass
class QueryResult:
    """One answered query plus its cost telemetry."""

    idx: np.ndarray  # (k,) node ids, best first (-1 in unfilled slots)
    val: np.ndarray  # (k,) scores (raw commute or corrected, see `corrected`)
    emb_id: str
    corrected: bool
    panels: int  # Z row panels streamed
    bytes_read: int  # backing-tier bytes served (pre-decode)
    latency_ms: float


def _resolve_handle(store, emb_id: str | None):
    """An :class:`EmbeddingHandle` from a store or a handle (duck-typed).

    Handles carry their ``emb_id``; stores don't (their ``read_panel`` takes
    one as an argument -- so that name can't disambiguate).
    """
    if hasattr(store, "emb_id"):  # already a handle
        return store
    return store.latest() if emb_id is None else store.embedding(emb_id)


def _streamed_topk(
    handle,
    zq: np.ndarray,
    inv_deg_q: np.ndarray,
    *,
    topk: int,
    corrected: bool,
    largest: bool,
    exclude: np.ndarray | None = None,
    prefetch_depth: int | None = None,
    interpret: bool | None = None,
) -> tuple[np.ndarray, np.ndarray, int]:
    """One pass over the artifact's Z panels; returns (vals, ids, n_panels).

    The running (q, topk) state threads through the kernel call per panel --
    identical shapes every call, so the whole stream reuses one compiled
    program regardless of n.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.tiles import stream_stats
    from repro.kernels.emb_query import panel_topk_update, topk_init
    from repro.store.pipeline import PanelPipeline

    n, _ = handle.shape
    pr = handle.panel_rows
    topk = min(int(topk), n)
    origins = list(range(0, n, pr))
    zq_dev = jnp.asarray(np.asarray(zq, np.float32))
    q = zq_dev.shape[0]
    idq = jnp.asarray(np.asarray(inv_deg_q, np.float32).reshape(q, 1))
    inv_deg = handle.inv_deg()
    vol = handle.vol
    ex = jnp.asarray(
        np.full((q, 1), -1, np.int32)
        if exclude is None
        else np.asarray(exclude, np.int32).reshape(q, 1)
    )
    vals, idx = topk_init(q, topk, largest=largest)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    n_panels = 0
    with PanelPipeline(
        [handle], origins, pr,
        depth=prefetch_depth, sharding=sharding, stats=stream_stats(),
        encoded=True,
    ) as pipe:
        for row0, (zp,) in pipe:
            idp = jnp.asarray(inv_deg[None, row0 : row0 + pr])
            vals, idx = panel_topk_update(
                vals, idx, zq_dev, zp, idq, idp, vol, row0, ex,
                topk=topk, corrected=corrected, largest=largest,
                interpret=interpret,
            )
            n_panels += 1
    return np.asarray(vals), np.asarray(idx), n_panels


def _run_query(kind: str, handle, fn, **span_args) -> QueryResult:
    """Shared telemetry wrapper: span, counters, latency."""
    t0 = time.perf_counter()
    m0 = REGISTRY.snapshot()
    with phase("query", kind=kind, emb_id=handle.emb_id, **span_args):
        vals, ids, n_panels = fn()
    dt_ms = (time.perf_counter() - t0) * 1e3
    bytes_read = int(REGISTRY.delta(m0).get("stream.bytes_read", 0.0))
    REGISTRY.add_named(
        {
            "query.calls": 1.0,
            "query.panels": float(n_panels),
            "query.bytes_read": float(bytes_read),
            "query.latency_ms": dt_ms,
        }
    )
    return vals, ids, n_panels, bytes_read, dt_ms


def top_anomalies_from_store(
    store,
    k: int = 10,
    *,
    emb_id: str | None = None,
    corrected: bool = False,
    prefetch_depth: int | None = None,
    interpret: bool | None = None,
) -> QueryResult:
    """The k most anomalous nodes of one committed embedding artifact.

    Scores each node by its commute distance to the volume centroid ``zbar``
    (persisted with the artifact): ``vol * ||z_j - zbar||^2``, whose ranking
    equals mean commute distance to all nodes.  ``corrected=True`` scores
    ``||z_j - zbar||^2 - mean(1/deg) - 1/deg_j`` instead -- the von Luxburg
    amplified distance, which discounts the degenerate degree term that
    dominates raw commute times on large dense graphs.

    ``store`` is an :class:`~repro.store.embstore.EmbeddingStore` (serving
    ``emb_id``, default latest) or an ``EmbeddingHandle`` directly.
    """
    handle = _resolve_handle(store, emb_id)
    zq = handle.zbar.reshape(1, -1)
    inv_q = np.asarray([handle.inv_deg().mean()], np.float32)

    def run():
        return _streamed_topk(
            handle, zq, inv_q,
            topk=k, corrected=corrected, largest=True,
            prefetch_depth=prefetch_depth, interpret=interpret,
        )

    vals, ids, n_panels, bytes_read, dt_ms = _run_query(
        "top_anomalies", handle, run, corrected=corrected, k=k
    )
    return QueryResult(
        idx=ids[0], val=vals[0], emb_id=handle.emb_id, corrected=corrected,
        panels=n_panels, bytes_read=bytes_read, latency_ms=dt_ms,
    )


def nearest_neighbors(
    store,
    node: int,
    k: int = 10,
    *,
    emb_id: str | None = None,
    corrected: bool = False,
    prefetch_depth: int | None = None,
    interpret: bool | None = None,
) -> QueryResult:
    """The k nearest (smallest commute distance) neighbors of ``node``,
    self excluded in-kernel.  Same streaming contract as
    :func:`top_anomalies_from_store`."""
    handle = _resolve_handle(store, emb_id)
    n = handle.shape[0]
    validate_node_indices("node", node, n)
    zq = handle.read_rows([int(node)])
    inv_q = handle.inv_deg()[[int(node)]]
    exclude = np.asarray([int(node)], np.int32)

    def run():
        return _streamed_topk(
            handle, zq, inv_q,
            topk=min(k, n - 1), corrected=corrected, largest=False,
            exclude=exclude, prefetch_depth=prefetch_depth,
            interpret=interpret,
        )

    vals, ids, n_panels, bytes_read, dt_ms = _run_query(
        "nearest_neighbors", handle, run, corrected=corrected, k=k, node=int(node)
    )
    return QueryResult(
        idx=ids[0], val=vals[0], emb_id=handle.emb_id, corrected=corrected,
        panels=n_panels, bytes_read=bytes_read, latency_ms=dt_ms,
    )


def commute_block(
    store,
    rows,
    cols,
    *,
    emb_id: str | None = None,
    corrected: bool = False,
) -> np.ndarray:
    """The (rows x cols) commute-distance block from a persisted artifact.

    ``c(i, j) = vol * ||z_i - z_j||^2`` (raw) or the von Luxburg amplified
    ``||z_i - z_j||^2 - 1/deg_i - 1/deg_j`` (``corrected=True``).  Indices
    are validated -- out-of-range ids raise ``IndexError`` naming the bad
    index and n, instead of jax's silent clamping gather.  Gathers O(|rows| +
    |cols|) Z rows via host panel reads; intended for handfuls of pairs, not
    n-scale scans (those are :func:`top_anomalies_from_store`'s job).
    """
    handle = _resolve_handle(store, emb_id)
    n = handle.shape[0]
    validate_node_indices("rows", rows, n)
    validate_node_indices("cols", cols, n)
    rows = np.asarray(rows).reshape(-1)
    cols = np.asarray(cols).reshape(-1)
    zi = handle.read_rows(rows).astype(np.float64)
    zj = handle.read_rows(cols).astype(np.float64)
    dist2 = np.maximum(
        (zi * zi).sum(-1)[:, None]
        + (zj * zj).sum(-1)[None, :]
        - 2.0 * zi @ zj.T,
        0.0,
    )
    if corrected:
        inv = handle.inv_deg().astype(np.float64)
        return (dist2 - inv[rows][:, None] - inv[cols][None, :]).astype(np.float32)
    return (handle.vol * dist2).astype(np.float32)


def rank_auc(labels, scores) -> float:
    """ROC-AUC via tie-averaged ranks (dependency-free Mann-Whitney U).

    ``labels`` boolean-ish (1 = anomaly), ``scores`` higher-is-more-anomalous.
    """
    labels = np.asarray(labels).astype(bool).reshape(-1)
    scores = np.asarray(scores, np.float64).reshape(-1)
    if labels.shape != scores.shape:
        raise ValueError(f"labels {labels.shape} vs scores {scores.shape}")
    n_pos = int(labels.sum())
    n_neg = labels.size - n_pos
    if n_pos == 0 or n_neg == 0:
        raise ValueError("rank_auc needs at least one positive and one negative")
    order = np.argsort(scores, kind="mergesort")
    _, inverse, counts = np.unique(scores[order], return_inverse=True, return_counts=True)
    ends = np.cumsum(counts)
    avg_rank_per_value = (ends - counts + 1 + ends) / 2.0
    ranks = np.empty(scores.size, np.float64)
    ranks[order] = avg_rank_per_value[inverse]
    u = ranks[labels].sum() - n_pos * (n_pos + 1) / 2.0
    return float(u / (n_pos * n_neg))


# ---------------------------------------------------------------------------
# caddelag-query CLI
# ---------------------------------------------------------------------------


def main(argv: list[str] | None = None) -> int:
    from repro.store.embstore import EmbeddingStore

    p = argparse.ArgumentParser(
        prog="caddelag-query",
        description="Serve top-k anomaly / nearest-neighbor queries from a "
        "persisted embedding artifact (no chain build, no solve).",
    )
    p.add_argument("--store", required=True, help="EmbeddingStore directory")
    p.add_argument("--id", default=None, help="embedding id (default: latest)")
    p.add_argument("--top-k", type=int, default=10)
    p.add_argument(
        "--corrected", action="store_true",
        help="von Luxburg amplified score C/vol - 1/deg_i - 1/deg_j",
    )
    p.add_argument(
        "--neighbors", type=int, default=None, metavar="NODE",
        help="nearest neighbors of NODE instead of top anomalies",
    )
    p.add_argument("--prefetch-depth", type=int, default=None)
    args = p.parse_args(argv)

    store = EmbeddingStore.open(args.store)
    handle = _resolve_handle(store, args.id)
    print(
        f"[caddelag-query] store={args.store} id={handle.emb_id} "
        f"n={handle.shape[0]} k={handle.shape[1]} "
        f"panel_rows={handle.panel_rows} codec={store.manifest.codec} "
        f"scorer={'corrected' if args.corrected else 'raw'}"
    )
    if args.neighbors is not None:
        res = nearest_neighbors(
            handle, args.neighbors, args.top_k,
            corrected=args.corrected, prefetch_depth=args.prefetch_depth,
        )
        print(f"[caddelag-query] nearest neighbors of node {args.neighbors}:")
    else:
        res = top_anomalies_from_store(
            handle, args.top_k,
            corrected=args.corrected, prefetch_depth=args.prefetch_depth,
        )
        print("[caddelag-query] top anomalies (commute distance to centroid):")
    for rank, (i, v) in enumerate(zip(res.idx, res.val)):
        if i < 0:
            break
        print(f"  #{rank + 1:<3d} node {int(i):<8d} score {float(v):.6g}")
    print(
        f"[caddelag-query] panels={res.panels} bytes_read={res.bytes_read} "
        f"latency_ms={res.latency_ms:.2f}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
