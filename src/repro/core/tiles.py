"""Unified tile-program layer: every blockwise computation in the core.

The paper's Spark job graph is a pile of near-identical map stages: "for the
(r, c) block of the n x n matrix, recover the global row/column ids, compute
something tile-local, optionally reduce across the block row".  The JAX port
accumulated five hand-rolled copies of that shard_map pattern; this module
owns it once.

``tile_map(ctx, fn, *operands)`` runs ``fn(tile, *local_blocks)`` on every
device with a :class:`Tile` describing the device's (rows, cols) window of the
global grid, and stitches the local outputs back into one sharded array.
An optional ``reduce="cols"`` psums the per-tile result across the column
axis (the Map+ReduceByKey of the paper).  Tile bodies are ordinary traced JAX,
so they can drop into a Pallas kernel for the inner loop (see
``node_anomaly_scores``) -- the tile program handles distribution, the kernel
handles the single-chip schedule.

``tile_stream(ctx, fn, *operands)`` is the out-of-core twin: operands may be
store-backed snapshot handles (see :mod:`repro.store`) instead of resident
arrays, and the same tile bodies run over row panels fetched from host/disk
with double-buffered host->device prefetch.  Device residency is bounded by
two panels per streamed operand, not by n^2 -- the row-parallel tile programs
(degrees, edge projection, CAD scoring, blockwise builds) are bitwise
identical to their resident runs because each output row sees exactly the
same per-device reduction extents either way.

This module also owns the version-compat shims for the manual-sharding API
(``jax.shard_map`` vs ``jax.experimental.shard_map``; ``lax.pcast`` /
``lax.pvary`` vs nothing) so the rest of the core is version-agnostic.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.obs import trace as obs_trace
from repro.obs.metrics import REGISTRY as _OBS_REGISTRY
from repro.obs.metrics import MetricsRegistry

# ---------------------------------------------------------------------------
# version compat: manual-sharding API surface
# ---------------------------------------------------------------------------

try:  # jax >= 0.5: top-level export with varying-type checking built in
    _shard_map = jax.shard_map
    _COMPAT_KWARGS: dict[str, Any] = {}
except AttributeError:  # jax 0.4.x: experimental module; disable rep checking
    from jax.experimental.shard_map import shard_map as _shard_map

    _COMPAT_KWARGS = {"check_rep": False}

import inspect as _inspect

_SHARD_MAP_PARAMS = frozenset(_inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check=None):
    """``jax.shard_map`` across jax versions (old versions skip rep checks).

    ``axis_names`` restricts manual sharding to those mesh axes (mapped to the
    old API's complementary ``auto=`` set); ``check=False`` disables varying-
    type checking where the installed jax supports toggling it.
    """
    kw = dict(_COMPAT_KWARGS)
    if check is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kw["check_vma"] = check
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kw["check_rep"] = check
    if axis_names is not None:
        if "axis_names" in _SHARD_MAP_PARAMS:
            kw["axis_names"] = set(axis_names)
        elif "auto" in _SHARD_MAP_PARAMS:
            kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


if hasattr(lax, "pcast"):

    def pcast_varying(x: jax.Array, axes: Sequence[str]) -> jax.Array:
        """Mark ``x`` as device-varying over ``axes`` (loop-carry seeding)."""
        return lax.pcast(x, tuple(axes), to="varying")

elif hasattr(lax, "pvary"):

    def pcast_varying(x: jax.Array, axes: Sequence[str]) -> jax.Array:
        return lax.pvary(x, tuple(axes))

else:  # old jax with check_rep=False: varying types are not tracked at all

    def pcast_varying(x: jax.Array, axes: Sequence[str]) -> jax.Array:
        return x


# ---------------------------------------------------------------------------
# tile-program compile cache
# ---------------------------------------------------------------------------
#
# jax.jit keys its C++ dispatch cache on the *callable's identity*, and both
# executors historically wrapped a fresh closure per invocation -- so a
# T-snapshot sequence run retraced (and recompiled) the same ~5 tile programs
# T times.  The cache below keys the jitted program on everything the closure
# actually depends on: the body function object, the mesh/axes context, the
# static panel geometry, the partition specs, the reduction and the output
# dtype.  Bodies that want cache hits must therefore be *module-level
# functions taking all data as operands* (a per-call lambda, or a closure over
# arrays, gets a fresh identity and safely misses).


class ProgramCacheStats:
    """Process-wide compile-cache accounting (see :func:`program_cache_stats`).

    ``traces`` counts Python executions of tile-program bodies -- a body runs
    in Python only while jax traces it, so a steady-state snapshot push that
    adds zero traces provably reused every compiled tile program.

    A live view over ``program_cache.*`` counters in a
    :class:`repro.obs.metrics.MetricsRegistry` (the process registry by
    default, so run reports read the same numbers).  Reads are properties,
    mutation goes through the atomic ``note_*`` methods, and
    :func:`reset_program_cache_stats` zeroes the counters *in place* -- held
    references stay live across resets.
    """

    __slots__ = ("_reg",)
    _PREFIX = "program_cache."

    def __init__(self, registry: MetricsRegistry | None = None):
        self._reg = registry if registry is not None else MetricsRegistry()

    @property
    def hits(self) -> int:  # cache hits: program reused, no retrace
        return int(self._reg.value("program_cache.hits"))

    @property
    def misses(self) -> int:  # cache misses: a new program was built (and traced)
        return int(self._reg.value("program_cache.misses"))

    @property
    def traces(self) -> int:  # Python trace executions of tile-program bodies
        return int(self._reg.value("program_cache.traces"))

    def note_hit(self) -> None:
        self._reg.inc("program_cache.hits")

    def note_miss(self) -> None:
        self._reg.inc("program_cache.misses")

    def note_trace(self) -> None:
        self._reg.inc("program_cache.traces")

    def __repr__(self) -> str:
        return (
            f"ProgramCacheStats(hits={self.hits}, misses={self.misses}, "
            f"traces={self.traces})"
        )


_PROGRAM_STATS = ProgramCacheStats(registry=_OBS_REGISTRY)
_PROGRAM_CACHE: OrderedDict = OrderedDict()
_PROGRAM_CACHE_MAX = 512  # per-call lambdas miss forever; bound their footprint


def program_cache_stats() -> ProgramCacheStats:
    """Counters since process start / last :func:`reset_program_cache_stats`."""
    return _PROGRAM_STATS


def reset_program_cache_stats() -> ProgramCacheStats:
    """Zero the counters in place (held references observe the reset)."""
    _PROGRAM_STATS._reg.reset(ProgramCacheStats._PREFIX)
    return _PROGRAM_STATS


def clear_program_cache() -> None:
    _PROGRAM_CACHE.clear()


def cached_program(key: tuple, build: Callable[[], Callable]) -> Callable:
    """The jitted program for ``key``, building (and tracing) it on first use.

    The caller owns the key contract: it must cover every value the built
    closure captures.  Keys holding per-call function objects pin them in the
    cache; eviction is LRU once the cache exceeds its bound, so a long run's
    churn of never-hit per-call lambdas (e.g. ``build_from_nodes`` closures,
    one per generated snapshot) can't evict the hot, constantly-hitting
    chain/scorer programs.
    """
    prog = _PROGRAM_CACHE.get(key)
    if prog is None:
        while len(_PROGRAM_CACHE) >= _PROGRAM_CACHE_MAX:
            _PROGRAM_CACHE.popitem(last=False)  # least recently used
        _PROGRAM_STATS.note_miss()
        prog = build()
        _PROGRAM_CACHE[key] = prog
    else:
        _PROGRAM_STATS.note_hit()
        _PROGRAM_CACHE.move_to_end(key)
    return prog


def _dtype_key(dt) -> str | None:
    return None if dt is None else np.dtype(dt).name


def sharded_zeros(shape: tuple[int, ...], dtype, sharding) -> jax.Array:
    """A zero buffer born with ``sharding`` (jitted with out_shardings).

    Eager ``jnp.zeros`` materializes the whole array on the default device
    before any reshard -- at out-of-core scale that single-device allocation
    OOMs exactly the buffers (streaming assembly targets, GEMM accumulators)
    whose residency the executors are bounding.  The jitted program allocates
    each shard on its own device; programs are cached per (shape, dtype,
    sharding).
    """
    return cached_program(
        ("zeros", tuple(shape), _dtype_key(dtype), sharding),
        lambda: jax.jit(lambda: jnp.zeros(shape, dtype), out_shardings=sharding),
    )()


# ---------------------------------------------------------------------------
# the tile-program primitive
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Tile:
    """One device's window of the global block grid, visible to tile bodies."""

    rows: jax.Array  # (pr,) global row ids of this tile
    cols: jax.Array  # (pc,) global col ids
    row_index: jax.Array  # scalar shard index along the row axes
    col_index: jax.Array  # scalar shard index along the col axes
    block_shape: tuple[int, int]  # static (pr, pc)
    mesh_axes: tuple[str, ...]  # all manual axes, for loop-carry casts

    def varying(self, x: jax.Array) -> jax.Array:
        """Seed a loop carry with the tile-varying type (no-op on old jax)."""
        return pcast_varying(x, self.mesh_axes)

    def diag_mask(self) -> jax.Array:
        """(pr, pc) bool mask of global-diagonal entries in this tile."""
        return self.rows[:, None] == self.cols[None, :]


def _axes_index(ctx, axes: Sequence[str]) -> jax.Array:
    """Flattened shard index over possibly-multiple mesh axes.

    Folded manually (row-major over ``axes``) instead of
    ``lax.axis_index(tuple)`` so it works on every jax version.
    """
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * ctx.mesh.shape[a] + lax.axis_index(a)
    return idx


def _tile_local(
    ctx,
    fn: Callable[..., jax.Array],
    pr: int,
    pc: int,
    reduce_axes,
    out_dtype,
    *,
    with_origin: bool = False,
):
    """Shared per-device body for :func:`tile_map` and :func:`tile_stream`.

    With ``with_origin=True`` the wrapped function takes a leading (traced)
    global row offset, so one compiled program serves every streamed panel.
    """
    mesh_axes = tuple(ctx.row_axes) + tuple(ctx.col_axes)

    def local(*args):
        _PROGRAM_STATS.note_trace()  # body runs in Python only while tracing
        if with_origin:
            origin, *blocks = args
        else:
            origin, blocks = jnp.int32(0), args
        r = _axes_index(ctx, ctx.row_axes)
        c = _axes_index(ctx, ctx.col_axes)
        tile = Tile(
            rows=origin + r * pr + jnp.arange(pr),
            cols=c * pc + jnp.arange(pc),
            row_index=origin // pr + r,
            col_index=c,
            block_shape=(pr, pc),
            mesh_axes=mesh_axes,
        )
        out = fn(tile, *blocks)
        if reduce_axes is not None:
            out = lax.psum(out, reduce_axes)
        if out_dtype is not None:
            out = out.astype(out_dtype)
        return out

    return local


def tile_map(
    ctx,
    fn: Callable[..., jax.Array],
    *operands: jax.Array,
    grid: tuple[int, int] | None = None,
    in_specs: Sequence[P] | None = None,
    out_spec: P | None = None,
    reduce: str | None = None,
    out_dtype=None,
) -> jax.Array:
    """Run ``fn(tile, *local_blocks)`` over the ctx mesh, one tile per device.

    Args:
      ctx: ``DistContext`` (mesh + row/col axis names).
      fn: tile body; receives a :class:`Tile` plus each operand's local block
        (operands with a replicated spec arrive whole).  Returns the local
        output block.  The body is ordinary traced JAX and may call Pallas
        kernels on its block.
      operands: global arrays.
      grid: global (n_rows, n_cols) of the logical block grid.  Defaults to
        the shape of the first matrix-sharded operand.
      in_specs: one PartitionSpec per operand.  Defaults to
        ``ctx.matrix_spec`` for every operand (pass ``P(None, None)`` / ``P()``
        explicitly for replicated tables and scalars).
      out_spec: sharding of the stitched output.  Defaults to
        ``ctx.matrix_spec``; with ``reduce="cols"`` defaults to
        ``ctx.vector_spec`` (pass ``P(row_axes, None)`` for (pr, k) tiles).
      reduce: ``None`` or ``"cols"``/``"rows"`` -- psum the tile output over
        that mesh axis before stitching (the blockwise Map+ReduceByKey).
      out_dtype: optional cast of the tile output.
    """
    if in_specs is None:
        in_specs = tuple(ctx.matrix_spec for _ in operands)
    in_specs = tuple(in_specs)
    if len(in_specs) != len(operands):
        raise ValueError(f"{len(operands)} operands but {len(in_specs)} in_specs")

    if grid is None:
        for op, spec in zip(operands, in_specs):
            if spec == ctx.matrix_spec:
                grid = (op.shape[0], op.shape[1])
                break
        if grid is None:
            raise ValueError("grid= is required when no operand is matrix-sharded")
    n0, n1 = grid
    R, C = ctx.n_row_shards, ctx.n_col_shards
    if n0 % R or n1 % C:
        raise ValueError(f"grid {grid} must divide the {R}x{C} shard grid")
    pr, pc = n0 // R, n1 // C

    if reduce not in (None, "cols", "rows"):
        raise ValueError(f"reduce must be None, 'cols' or 'rows', got {reduce!r}")
    reduce_axes = {"cols": ctx.col_axes, "rows": ctx.row_axes, None: None}[reduce]

    if out_spec is None:
        if reduce == "cols":
            out_spec = ctx.vector_spec
        elif reduce == "rows":
            out_spec = P(ctx.col_axes)
        else:
            out_spec = ctx.matrix_spec

    # jit for numeric parity with tile_stream: both executors compile their
    # tile program through the same pipeline, so a streamed run is bitwise
    # identical to the resident run (XLA fuses jit and eager-dispatch
    # programs slightly differently).  The program is cached on everything the
    # closure depends on, so repeated calls with the same body reuse one
    # compiled program instead of retracing per call.
    key = ("tile_map", fn, ctx, pr, pc, in_specs, out_spec, reduce, _dtype_key(out_dtype))
    mapped = cached_program(
        key,
        lambda: jax.jit(
            shard_map(
                _tile_local(ctx, fn, pr, pc, reduce_axes, out_dtype),
                mesh=ctx.mesh,
                in_specs=in_specs,
                out_specs=out_spec,
            )
        ),
    )
    return mapped(*operands)


# ---------------------------------------------------------------------------
# the streaming tile executor (out-of-core operands)
# ---------------------------------------------------------------------------


def is_streamable(x) -> bool:
    """True for store-backed snapshot handles (duck-typed, no store import).

    The protocol: ``shape`` (n0, n1), ``dtype``, ``panel_rows`` (preferred
    streaming height) and ``read_panel(row0, height) -> host array``.
    :class:`repro.store.SnapshotHandle` satisfies it; so can any user object.
    """
    return (
        not isinstance(x, (jax.Array, np.ndarray))
        and hasattr(x, "read_panel")
        and hasattr(x, "panel_rows")
        and hasattr(x, "shape")
    )


class StreamStats:
    """Process-wide accounting of the streaming executors (see stream_stats()).

    ``bytes_read`` counts what the backing tier (disk / store RAM) actually
    served, *before* codec decode -- the number that tracks real disk traffic
    across PRs.  ``bytes_decoded`` is the post-codec host bytes the prefetch
    thread produced from them; with ``codec='raw'`` the two move together
    (modulo .npy headers), with ``bf16``/``zstd`` the gap is the bandwidth
    the codec saved.  Host-RAM replays (solver iteration batching) add
    ``panels``/``bytes_h2d`` but zero ``bytes_read`` and zero
    ``bytes_decoded`` -- nothing was served or decoded for them.

    ``bytes_h2d_saved`` is the stored-width vs decoded-width transfer gap of
    the kernel path: panels shipped in their *stored* form (bf16 bit patterns
    decoded on-device by the stream-GEMM kernel) add the difference between
    what a host-decoded fp32 transfer would have cost and what actually
    crossed H2D.  Zero on the host-decode path -- the counter is exactly the
    bandwidth the on-device decode won.

    A live view over ``stream.*`` counters in a
    :class:`repro.obs.metrics.MetricsRegistry`.  The process-wide instance
    behind :func:`stream_stats` is backed by the process registry (so run
    reports read the very same counters); a bare ``StreamStats()`` gets its
    own private registry for isolated accounting (tests pass one straight to
    a :class:`~repro.store.PanelPipeline`).  All mutation goes through the
    atomic :meth:`add`, and :func:`reset_stream_stats` zeroes the counters
    *in place* -- a prefetch thread mid-``add`` can no longer race a reset
    into lost updates, and references held across a reset stay live.
    """

    __slots__ = ("_reg",)
    _PREFIX = "stream."
    FIELDS = (
        "panels",  # row panels fetched host -> device
        "bytes_h2d",  # bytes device_put by the executor
        "bytes_h2d_saved",  # decoded-width minus stored-width H2D (kernel path)
        "bytes_read",  # pre-decode bytes served by the backing store
        "bytes_decoded",  # post-decode host bytes produced by prefetch
        "calls",  # tile_stream invocations
    )

    def __init__(self, registry: MetricsRegistry | None = None):
        self._reg = registry if registry is not None else MetricsRegistry()

    def add(self, **fields: int) -> None:
        """Atomically increment counters: ``st.add(panels=1, bytes_h2d=nb)``."""
        for name in fields:
            if name not in StreamStats.FIELDS:
                raise AttributeError(f"unknown stream counter {name!r}")
        self._reg.add_named(
            {f"stream.{name}": v for name, v in fields.items()}
        )

    def _note_live(self, live: int) -> None:
        self._reg.max_gauge("stream.peak_live_bytes", live)

    @property
    def panels(self) -> int:
        return int(self._reg.value("stream.panels"))

    @property
    def bytes_h2d(self) -> int:
        return int(self._reg.value("stream.bytes_h2d"))

    @property
    def bytes_h2d_saved(self) -> int:
        return int(self._reg.value("stream.bytes_h2d_saved"))

    @property
    def bytes_read(self) -> int:
        return int(self._reg.value("stream.bytes_read"))

    @property
    def bytes_decoded(self) -> int:
        return int(self._reg.value("stream.bytes_decoded"))

    @property
    def calls(self) -> int:
        return int(self._reg.value("stream.calls"))

    @property
    def peak_live_bytes(self) -> int:
        return int(self._reg.gauge("stream.peak_live_bytes"))

    def snapshot(self) -> dict[str, int]:
        """One atomic dict of every counter (plus the peak gauge)."""
        snap = self._reg.snapshot()
        out = {f: int(snap.counter(f"stream.{f}")) for f in StreamStats.FIELDS}
        out["peak_live_bytes"] = int(snap.gauges.get("stream.peak_live_bytes", 0))
        return out

    def __repr__(self) -> str:
        fields = ", ".join(f"{k}={v}" for k, v in self.snapshot().items())
        return f"StreamStats({fields})"


_STREAM_STATS = StreamStats(registry=_OBS_REGISTRY)


def stream_stats() -> StreamStats:
    """Counters since process start / last :func:`reset_stream_stats`."""
    return _STREAM_STATS


def reset_stream_stats() -> StreamStats:
    """Zero the counters in place, atomically.

    The returned object is the same live instance every caller (and every
    in-flight :class:`~repro.store.PanelPipeline`) already holds -- the reset
    cannot strand a pipeline on a stale counter object, and a concurrent
    ``add`` from the prefetch thread lands entirely before or entirely after
    the reset, never interleaved with it.
    """
    _STREAM_STATS._reg.reset(StreamStats._PREFIX)
    return _STREAM_STATS


class _PanelSource:
    """Operand classification for the streaming executor: ``streamed``
    operands are prefetched by the :class:`repro.store.PanelPipeline`
    background thread, resident ones are sliced on device at consume time."""

    def __init__(self, x, streamed: bool):
        self.x = x
        self.streamed = streamed


def _infer_panel_rows(handles, n0: int, n_row_shards: int) -> int:
    """Smallest height that is tile-aligned for every handle and shardable."""
    quanta = [int(h.panel_rows) for h in handles] + [n_row_shards]
    rows = int(np.lcm.reduce(np.asarray(quanta, np.int64)))
    if n0 % rows:
        raise ValueError(
            f"no common panel height: operand tile rows {quanta} don't tile n0={n0}"
        )
    return rows


def tile_stream(
    ctx,
    fn: Callable[..., jax.Array],
    *operands,
    grid: tuple[int, int] | None = None,
    in_specs: Sequence[P] | None = None,
    out_spec: P | None = None,
    reduce: str | None = None,
    out_dtype=None,
    panel_rows: int | None = None,
    prefetch_depth: int | None = None,
) -> jax.Array:
    """Run a :func:`tile_map` body over *streamed* row panels of the operands.

    The out-of-core execution path: operands that satisfy the snapshot-handle
    protocol (:func:`is_streamable`) are fetched from host/disk one full-width
    row panel at a time and fed to ``fn`` under the same :class:`Tile`
    contract as ``tile_map`` -- existing tile bodies (degrees, edge
    projection, blockwise builds, the Pallas CAD scorer) run unchanged, with
    ``tile.rows`` carrying the true global ids of the current panel.

    Prefetch is owned by :class:`repro.store.PanelPipeline`: a background
    thread fetches (and codec-decodes) up to ``prefetch_depth`` panels per
    streamed operand ahead of the consumer (default 2), and the
    ``jax.device_put`` of panel t+1 is issued before the compute on panel t
    is dispatched, so host reads, decode and the host->device copy all
    overlap the tile program.  Device residency for each streamed operand
    stays at most two panels regardless of the host-side depth.

    Bitwise contract: every supported body is row-parallel (output rows
    [r0:r1] depend only on operand rows [r0:r1]), and a panel run splits the
    mesh reduction extents exactly as the resident run does, so results are
    bitwise identical to ``tile_map`` on the same mesh.

    Args mirror :func:`tile_map`; additionally ``panel_rows`` overrides the
    streaming unit (default: the finest tile-aligned height that divides the
    row-shard grid) and ``prefetch_depth`` the host-side staging depth.
    ``reduce`` may be ``None`` (the (n0, n1) output is assembled
    panel-by-panel into a sharded buffer, donated between updates) or
    ``"cols"`` (per-panel row reductions are concatenated).
    """
    if reduce not in (None, "cols"):
        raise ValueError(f"tile_stream supports reduce=None or 'cols', got {reduce!r}")
    if in_specs is None:
        in_specs = tuple(ctx.matrix_spec for _ in operands)
    in_specs = tuple(in_specs)
    if len(in_specs) != len(operands):
        raise ValueError(f"{len(operands)} operands but {len(in_specs)} in_specs")

    handles = [op for op in operands if is_streamable(op)]
    if grid is None:
        if not handles:
            raise ValueError("grid= is required when no operand is streamable")
        grid = tuple(handles[0].shape)
    n0, n1 = grid
    for h in handles:
        if tuple(h.shape) != (n0, n1):
            raise ValueError(f"streamed operand is {h.shape}, grid is {grid}")

    R, C = ctx.n_row_shards, ctx.n_col_shards
    if panel_rows is None:
        panel_rows = _infer_panel_rows(handles, n0, R) if handles else n0
    if n0 % panel_rows or panel_rows % R or n1 % C:
        raise ValueError(
            f"panel_rows={panel_rows} must divide n0={n0} and the {R}x{C} shard grid"
        )
    pr, pc = panel_rows // R, n1 // C

    # Streamed operands: anything satisfying the handle protocol, plus
    # resident matrix-sharded arrays of the full grid shape (mixed
    # resident/store transitions slice their panels on device).
    sources: list[_PanelSource | None] = []
    for op, spec in zip(operands, in_specs):
        if is_streamable(op):
            sources.append(_PanelSource(op, streamed=True))
        elif spec == ctx.matrix_spec and getattr(op, "shape", None) == (n0, n1):
            sources.append(_PanelSource(op, streamed=False))
        else:
            sources.append(None)  # per-call constant (replicated table, scalar)

    reduce_axes = ctx.col_axes if reduce == "cols" else None

    panel_in_specs = []
    for spec, src in zip(in_specs, sources):
        panel_in_specs.append(ctx.matrix_spec if src is not None else spec)
    panel_in_specs = tuple(panel_in_specs)
    if out_spec is None:
        out_spec = ctx.vector_spec if reduce == "cols" else ctx.matrix_spec
    panel_out_spec = out_spec

    # jit so panels after the first hit the compile cache (eager shard_map
    # retraces per call; one compiled program serves the whole panel walk
    # because the row origin is a traced operand, not a constant), and cache
    # the program itself so later tile_stream calls with the same body don't
    # retrace either.
    key = (
        "tile_stream", fn, ctx, pr, pc, panel_in_specs, panel_out_spec, reduce,
        _dtype_key(out_dtype),
    )
    mapped = cached_program(
        key,
        lambda: jax.jit(
            shard_map(
                _tile_local(ctx, fn, pr, pc, reduce_axes, out_dtype, with_origin=True),
                mesh=ctx.mesh,
                in_specs=(P(), *panel_in_specs),
                out_specs=panel_out_spec,
            )
        ),
    )

    stats = _STREAM_STATS
    stats.add(calls=1)
    consts = [op for op, src in zip(operands, sources) if src is None]
    panel_sharding = ctx.sharding(ctx.matrix_spec)

    def run_panel(row0: int, panels):
        args = []
        it = iter(panels)
        jt = iter(consts)
        for src in sources:
            args.append(next(it) if src is not None else next(jt))
        return mapped(jnp.int32(row0), *args)

    # reduce="cols" panel outputs are small row reductions -- collect and
    # concatenate.  reduce=None assembles the (n0, n1) output *incrementally*
    # (buffer donated between updates), so at most one output buffer plus the
    # in-flight panels are ever live -- never all panels at once.
    out_sharding = ctx.sharding(out_spec)
    donate = (0,) if jax.default_backend() != "cpu" else ()
    update = cached_program(
        ("stream_update", out_sharding, donate),
        lambda: jax.jit(
            lambda buf, blk, r0: lax.dynamic_update_slice(buf, blk, (r0, jnp.int32(0))),
            donate_argnums=donate,
            out_shardings=out_sharding,
        ),
    )
    reduced_outs: list[jax.Array] = []
    buf = None

    def consume(row0: int, panels):
        nonlocal buf
        out = run_panel(row0, panels)
        if reduce == "cols":
            reduced_outs.append(out)
        else:
            if buf is None:
                buf = sharded_zeros((n0, n1), out.dtype, out_sharding)
            buf = update(buf, out, jnp.int32(row0))

    # All host staging -- background fetch + codec decode + device_put one
    # origin ahead -- is owned by the panel pipeline; the executor only runs
    # the compiled panel program and stitches outputs.
    from repro.store.pipeline import PanelPipeline  # deferred: store is optional

    origins = list(range(0, n0, panel_rows))
    with obs_trace.span(
        "tile_stream",
        body=getattr(fn, "__name__", repr(fn)),
        n0=n0,
        n1=n1,
        panels=len(origins),
    ):
        with PanelPipeline(
            [src.x for src in sources if src is not None],
            origins,
            panel_rows,
            depth=prefetch_depth,
            sharding=panel_sharding,
            stats=stats,
        ) as pipe:
            for r0, panels in pipe:
                consume(r0, panels)

    if reduce == "cols":
        if len(reduced_outs) == 1:
            return ctx.constrain(reduced_outs[0], out_spec)
        # Host-side concat of the small per-panel reductions: jax 0.4.x eager
        # concatenate on partially-replicated shardings sums the replicas
        # (observed on 0.4.37); copying through the host is bitwise-safe.
        out = np.concatenate([np.asarray(o) for o in reduced_outs], axis=0)
        return jax.device_put(out, ctx.sharding(out_spec))
    return buf
