"""Unified tile-program layer: every blockwise computation in the core.

The paper's Spark job graph is a pile of near-identical map stages: "for the
(r, c) block of the n x n matrix, recover the global row/column ids, compute
something tile-local, optionally reduce across the block row".  The JAX port
accumulated five hand-rolled copies of that shard_map pattern; this module
owns it once.

``tile_map(ctx, fn, *operands)`` runs ``fn(tile, *local_blocks)`` on every
device with a :class:`Tile` describing the device's (rows, cols) window of the
global grid, and stitches the local outputs back into one sharded array.
An optional ``reduce="cols"`` psums the per-tile result across the column
axis (the Map+ReduceByKey of the paper).  Tile bodies are ordinary traced JAX,
so they can drop into a Pallas kernel for the inner loop (see
``node_anomaly_scores``) -- the tile program handles distribution, the kernel
handles the single-chip schedule.

This module also owns the version-compat shims for the manual-sharding API
(``jax.shard_map`` vs ``jax.experimental.shard_map``; ``lax.pcast`` /
``lax.pvary`` vs nothing) so the rest of the core is version-agnostic.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# version compat: manual-sharding API surface
# ---------------------------------------------------------------------------

try:  # jax >= 0.5: top-level export with varying-type checking built in
    _shard_map = jax.shard_map
    _COMPAT_KWARGS: dict[str, Any] = {}
except AttributeError:  # jax 0.4.x: experimental module; disable rep checking
    from jax.experimental.shard_map import shard_map as _shard_map

    _COMPAT_KWARGS = {"check_rep": False}

import inspect as _inspect

_SHARD_MAP_PARAMS = frozenset(_inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check=None):
    """``jax.shard_map`` across jax versions (old versions skip rep checks).

    ``axis_names`` restricts manual sharding to those mesh axes (mapped to the
    old API's complementary ``auto=`` set); ``check=False`` disables varying-
    type checking where the installed jax supports toggling it.
    """
    kw = dict(_COMPAT_KWARGS)
    if check is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kw["check_vma"] = check
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kw["check_rep"] = check
    if axis_names is not None:
        if "axis_names" in _SHARD_MAP_PARAMS:
            kw["axis_names"] = set(axis_names)
        elif "auto" in _SHARD_MAP_PARAMS:
            kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


if hasattr(lax, "pcast"):

    def pcast_varying(x: jax.Array, axes: Sequence[str]) -> jax.Array:
        """Mark ``x`` as device-varying over ``axes`` (loop-carry seeding)."""
        return lax.pcast(x, tuple(axes), to="varying")

elif hasattr(lax, "pvary"):

    def pcast_varying(x: jax.Array, axes: Sequence[str]) -> jax.Array:
        return lax.pvary(x, tuple(axes))

else:  # old jax with check_rep=False: varying types are not tracked at all

    def pcast_varying(x: jax.Array, axes: Sequence[str]) -> jax.Array:
        return x


# ---------------------------------------------------------------------------
# the tile-program primitive
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Tile:
    """One device's window of the global block grid, visible to tile bodies."""

    rows: jax.Array  # (pr,) global row ids of this tile
    cols: jax.Array  # (pc,) global col ids
    row_index: jax.Array  # scalar shard index along the row axes
    col_index: jax.Array  # scalar shard index along the col axes
    block_shape: tuple[int, int]  # static (pr, pc)
    mesh_axes: tuple[str, ...]  # all manual axes, for loop-carry casts

    def varying(self, x: jax.Array) -> jax.Array:
        """Seed a loop carry with the tile-varying type (no-op on old jax)."""
        return pcast_varying(x, self.mesh_axes)

    def diag_mask(self) -> jax.Array:
        """(pr, pc) bool mask of global-diagonal entries in this tile."""
        return self.rows[:, None] == self.cols[None, :]


def _axes_index(ctx, axes: Sequence[str]) -> jax.Array:
    """Flattened shard index over possibly-multiple mesh axes.

    Folded manually (row-major over ``axes``) instead of
    ``lax.axis_index(tuple)`` so it works on every jax version.
    """
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * ctx.mesh.shape[a] + lax.axis_index(a)
    return idx


def tile_map(
    ctx,
    fn: Callable[..., jax.Array],
    *operands: jax.Array,
    grid: tuple[int, int] | None = None,
    in_specs: Sequence[P] | None = None,
    out_spec: P | None = None,
    reduce: str | None = None,
    out_dtype=None,
) -> jax.Array:
    """Run ``fn(tile, *local_blocks)`` over the ctx mesh, one tile per device.

    Args:
      ctx: ``DistContext`` (mesh + row/col axis names).
      fn: tile body; receives a :class:`Tile` plus each operand's local block
        (operands with a replicated spec arrive whole).  Returns the local
        output block.  The body is ordinary traced JAX and may call Pallas
        kernels on its block.
      operands: global arrays.
      grid: global (n_rows, n_cols) of the logical block grid.  Defaults to
        the shape of the first matrix-sharded operand.
      in_specs: one PartitionSpec per operand.  Defaults to
        ``ctx.matrix_spec`` for every operand (pass ``P(None, None)`` / ``P()``
        explicitly for replicated tables and scalars).
      out_spec: sharding of the stitched output.  Defaults to
        ``ctx.matrix_spec``; with ``reduce="cols"`` defaults to
        ``ctx.vector_spec`` (pass ``P(row_axes, None)`` for (pr, k) tiles).
      reduce: ``None`` or ``"cols"``/``"rows"`` -- psum the tile output over
        that mesh axis before stitching (the blockwise Map+ReduceByKey).
      out_dtype: optional cast of the tile output.
    """
    if in_specs is None:
        in_specs = tuple(ctx.matrix_spec for _ in operands)
    in_specs = tuple(in_specs)
    if len(in_specs) != len(operands):
        raise ValueError(f"{len(operands)} operands but {len(in_specs)} in_specs")

    if grid is None:
        for op, spec in zip(operands, in_specs):
            if spec == ctx.matrix_spec:
                grid = (op.shape[0], op.shape[1])
                break
        if grid is None:
            raise ValueError("grid= is required when no operand is matrix-sharded")
    n0, n1 = grid
    R, C = ctx.n_row_shards, ctx.n_col_shards
    if n0 % R or n1 % C:
        raise ValueError(f"grid {grid} must divide the {R}x{C} shard grid")
    pr, pc = n0 // R, n1 // C

    if reduce not in (None, "cols", "rows"):
        raise ValueError(f"reduce must be None, 'cols' or 'rows', got {reduce!r}")
    reduce_axes = {"cols": ctx.col_axes, "rows": ctx.row_axes, None: None}[reduce]

    mesh_axes = tuple(ctx.row_axes) + tuple(ctx.col_axes)

    def local(*blocks):
        r = _axes_index(ctx, ctx.row_axes)
        c = _axes_index(ctx, ctx.col_axes)
        tile = Tile(
            rows=r * pr + jnp.arange(pr),
            cols=c * pc + jnp.arange(pc),
            row_index=r,
            col_index=c,
            block_shape=(pr, pc),
            mesh_axes=mesh_axes,
        )
        out = fn(tile, *blocks)
        if reduce_axes is not None:
            out = lax.psum(out, reduce_axes)
        if out_dtype is not None:
            out = out.astype(out_dtype)
        return out

    if out_spec is None:
        if reduce == "cols":
            out_spec = ctx.vector_spec
        elif reduce == "rows":
            out_spec = P(ctx.col_axes)
        else:
            out_spec = ctx.matrix_spec

    mapped = shard_map(
        local, mesh=ctx.mesh, in_specs=in_specs, out_specs=out_spec
    )
    return mapped(*operands)
