"""Sequence engine: amortized CADDeLaG over a stream of T graph snapshots.

The paper's headline object is a *sequence* of dense snapshots (climate
months, election cycles).  Scoring every transition with
:func:`repro.core.cad.detect_anomalies` rebuilds the O(n^3)-GEMM chain
operator for both endpoints -- 2(T-1) builds where T suffice.
:class:`SequenceDetector` computes each snapshot's ``ChainOperator`` /
``Embedding`` exactly once and carries it forward: snapshot t's embedding is
reused as the left endpoint of transition (t, t+1).

Memory follows the paper's "never load the whole sequence" design: only two
snapshots (adjacency + embedding) are resident at any time.  With
``donate=True`` the detector eagerly deletes the outgoing snapshot's device
buffers after its last use (double buffering) -- callers must not touch a
donated snapshot again.

Out-of-core mode: ``push`` (and ``run``) also accept store-backed snapshot
handles (:class:`repro.store.SnapshotHandle`, e.g. from
``TileStore.iter_snapshots()``).  Handles are scored by the streaming tile
executor -- adjacencies stay on host/disk and devices only ever hold two row
*panels* per operand, so residency is bounded by tiles, not snapshots, and n
is bounded by host/disk capacity rather than HBM.

A streaming global top-k across all transitions is maintained by merging each
transition's top-k into the running global top-k over 2k candidates.  The
merge runs on the host: the candidates are partially-replicated k-vectors,
and eager concatenation on those sums replicas on jax 0.4.x (see ROADMAP).
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chain
from repro.core.cad import CADResult, node_anomaly_scores, top_anomalies
from repro.core.delta_chain import BaseChain, build_base_chain, try_delta_update
from repro.core.distmatrix import DistContext
from repro.core.embedding import CommuteConfig, Embedding, commute_time_embedding
from repro.obs import phase
from repro.obs import trace as obs_trace
from repro.obs.metrics import REGISTRY as _OBS_REGISTRY


@dataclass
class SequenceResult:
    """Per-transition results plus the sequence-wide top-k."""

    transitions: list[CADResult]  # transitions[t] scores snapshot t -> t+1
    global_top_idx: jax.Array  # (k,) node ids
    global_top_val: jax.Array  # (k,) scores
    global_top_step: jax.Array  # (k,) transition index of each entry
    n_snapshots: int
    chain_builds: int  # chain_product invocations during run()
    transition_seconds: list[float] = field(default_factory=list)
    # Registry counter deltas (see repro.obs.metrics) per scored transition,
    # aligned with ``transitions``; ``warmup_metrics`` is the delta of the
    # first push (embedding build only -- nothing scored yet).
    transition_metrics: list[dict] = field(default_factory=list)
    warmup_metrics: dict | None = None


class SequenceDetector:
    """Streaming CADDeLaG over T snapshots with one chain build per snapshot.

    Usage::

        det = SequenceDetector(ctx, cfg, top_k=20)
        for a_t in snapshots:          # iterator; never holds the sequence
            res = det.push(a_t)        # CADResult for (t-1, t), None at t=0
        final = det.finalize()

    or simply ``det.run(snapshots)``.
    """

    def __init__(
        self,
        ctx: DistContext,
        cfg: CommuteConfig | None = None,
        *,
        top_k: int = 10,
        use_kernel: bool = False,
        donate: bool = False,
        emb_store=None,
    ):
        self.ctx = ctx
        self.cfg = cfg or CommuteConfig()
        self.top_k = top_k
        self.use_kernel = use_kernel
        self.donate = donate
        # Write/read split: with an EmbeddingStore attached, every push
        # publishes the committed (z, vol, deg, zbar) artifact so query-path
        # readers (repro.core.query) never touch live solver state.  Duck-
        # typed (put_embedding), so the core keeps zero store imports.
        self.emb_store = emb_store
        self._prev: tuple[jax.Array, Embedding] | None = None
        self._base: BaseChain | None = None  # incremental-chain base (cfg.incremental_chain)
        self._t = 0  # snapshots consumed
        self._transitions: list[CADResult] = []
        self._seconds: list[float] = []
        self._metrics: list[dict] = []
        self._warmup_metrics: dict | None = None
        self._builds0 = chain.chain_build_count()
        self._g_val: np.ndarray | None = None
        self._g_idx: np.ndarray | None = None
        self._g_step: np.ndarray | None = None

    # -- streaming global top-k ---------------------------------------------

    def _merge_topk(self, idx, val, step: int) -> None:
        """Merge one transition's top-k into the running global top-k, on host.

        Host-side on purpose (the jax 0.4.x partial-replication bug, see
        ROADMAP / tile_stream): the per-transition candidates are (k,)
        vectors sharded ``P(row_axes)`` -- *partially replicated* over the
        column mesh axes -- and eager ``jnp.concatenate`` on such inputs SUMS
        the replicas on jax 0.4.37 (observed: every candidate doubled on a
        2x2 mesh).  The candidates are k elements, so the host round-trip is
        free; ties break toward the lower candidate index, exactly like
        ``lax.top_k``.
        """
        idx = np.asarray(idx)
        val = np.asarray(val)
        step_arr = np.full_like(idx, step)
        if self._g_val is None:
            cand_val, cand_idx, cand_step = val, idx, step_arr
        else:
            cand_val = np.concatenate([self._g_val, val])
            cand_idx = np.concatenate([self._g_idx, idx])
            cand_step = np.concatenate([self._g_step, step_arr])
        pos = np.argsort(-cand_val, kind="stable")[: self.top_k]
        self._g_val = cand_val[pos]
        self._g_idx = cand_idx[pos]
        self._g_step = cand_step[pos]

    # -- snapshot lifecycle --------------------------------------------------

    def _release(self, a: jax.Array, emb: Embedding) -> None:
        """Retire an outgoing snapshot as it leaves the two-snapshot window.

        An out-of-core chain operator's P1 / P2 handles live in a scratch
        store owned by the build; those snapshots are ALWAYS removed here
        (resident operators are freed by refcount either way -- without this,
        a disk-backed scratch would grow by 2 n^2 bytes per snapshot for the
        whole sequence).  The input snapshot ``a`` may also be a store-backed
        handle -- that is the *user's* data and is never removed from its
        store.  ``donate=True`` additionally deletes the outgoing *device*
        buffers eagerly (double buffering); callers must not touch a donated
        snapshot again.
        """
        if emb.op is not None:
            emb.op.release_scratch()  # no-op when the op shares the base chain
        if not self.donate:
            return
        shared = emb.op is not None and getattr(emb.op, "shared_base", False)
        for buf in (
            a, emb.z,
            # A shared-base op's P1/P2 *are* the retained base chain's arrays
            # (possibly still serving later incremental transitions): never
            # donate-delete them here -- BaseChain.release() owns that.
            *(() if emb.op is None or shared else (emb.op.p1, emb.op.p2)),
        ):
            delete = getattr(buf, "delete", None)
            if delete is None:
                continue  # store-backed handle: the user's data, not ours
            try:
                delete()
            except (RuntimeError, ValueError, OSError) as exc:
                # Already-deleted / donated buffers raise here; that is the
                # expected double-buffering race and safe to continue past --
                # but say so, instead of silently eating every exception (a
                # genuinely failing delete used to vanish without a trace).
                warnings.warn(
                    f"snapshot buffer delete failed during release: {exc!r}",
                    RuntimeWarning,
                    stacklevel=2,
                )

    def _incremental_op(self, a):
        """The chain operator for snapshot ``a`` under incremental mode.

        Tries a low-rank delta update against the retained base chain
        (:func:`repro.core.delta_chain.try_delta_update`); when the drift
        monitor rejects the transition -- or there is no base yet -- the
        accumulated correction collapses into a fresh full build that becomes
        the new base.  Timing lands under the same ``phase("chain")`` counter
        the full-build path uses, so per-transition chain seconds stay
        comparable across modes.
        """
        with phase(
            "chain", n=int(a.shape[0]), d=self.cfg.d, oocore=self.cfg.oocore,
            incremental=True,
        ) as sp:
            if self._base is not None:
                op = try_delta_update(self.ctx, self._base, a, self.cfg)
                if op is not None:
                    sp.annotate(mode="delta")
                    return op
                # drift over budget: retire the base before rebuilding
                self._base.release()
                self._base = None
            self._base = build_base_chain(
                self.ctx, a, self.cfg, use_kernel=self.use_kernel
            )
            sp.annotate(mode="rebuild")
            op = self._base.op
            sp.fence(op.vol)
        return op

    def _publish(self, emb: Embedding) -> None:
        """Publish snapshot t's committed embedding to the attached store.

        The artifact is a host-side *copy* of (z, vol, deg) -- readers never
        alias live device buffers, so ``donate=True`` double-buffering and
        in-flight solves can't tear a query.  Atomic panel writes +
        commit-on-complete (see :class:`repro.store.embstore.EmbeddingStore`)
        mean a crash mid-publish leaves the previous artifact current.
        """
        with phase("publish", t=self._t, n=int(emb.z.shape[0])):
            self.emb_store.put_embedding(
                f"t{self._t:04d}",
                np.asarray(emb.z),
                float(np.asarray(emb.vol)),
                np.asarray(emb.op.deg),
            )

    def push(self, a) -> CADResult | None:
        """Consume snapshot t; returns the CADResult for transition (t-1, t).

        ``a`` is a resident sharded adjacency or a store-backed snapshot
        handle (streamed off-core; scores bitwise-identical to the resident
        run with the default chain build, allclose under ``fuse_l=True``).
        Builds exactly one chain operator (for ``a``); the left endpoint's
        operator was built when *it* was pushed.  With
        ``cfg.warm_start=True``, the previous snapshot's solution seeds the
        solver (transition 1 onward) -- a tolerance-targeted solve on a
        slowly-drifting sequence then converges in far fewer iterations.
        """
        t0 = time.perf_counter()
        m0 = _OBS_REGISTRY.snapshot()
        with obs_trace.span("sequence.push", t=self._t) as push_sp:
            warm_from = (
                self._prev[1].z
                if (self.cfg.warm_start and self._prev is not None)
                else None
            )
            op_in = self._incremental_op(a) if self.cfg.incremental_chain else None
            emb = commute_time_embedding(
                self.ctx, a, self.cfg, op=op_in, use_kernel=self.use_kernel,
                warm_from=warm_from,
            )
            if self.emb_store is not None:
                self._publish(emb)
            out = None
            if self._prev is not None:
                a_prev, e_prev = self._prev
                scores = node_anomaly_scores(
                    self.ctx,
                    a_prev,
                    a,
                    e_prev,
                    emb,
                    use_kernel=self.use_kernel,
                    prefetch_depth=self.cfg.prefetch_depth,
                )
                idx, vals = top_anomalies(scores, self.top_k)
                out = CADResult(
                    scores=scores, top_idx=idx, top_val=vals,
                    solve_reports=(e_prev.report, emb.report),
                )
                jax.block_until_ready(out.scores)
                self._merge_topk(idx, vals, self._t - 1)
                self._transitions.append(out)
                self._seconds.append(time.perf_counter() - t0)
                self._metrics.append(_OBS_REGISTRY.delta(m0))
                self._release(a_prev, e_prev)
            else:
                self._warmup_metrics = _OBS_REGISTRY.delta(m0)
            push_sp.annotate(scored=out is not None)
        self._prev = (a, emb)
        self._t += 1
        return out

    def finalize(self) -> SequenceResult:
        """Package per-transition results and the sequence-wide top-k.

        A single-snapshot sequence (T=1) has zero transitions by definition
        and finalizes to an empty result; T=0 means the detector never saw a
        snapshot at all, which is a caller bug and raises.
        """
        if self._t == 0:
            raise ValueError(
                "finalize() on an empty sequence: 0 snapshots were pushed "
                "(scoring transitions needs at least 2)"
            )
        if self._base is not None:
            # Retire the incremental base chain: drops the retained T/P level
            # snapshots from the scratch store (and the scratch itself).  The
            # final embedding's z/scores are already materialized; only the
            # operator's scratch handles die here.
            self._base.release()
            self._base = None
        if not self._transitions:  # T == 1: nothing to score, not an error
            return SequenceResult(
                transitions=[],
                global_top_idx=jnp.zeros((0,), jnp.int32),
                global_top_val=jnp.zeros((0,), jnp.float32),
                global_top_step=jnp.zeros((0,), jnp.int32),
                n_snapshots=self._t,
                chain_builds=chain.chain_build_count() - self._builds0,
                transition_seconds=self._seconds,
                transition_metrics=self._metrics,
                warmup_metrics=self._warmup_metrics,
            )
        return SequenceResult(
            transitions=self._transitions,
            global_top_idx=jnp.asarray(self._g_idx),
            global_top_val=jnp.asarray(self._g_val),
            global_top_step=jnp.asarray(self._g_step),
            n_snapshots=self._t,
            chain_builds=chain.chain_build_count() - self._builds0,
            transition_seconds=self._seconds,
            transition_metrics=self._metrics,
            warmup_metrics=self._warmup_metrics,
        )

    def run(self, snapshots: Iterable[jax.Array]) -> SequenceResult:
        """Consume an iterator of T snapshots, score all T-1 transitions."""
        for a in snapshots:
            self.push(a)
        return self.finalize()


def detect_sequence_anomalies(
    ctx: DistContext,
    snapshots: Iterable[jax.Array],
    cfg: CommuteConfig | None = None,
    *,
    top_k: int = 10,
    use_kernel: bool = False,
    donate: bool = False,
) -> SequenceResult:
    """One-shot convenience wrapper around :class:`SequenceDetector`."""
    det = SequenceDetector(ctx, cfg, top_k=top_k, use_kernel=use_kernel, donate=donate)
    return det.run(snapshots)
