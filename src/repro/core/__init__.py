"""CADDeLaG core: distributed commute-time anomaly detection in JAX.

Public API re-exports.
"""

from repro.core.cad import CADResult, detect_anomalies, node_anomaly_scores, top_anomalies
from repro.core.chain import ChainOperator, chain_product
from repro.core.distmatrix import (
    SCHEDULES,
    DistContext,
    build_from_nodes,
    make_context,
    matmul,
    matmul_rowblock,
    trivial_context,
)
from repro.core.embedding import (
    CommuteConfig,
    Embedding,
    commute_distance_block,
    commute_time_embedding,
    edge_projection,
    exact_commute_distances,
)
from repro.core.solver import estimate_solution, residual_norm

__all__ = [
    "CADResult",
    "ChainOperator",
    "CommuteConfig",
    "DistContext",
    "Embedding",
    "SCHEDULES",
    "build_from_nodes",
    "chain_product",
    "commute_distance_block",
    "commute_time_embedding",
    "detect_anomalies",
    "edge_projection",
    "estimate_solution",
    "exact_commute_distances",
    "make_context",
    "matmul",
    "matmul_rowblock",
    "node_anomaly_scores",
    "residual_norm",
    "top_anomalies",
    "trivial_context",
]
