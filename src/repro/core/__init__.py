"""CADDeLaG core: distributed commute-time anomaly detection in JAX.

Public API re-exports.
"""

from repro.core.cad import CADResult, detect_anomalies, node_anomaly_scores, top_anomalies
from repro.core.chain import (
    ChainOperator,
    chain_build_count,
    chain_product,
    reset_chain_build_count,
)
from repro.core.delta_chain import (
    BaseChain,
    build_base_chain,
    full_build_gemm_cost,
    truncate_factors,
    try_delta_update,
)
from repro.core.distmatrix import (
    SCHEDULES,
    DistContext,
    build_from_nodes,
    make_context,
    matmul,
    matmul_rowblock,
    trivial_context,
)
from repro.core.embedding import (
    CommuteConfig,
    Embedding,
    commute_distance_block,
    commute_time_embedding,
    edge_projection,
    exact_commute_distances,
    validate_node_indices,
)
from repro.core.query import (
    QueryResult,
    commute_block,
    nearest_neighbors,
    rank_auc,
    top_anomalies_from_store,
)
from repro.core.sequence import SequenceDetector, SequenceResult, detect_sequence_anomalies
from repro.core.solver import estimate_solution, residual_norm
from repro.core.solvers import SolveReport, SolverSpec, estimate_rho, solve
from repro.core.tiles import (
    ProgramCacheStats,
    StreamStats,
    Tile,
    clear_program_cache,
    is_streamable,
    program_cache_stats,
    reset_program_cache_stats,
    reset_stream_stats,
    stream_stats,
    tile_map,
    tile_stream,
)

__all__ = [
    "BaseChain",
    "CADResult",
    "ChainOperator",
    "build_base_chain",
    "full_build_gemm_cost",
    "truncate_factors",
    "try_delta_update",
    "CommuteConfig",
    "ProgramCacheStats",
    "clear_program_cache",
    "program_cache_stats",
    "reset_program_cache_stats",
    "DistContext",
    "Embedding",
    "SCHEDULES",
    "SequenceDetector",
    "SequenceResult",
    "SolveReport",
    "SolverSpec",
    "StreamStats",
    "Tile",
    "build_from_nodes",
    "chain_build_count",
    "chain_product",
    "commute_distance_block",
    "commute_time_embedding",
    "detect_anomalies",
    "detect_sequence_anomalies",
    "edge_projection",
    "estimate_rho",
    "estimate_solution",
    "exact_commute_distances",
    "is_streamable",
    "make_context",
    "matmul",
    "matmul_rowblock",
    "node_anomaly_scores",
    "QueryResult",
    "commute_block",
    "nearest_neighbors",
    "rank_auc",
    "top_anomalies_from_store",
    "validate_node_indices",
    "reset_chain_build_count",
    "reset_stream_stats",
    "residual_norm",
    "solve",
    "stream_stats",
    "tile_map",
    "tile_stream",
    "top_anomalies",
    "trivial_context",
]
