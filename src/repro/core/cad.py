"""CAD anomaly scoring over a graph transition (paper Algorithm 4).

    dE      = |A_1 - A_2| (.) |D_1 - D_2|     (Hadamard)
    F_i     = sum_j dE[i, j]                  (node anomaly scores)

The commute-distance matrices D_t are *never materialized*: each device fuses
the distance evaluation ||Z_i - Z_j||^2 (two skinny GEMMs on the MXU), the
|dA| gate, and the row reduction inside its own adjacency tile.  Pairs with
dA = 0 contribute nothing -- the paper's "only compute d for changed pairs"
optimization becomes a fused multiply on dense hardware, which beats
gather/scatter on the MXU for dense graphs.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.distmatrix import DistContext
from repro.core.embedding import CommuteConfig, Embedding, commute_time_embedding
from repro.core.tiles import is_streamable, tile_map, tile_stream
from repro.obs import phase


def _cad_scores_body(tile, b1, b2, z1, z2, v1, v2):
    def dist(z, vol):
        zi = z[tile.rows].astype(jnp.float32)
        zj = z[tile.cols].astype(jnp.float32)
        sq_i = jnp.sum(zi * zi, -1)
        sq_j = jnp.sum(zj * zj, -1)
        return vol * (sq_i[:, None] + sq_j[None, :] - 2.0 * (zi @ zj.T))

    de = jnp.abs(b1.astype(jnp.float32) - b2.astype(jnp.float32)) * jnp.abs(
        dist(z1, v1) - dist(z2, v2)
    )
    return de.sum(axis=1)


def _cad_scores_kernel_body(tile, b1, b2, z1, z2, v1, v2):
    from repro.kernels import ops as kops

    return kops.cad_scores_tile(
        b1, b2, z1[tile.rows], z1[tile.cols], z2[tile.rows], z2[tile.cols], v1, v2
    )


def node_anomaly_scores(
    ctx: DistContext,
    a1: jax.Array,
    a2: jax.Array,
    e1: Embedding,
    e2: Embedding,
    *,
    use_kernel: bool = False,
    prefetch_depth: int | None = None,
) -> jax.Array:
    """F (n,) row-sharded; fused blockwise Alg. 4 lines 3-6.

    ``use_kernel=True`` swaps the tile body for the fused Pallas scorer
    (:func:`repro.kernels.cad_score.cad_scores_tile`) -- the tile program owns
    distribution, the kernel owns the on-chip schedule.

    Either adjacency may be a store-backed snapshot handle: the scorer then
    streams matching row panels of both endpoints (``prefetch_depth`` panels
    staged ahead by the panel pipeline) and the same tile body runs off-core,
    bitwise identical to the resident run.  Only the (n, k_RP) embeddings
    stay device-resident.
    """
    # Z is (n, k_RP) -- small; replicate it for tile-local access to rows+cols.
    z1 = ctx.constrain(e1.z, P(None, None))
    z2 = ctx.constrain(e2.z, P(None, None))
    streamed = is_streamable(a1) or is_streamable(a2)
    kwargs = {"prefetch_depth": prefetch_depth} if streamed else {}
    runner = tile_stream if streamed else tile_map
    with phase("score", streamed=streamed, kernel=use_kernel) as sp:
        scores = runner(
            ctx,
            _cad_scores_kernel_body if use_kernel else _cad_scores_body,
            a1,
            a2,
            z1,
            z2,
            e1.vol,
            e2.vol,
            in_specs=(
                ctx.matrix_spec,
                ctx.matrix_spec,
                P(None, None),
                P(None, None),
                P(),
                P(),
            ),
            reduce="cols",
            **kwargs,
        )
        sp.fence(scores)
    return scores


def top_anomalies(scores: jax.Array, k: int):
    vals, idx = lax.top_k(scores, k)
    return idx, vals


@dataclass
class CADResult:
    scores: jax.Array  # (n,) node anomaly scores
    top_idx: jax.Array  # (k,)
    top_val: jax.Array  # (k,)
    # Solver telemetry of the two endpoint embeddings (left, right); None
    # entries when an embedding was built before reports existed / externally.
    solve_reports: tuple = ()


def detect_anomalies(
    ctx: DistContext,
    a1: jax.Array,
    a2: jax.Array,
    cfg: CommuteConfig | None = None,
    *,
    top_k: int = 10,
    use_kernel: bool = False,
) -> CADResult:
    """End-to-end CADDeLaG (Algorithm 4) for one graph transition."""
    cfg = cfg or CommuteConfig()
    e1 = commute_time_embedding(ctx, a1, cfg, use_kernel=use_kernel)
    e2 = commute_time_embedding(ctx, a2, cfg, use_kernel=use_kernel)
    scores = node_anomaly_scores(
        ctx, a1, a2, e1, e2, use_kernel=use_kernel, prefetch_depth=cfg.prefetch_depth
    )
    idx, vals = top_anomalies(scores, top_k)
    # The operators die with this call: retire any out-of-core scratch they
    # hold, so a pairwise loop over a disk scratch dir stays bounded.
    for e in (e1, e2):
        if e.op is not None:
            e.op.release_scratch()
    return CADResult(
        scores=scores, top_idx=idx, top_val=vals,
        solve_reports=(e1.report, e2.report),
    )
