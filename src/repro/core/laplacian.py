"""Graph Laplacian pieces, computed blockwise on the sharded adjacency.

All outputs stay sharded; nothing here ever gathers the n x n matrix.
The degree vector is D = A @ 1 exactly as the paper computes it (one
Map + ReduceByKey in Spark == one row-reduction + psum here).

Tile bodies are module-level functions taking all data as *operands* (not
closures), so every call with the same body hits the tile-program compile
cache -- a T-snapshot sequence run compiles each of these programs once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.distmatrix import DistContext
from repro.core.tiles import is_streamable, tile_map, tile_stream


def _degrees_body(tile, blk):
    return blk.astype(jnp.float32).sum(axis=1)


def _sym_scale_body(tile, blk, scale_vec):
    """blk * scale[rows] x scale[cols] -- the D^{-1/2} . D^{-1/2} sandwich."""
    return (
        blk.astype(jnp.float32)
        * scale_vec[tile.rows][:, None]
        * scale_vec[tile.cols][None, :]
    )


def _norm_adj_deflate_body(tile, blk, inv_sqrt, deg, vol):
    s = blk.astype(jnp.float32) * inv_sqrt[tile.rows][:, None] * inv_sqrt[tile.cols][None, :]
    u_r = jnp.sqrt(jnp.maximum(deg[tile.rows], 0.0) / vol)
    u_c = jnp.sqrt(jnp.maximum(deg[tile.cols], 0.0) / vol)
    return s - u_r[:, None] * u_c[None, :]


def _laplacian_body(tile, blk, deg):
    eye = tile.diag_mask().astype(jnp.float32)
    return eye * deg[tile.rows][:, None] - blk.astype(jnp.float32)


def degrees(
    ctx: DistContext, a: jax.Array, *, prefetch_depth: int | None = None
) -> jax.Array:
    """d = A @ 1 as a replicated-column, row-sharded (n,) vector.

    Accepts a resident sharded adjacency or a store-backed snapshot handle;
    the streamed run is bitwise identical (row sums are row-parallel).
    """
    if is_streamable(a):
        return tile_stream(
            ctx, _degrees_body, a, reduce="cols", prefetch_depth=prefetch_depth
        )
    return tile_map(ctx, _degrees_body, a, reduce="cols")


def volume(ctx: DistContext, deg: jax.Array) -> jax.Array:
    return jnp.sum(deg.astype(jnp.float32))


def normalized_adjacency(
    ctx: DistContext,
    a: jax.Array,
    deg: jax.Array,
    *,
    deflate: bool = True,
    dtype=jnp.float32,
    prefetch_depth: int | None = None,
) -> jax.Array:
    """S = D^{-1/2} A D^{-1/2}, optionally deflated.

    Deflation subtracts the known top eigenpair (eigenvalue 1, eigenvector
    u = sqrt(d / V_G)): S~ = S - u u^T.  The paper's fp64 CPU chain tolerates
    the undeflated spectrum; a bf16 MXU chain does not -- the 2^d growth along
    u swamps the useful part of P in rounding error.  Closed form: the rank-1
    correction of tile (i, j) is sqrt(d_i d_j) / V_G.
    """
    vol = volume(ctx, deg)
    inv_sqrt = jnp.where(deg > 0, lax.rsqrt(jnp.maximum(deg, 1e-30)), 0.0)
    streamed = is_streamable(a)
    runner = tile_stream if streamed else tile_map
    kwargs = {"prefetch_depth": prefetch_depth} if streamed else {}
    if deflate:
        return runner(
            ctx,
            _norm_adj_deflate_body,
            a,
            inv_sqrt,
            deg,
            vol,
            in_specs=(ctx.matrix_spec, P(None), P(None), P()),
            out_dtype=dtype,
            **kwargs,
        )
    return runner(
        ctx,
        _sym_scale_body,
        a,
        inv_sqrt,
        in_specs=(ctx.matrix_spec, P(None)),
        out_dtype=dtype,
        **kwargs,
    )


def laplacian(
    ctx: DistContext,
    a: jax.Array,
    deg: jax.Array,
    *,
    dtype=jnp.float32,
    prefetch_depth: int | None = None,
) -> jax.Array:
    """L = D - A, materialized sharded (the paper-faithful path)."""
    streamed = is_streamable(a)
    runner = tile_stream if streamed else tile_map
    kwargs = {"prefetch_depth": prefetch_depth} if streamed else {}
    return runner(
        ctx,
        _laplacian_body,
        a,
        deg,
        in_specs=(ctx.matrix_spec, P(None)),
        out_dtype=dtype,
        **kwargs,
    )
