"""Graph Laplacian pieces, computed blockwise on the sharded adjacency.

All outputs stay sharded; nothing here ever gathers the n x n matrix.
The degree vector is D = A @ 1 exactly as the paper computes it (one
Map + ReduceByKey in Spark == one row-reduction + psum here).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.distmatrix import DistContext, blockwise_unary
from repro.core.tiles import is_streamable, tile_map, tile_stream


def degrees(ctx: DistContext, a: jax.Array) -> jax.Array:
    """d = A @ 1 as a replicated-column, row-sharded (n,) vector.

    Accepts a resident sharded adjacency or a store-backed snapshot handle;
    the streamed run is bitwise identical (row sums are row-parallel).
    """
    body = lambda tile, blk: blk.astype(jnp.float32).sum(axis=1)
    if is_streamable(a):
        return tile_stream(ctx, body, a, reduce="cols")
    return tile_map(ctx, body, a, reduce="cols")


def volume(ctx: DistContext, deg: jax.Array) -> jax.Array:
    return jnp.sum(deg.astype(jnp.float32))


def normalized_adjacency(
    ctx: DistContext,
    a: jax.Array,
    deg: jax.Array,
    *,
    deflate: bool = True,
    dtype=jnp.float32,
) -> jax.Array:
    """S = D^{-1/2} A D^{-1/2}, optionally deflated.

    Deflation subtracts the known top eigenpair (eigenvalue 1, eigenvector
    u = sqrt(d / V_G)): S~ = S - u u^T.  The paper's fp64 CPU chain tolerates
    the undeflated spectrum; a bf16 MXU chain does not -- the 2^d growth along
    u swamps the useful part of P in rounding error.  Closed form: the rank-1
    correction of tile (i, j) is sqrt(d_i d_j) / V_G.
    """
    vol = volume(ctx, deg)
    inv_sqrt = jnp.where(deg > 0, lax.rsqrt(jnp.maximum(deg, 1e-30)), 0.0)

    def tile(blk, rows, cols):
        s = blk.astype(jnp.float32) * inv_sqrt[rows][:, None] * inv_sqrt[cols][None, :]
        if deflate:
            u_r = jnp.sqrt(jnp.maximum(deg[rows], 0.0) / vol)
            u_c = jnp.sqrt(jnp.maximum(deg[cols], 0.0) / vol)
            s = s - u_r[:, None] * u_c[None, :]
        return s

    return blockwise_unary(ctx, tile, a, out_dtype=dtype)


def laplacian(ctx: DistContext, a: jax.Array, deg: jax.Array, *, dtype=jnp.float32) -> jax.Array:
    """L = D - A, materialized sharded (the paper-faithful path)."""

    def tile(blk, rows, cols):
        eye = (rows[:, None] == cols[None, :]).astype(jnp.float32)
        return eye * deg[rows][:, None] - blk.astype(jnp.float32)

    return blockwise_unary(ctx, tile, a, out_dtype=dtype)
