"""Commute-time embedding (paper Algorithm 3, CommuteTimeEmbedding).

For j = 1..k_RP:  y_j = B^T W^{1/2} q_j  (edge-space Rademacher projection,
generated counter-based -- see :mod:`repro.core.rng`),  solve L z_j = y_j with
the precomputed chain operator.  Stack Z = [z_1 .. z_k]; then

    c(i, j) ~= V_G * || Z_i - Z_j ||^2.

The edge projection never materializes the m = n^2 edge space: each device
reduces sqrt(A) (.) Q over its own adjacency tile, regenerating Q from integer
hashes.  One pass over A per batch of k_RP columns, zero stored randomness.
"""

from __future__ import annotations

import math
import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import rng as crng
from repro.core.chain import ChainOperator, chain_product
from repro.core.distmatrix import DistContext
from repro.core.solvers import SolveReport, SolverSpec, solve
from repro.core.tiles import is_streamable, tile_map, tile_stream
from repro.obs import REGISTRY, phase


@dataclass(frozen=True)
class CommuteConfig:
    """Accuracy knobs, named as in the paper (eps_RP, d, q)."""

    eps_rp: float = 1e-3
    d: int = 6  # inverse-chain length
    q: int = 10  # Richardson iterations
    seed: int = 0
    schedule: str = "cannon"
    dtype: object = jnp.float32
    deflate: bool = True
    fuse_l: bool = False
    k_override: int | None = None  # force embedding dim (tests/ablations)
    # Out-of-core chain: spill S/T/P/P1/P2 through a TileStore scratch so the
    # chain build (and the solver, via store-backed P1/P2) is panel-bounded.
    oocore: bool = False
    oocore_dir: str | None = None  # scratch dir; None = host-RAM scratch
    oocore_panel_rows: int | None = None  # override the streaming unit
    # Panel-I/O knobs (see repro.store.PanelPipeline): staging depth of the
    # background prefetch, scratch-tile storage codec (raw / bf16 / zstd),
    # and Richardson iteration batching (stream P2 once per `solver_batch`
    # iterations, replay from host RAM -- cuts solve-phase scratch reads).
    prefetch_depth: int = 2
    tile_codec: str = "raw"
    solver_batch: int = 1
    # Fused Pallas stream-GEMM path for the out-of-core hot loop: panels ship
    # at stored width (bf16 bit patterns decode in-kernel, halving H2D) and
    # streamed solve iterations fuse mat-vec + update + residual into one
    # pass over the panel stream.  Interpret-mode fallback off-TPU.
    use_gemm_kernel: bool = False
    # Solver subsystem (see repro.core.solvers): the iterative method, an
    # optional relative-residual target (None = fixed `q` iterations, the
    # historical behaviour), an optional hard step cap, and the paper's delta
    # (q = ceil(log 1/delta)) as an alternative way to bound iterations.
    solver: str = "richardson"  # "richardson" | "chebyshev" | "cg"
    solver_tol: float | None = None
    solver_max_iters: int | None = None
    delta: float | None = None
    # Warm-start sequence solves from the previous snapshot's solution: the
    # detector carries Embedding.z forward, so a slowly-drifting transition's
    # first residual starts ~|dA| instead of ~1 and tolerance-targeted solves
    # converge in far fewer iterations.  Scores stay allclose to cold solves
    # (same tolerance, same stopping metric); only the iteration count drops.
    warm_start: bool = False
    # Incremental delta-chain updates (repro.core.delta_chain): on a
    # slowly-drifting transition, skip the O(n^3) chain rebuild -- compress
    # the change in S to a rank-`delta_rank` factorisation, propagate it
    # through the squaring recurrence as skinny panel GEMMs against the
    # retained base chain (O(n^2 r) per level), and attach the result to the
    # operator as a low-rank correction every solve applies.  `delta_budget`
    # is the drift gate: the sketched relative drift ||dS|| / ||S|| (always
    # measured against the last *full-rebuild* base, so corrections never
    # compound error) above which the detector falls back to a full rebuild
    # and collapses the accumulated correction into a fresh base.
    incremental_chain: bool = False
    delta_rank: int = 4
    delta_budget: float = 0.1

    def k_rp(self, n: int) -> int:
        if self.k_override is not None:
            return int(self.k_override)
        return max(1, math.ceil(math.log(n / self.eps_rp)))

    def solver_spec(self) -> SolverSpec:
        """The :class:`~repro.core.solvers.SolverSpec` these knobs select."""
        return SolverSpec(
            method=self.solver,
            tolerance=self.solver_tol,
            max_iters=self.solver_max_iters,
            delta=self.delta,
        )


def _edge_projection_body(tile, blk, seed, ks):
    s = jnp.sqrt(jnp.maximum(blk.astype(jnp.float32), 0.0))
    q = crng.edge_rademacher(
        seed,
        tile.rows[:, None, None],
        tile.cols[None, :, None],
        ks[None, None, :],
    )
    # sum (not einsum): reduces each column over axis 1 in the same order
    # as the sequential per-column pass, keeping the output bit-identical.
    return jnp.sum(s[:, :, None] * q, axis=1)


def edge_projection(
    ctx: DistContext,
    a: jax.Array,
    seed: int,
    k: int,
    *,
    prefetch_depth: int | None = None,
) -> jax.Array:
    """Y = B^T W^{1/2} Q for k Rademacher columns, (n, k) row-sharded.

    Y[i, c] = sum_j sqrt(A[i, j]) * Q_c[i, j] with Q_c antisymmetric +/-1.
    Entries scaled 1/sqrt(k) (Johnson-Lindenstrauss normalization).

    All k Rademacher columns are generated in one vectorized (pr, pc, k) pass
    per tile -- same counter hash, same per-column reduction order (hence
    bitwise identical to the former sequential ``fori_loop``), but the VPU
    sees one fused multiply-reduce instead of k dependent passes (this is the
    layout the Pallas kernel in :mod:`repro.kernels.edge_projection` uses).
    ``a`` may be a store-backed snapshot handle; the projection then streams
    row panels (one pass over A either way).  The seed and the column counter
    enter as uint32 operands (same hash bits as the former literals), keeping
    the body a cache-stable module-level program.
    """
    seed_arr = jnp.asarray(np.uint32(int(seed) & 0xFFFFFFFF))
    ks = jnp.arange(k, dtype=jnp.uint32)
    kwargs = dict(
        in_specs=(ctx.matrix_spec, P(), P(None)),
        reduce="cols",
        out_spec=P(ctx.row_axes, None),
    )
    if is_streamable(a):
        y = tile_stream(
            ctx, _edge_projection_body, a, seed_arr, ks,
            prefetch_depth=prefetch_depth, **kwargs,
        )
    else:
        y = tile_map(ctx, _edge_projection_body, a, seed_arr, ks, **kwargs)
    return y * (1.0 / jnp.sqrt(jnp.float32(k)))


@dataclass
class Embedding:
    z: jax.Array  # (n, k) row-sharded
    vol: jax.Array  # scalar V_G
    op: ChainOperator | None = None  # kept for reuse across random batches
    report: SolveReport | None = None  # solver telemetry for this embedding's solve


def commute_time_embedding(
    ctx: DistContext,
    a: jax.Array,
    cfg: CommuteConfig,
    *,
    op: ChainOperator | None = None,
    use_kernel: bool = False,
    warm_from: jax.Array | None = None,
) -> Embedding:
    """Z (n, k_RP) commute-time embedding of ``a`` (Algorithm 3).

    ``a`` may be a resident sharded adjacency or a store-backed snapshot
    handle -- with a handle, the chain build and the edge projection stream
    row panels from the store and A is never fully device-resident.

    ``warm_from`` is a previous embedding's ``z`` (same n, same seed => same
    k): the solver starts from it instead of the cold ``y0 = chi`` start.
    Ignored (with a cold solve) when its shape does not match -- a sequence
    whose k_RP changed mid-stream should not crash the detector.
    """
    n = a.shape[0]
    k = cfg.k_rp(n)
    if op is None:
        with phase("chain", n=n, d=cfg.d, oocore=cfg.oocore) as sp:
            op = chain_product(
                ctx,
                a,
                cfg.d,
                schedule=cfg.schedule,
                dtype=cfg.dtype,
                deflate=cfg.deflate,
                fuse_l=cfg.fuse_l,
                use_kernel=use_kernel,
                oocore=cfg.oocore,
                oocore_work=cfg.oocore_dir,
                oocore_panel_rows=cfg.oocore_panel_rows,
                tile_codec=cfg.tile_codec,
                prefetch_depth=cfg.prefetch_depth,
                use_gemm_kernel=cfg.use_gemm_kernel,
            )
            sp.fence(op.p2 if not is_streamable(op.p2) else op.vol)
    with phase("ingest", n=n, k=k) as sp:
        y = edge_projection(
            ctx, a, cfg.seed, k, prefetch_depth=cfg.prefetch_depth
        )
        sp.fence(y)
    y0 = None
    if warm_from is not None:
        if tuple(warm_from.shape) == (int(n), int(k)):
            y0 = warm_from
        else:
            # A silent cold start here used to be invisible: the sequence kept
            # converging, just slowly.  Count it and warn so a mid-stream k_RP
            # (or n) change shows up in run reports and test output.
            REGISTRY.inc("solve.warm_skipped")
            warnings.warn(
                f"warm_from shape {tuple(warm_from.shape)} does not match the "
                f"expected ({int(n)}, {int(k)}); solving cold (counted in "
                "solve.warm_skipped)",
                RuntimeWarning,
                stacklevel=2,
            )
    with phase("solve", n=n, k=k, method=cfg.solver, warm=y0 is not None) as sp:
        z, report = solve(
            ctx,
            op,
            y,
            cfg.solver_spec(),
            fixed_q=cfg.q,
            deflate=cfg.deflate,
            solver_batch=cfg.solver_batch,
            prefetch_depth=cfg.prefetch_depth,
            y0=y0,
        )
        sp.fence(z)
    return Embedding(z=z, vol=op.vol, op=op, report=report)


def validate_node_indices(name: str, idx, n: int) -> None:
    """Raise ``IndexError`` naming the first bad index when any of ``idx``
    falls outside ``[0, n)``.

    jax's gather silently *clamps* out-of-range indices, so ``z[rows]`` with
    a bad row returns the edge row's distances -- a plausible-looking, wrong
    answer.  Validation only applies to concrete indices; traced indices
    (inside jit) cannot be checked at trace time and pass through.
    """
    try:
        arr = np.asarray(idx)
    except Exception:
        return  # traced: concrete values unavailable at trace time
    if arr.size == 0:
        return
    bad = (arr < 0) | (arr >= n)
    if bad.any():
        first = int(arr[bad][0] if arr.ndim else arr)
        raise IndexError(
            f"{name} index {first} is out of range for n={n} "
            "(valid node ids are 0..n-1; jax would silently clamp it)"
        )


def commute_distance_block(
    emb: Embedding, rows: jax.Array, cols: jax.Array
) -> jax.Array:
    """c(i, j) = V_G ||Z_i - Z_j||^2 for an index block (gathered Z rows)."""
    n = int(emb.z.shape[0])
    validate_node_indices("rows", rows, n)
    validate_node_indices("cols", cols, n)
    zi = emb.z[rows].astype(jnp.float32)
    zj = emb.z[cols].astype(jnp.float32)
    sq_i = jnp.sum(zi * zi, axis=-1)
    sq_j = jnp.sum(zj * zj, axis=-1)
    cross = zi @ zj.T
    return emb.vol * (sq_i[:, None] + sq_j[None, :] - 2.0 * cross)


def exact_commute_distances(a) -> jax.Array:
    """O(n^3) eigendecomposition oracle (tests / paper Fig. 2 baseline)."""
    import numpy as np

    a = np.asarray(a, np.float64)
    n = a.shape[0]
    deg = a.sum(1)
    l_mat = np.diag(deg) - a
    pinv = np.linalg.pinv(l_mat, rcond=1e-12)
    di = np.diag(pinv)
    vol = deg.sum()
    return jnp.asarray(vol * (di[:, None] + di[None, :] - 2.0 * pinv))
