"""llama4-maverick-400b-a17b [moe]: MoE top-1 128e, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Interleaved MoE (every 2nd layer, as in the HF release): 24 dense layers
with d_ff 2x16384 alternate with 24 MoE layers (128 routed experts top-1
with d_ff=8192 + 1 shared expert) -> ~400B total / ~17B active params.
Early fusion: image tokens share the 202048 vocab (frontend stub).
bf16 params + Adafactor second moments (see training/optim.py) keep the
per-chip optimizer footprint inside v5e HBM.  long_500k: SKIPPED (full attn).
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,           # expert FFN width; dense layers use 2x
    vocab=202048,
    n_experts=128,
    top_k=1,
    d_expert=8192,
    moe_layer_step=2,
    n_shared_experts=1,
    optimizer="adafactor",
    param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64,
    vocab=512, n_experts=8, d_expert=64, remat=False,
    param_dtype="float32", compute_dtype="float32",
)
