"""Architecture registry: ``--arch <id>`` -> (full config, smoke config).

All 10 assigned architectures plus the paper's own graph jobs.  Full configs
are exercised only via the dry-run (ShapeDtypeStruct, no allocation); smoke
tests instantiate the reduced SMOKE variants on CPU.
"""

from __future__ import annotations

import importlib

from repro.configs.shapes import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ShapeSpec,
    input_specs,
    is_supported,
    supported_shapes,
)
from repro.models.common import ArchConfig

_MODULES = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "granite-3-2b": "granite_3_2b",
    "qwen2-1.5b": "qwen2_1_5b",
    "deepseek-67b": "deepseek_67b",
    "stablelm-1.6b": "stablelm_1_6b",
    "zamba2-7b": "zamba2_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "rwkv6-3b": "rwkv6_3b",
    "chameleon-34b": "chameleon_34b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ArchConfig:
    return _module(arch_id).SMOKE


def all_cells() -> list[tuple[str, ShapeSpec]]:
    """Every supported (arch x shape) cell -- 34 runnable of the 40 assigned
    (6 long_500k cells are documented skips for quadratic-attention archs)."""
    cells = []
    for aid in ARCH_IDS:
        cfg = get_config(aid)
        for sh in supported_shapes(cfg):
            cells.append((aid, sh))
    return cells


__all__ = [
    "ALL_SHAPES",
    "ARCH_IDS",
    "DECODE_32K",
    "LONG_500K",
    "PREFILL_32K",
    "SHAPES_BY_NAME",
    "TRAIN_4K",
    "ShapeSpec",
    "all_cells",
    "get_config",
    "get_smoke",
    "input_specs",
    "is_supported",
    "supported_shapes",
]
