"""granite-moe-3b-a800m [moe]: 40 experts top-8, fine-grained d_expert=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

NOTE: the assignment text says both "MoE 40e" and "32 experts"; we follow
the structured spec (40 experts, top-8), matching granite-3.0-3b-a800m.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    n_experts=40,
    top_k=8,
    d_expert=512,
    tie_embeddings=True,
    # 40 experts don't divide the 16-way model axis: shard the dispatch
    # capacity dim over the whole mesh and the tiny expert FFN over model.
    rules_override=(("experts", None), ("expert_ff", "model"), ("moe_cap", ("data", "model"))),
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=32, vocab=512,
    n_experts=8, top_k=2, d_expert=32, remat=False,
    param_dtype="float32", compute_dtype="float32",
)
