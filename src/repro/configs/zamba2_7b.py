"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block.
[arXiv:2411.15242; unverified].

81 Mamba2 layers; one weight-SHARED attention+MLP block applied every 6
layers (13 invocations; its input is concat(hidden, initial-embedding), so
the attention runs at width 2*d_model).  d_ff=14336 is the shared block's
FFN.  long_500k RUNS: SSM state is O(1) in sequence length and the shared
block decodes against its KV cache (linear per token).
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=64,  # S*Q*H decay-tensor memory is linear in Q (EXPERIMENTS P5)
    attn_every=6,
)

SMOKE = CONFIG.replace(
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    ssm_state=16, ssm_headdim=16, ssm_chunk=8, attn_every=3,
    remat=False, param_dtype="float32", compute_dtype="float32",
)
