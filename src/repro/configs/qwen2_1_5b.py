"""qwen2-1.5b [dense]: GQA with QKV bias.  [arXiv:2407.10671; hf]."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    remat=False, param_dtype="float32", compute_dtype="float32",
)
