"""deepseek-67b [dense]: llama-arch GQA.  [arXiv:2401.02954; hf].

bf16 params (134 GB): at 256+ chips the FSDP shard is ~0.5 GB/chip.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160, vocab=512,
    remat=False, param_dtype="float32", compute_dtype="float32",
)
