"""chameleon-34b [vlm]: early-fusion, VQ image tokens, QK-norm.
[arXiv:2405.09818; unverified].

The image tokenizer is a STUB: VQ image tokens share the 65536 vocabulary,
so input_specs() provides plain token ids (mixed text/image stream).
QK-norm per Chameleon's training-stability fix.  long_500k: SKIPPED.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    remat=False, param_dtype="float32", compute_dtype="float32",
)
