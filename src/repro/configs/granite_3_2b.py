"""granite-3-2b [dense]: GQA.  [hf:ibm-granite/granite-3.0-2b-base; hf]."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    remat=False, param_dtype="float32", compute_dtype="float32",
)
