"""seamless-m4t-medium [audio]: enc-dec multimodal backbone.

[arXiv:2308.11596; hf].  "12L" = 12 encoder + 12 decoder layers (HF card).
The audio frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, S, d_model) for the encoder; the decoder consumes tokens.
kv=16 == n_heads -> MHA.  long_500k: SKIPPED (full quadratic attention).
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,
    enc_layers=12,
    dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    norm="ln",
    input_mode="frames",
)

SMOKE = CONFIG.replace(
    enc_layers=2, dec_layers=2, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, remat=False, param_dtype="float32", compute_dtype="float32",
)
