"""Input-shape cells shared by all assigned LM architectures.

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), NOT ``train_step``.  ``long_500k`` needs sub-quadratic
attention: it runs only for SSM/hybrid archs (zamba2, rwkv6) and is a
documented skip for pure full-attention archs (see DESIGN.md).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}

# families whose attention cost is sub-quadratic in context (state-based)
_SUBQUADRATIC = ("ssm", "hybrid")


def supported_shapes(cfg: ArchConfig) -> tuple[ShapeSpec, ...]:
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.family in _SUBQUADRATIC:
        out.append(LONG_500K)
    return tuple(out)


def is_supported(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    return any(s.name == shape.name for s in supported_shapes(cfg))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Weak-type-correct, shardable, zero device allocation.  ``[audio]``/``[vlm]``
    archs: the modality frontend is a stub -- for seamless the encoder input is
    precomputed frame embeddings (B, S, d_model); for chameleon the VQ image
    tokens share the token vocabulary, so inputs are plain token ids.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.input_mode == "frames":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.input_mode == "frames":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdtype)
        return specs
    if shape.kind == "decode":
        # one new token; the KV cache of length seq_len is built by the caller
        # via jax.eval_shape(init_cache, ...) -- see launch/dryrun.py.
        return {"token": jax.ShapeDtypeStruct((b,), i32)}
    raise ValueError(shape.kind)
