"""The paper's own workload configs: dense-graph anomaly detection.

``CLIMATE`` mirrors section 4.2.1 Climate Data: 259,200 geolocations
(0.5-degree grid), fully connected, Gaussian kernel sigma=388.
``SYNTH_*`` mirror the scalability study sizes of Fig. 3.
"""

from dataclasses import dataclass

from repro.core.embedding import CommuteConfig


@dataclass(frozen=True)
class GraphJob:
    name: str
    n_nodes: int
    commute: CommuteConfig
    top_k: int = 100


# paper defaults: eps 1e-2/1e-3, d=3, q=10 (section 4.2.2)
_DEFAULT = CommuteConfig(eps_rp=1e-3, d=6, q=10, schedule="cannon", fuse_l=True)

CLIMATE = GraphJob(name="climate-0.5deg", n_nodes=259200, commute=_DEFAULT)
ELECTION = GraphJob(name="election-donors", n_nodes=555924, commute=_DEFAULT)
SYNTH_100K = GraphJob(name="synth-100k", n_nodes=100000, commute=_DEFAULT)
SYNTH_200K = GraphJob(name="synth-200k", n_nodes=200000, commute=_DEFAULT)
SYNTH_500K = GraphJob(name="synth-500k", n_nodes=500000, commute=_DEFAULT)
