"""stablelm-1.6b [dense]: MHA (kv=32), LayerNorm.
[hf:stabilityai/stablelm-2-1_6b; unverified]."""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    norm="ln",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    remat=False, param_dtype="float32", compute_dtype="float32",
)
