"""rwkv6-3b [ssm]: Finch, data-dependent decay, attention-free.
[arXiv:2404.05892; hf].

40 heads x 64 head-dim; token-shift + LoRA-parameterized per-channel decay.
long_500k RUNS: recurrence state is O(1) in context length.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    rwkv=True,
    n_layers=32,
    d_model=2560,
    n_heads=40,        # informational; the wkv recurrence uses rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    rwkv_head_dim=64,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    rwkv_head_dim=16, ssm_chunk=8, remat=False,
    param_dtype="float32", compute_dtype="float32",
)
