"""Persisted commute-embedding artifacts: the query-scale read path's store.

The exact pipeline (chain build + solve) is the *write* path; queries should
never pay it again.  :class:`EmbeddingStore` persists each transition's
committed embedding -- the (n, k_RP) sketch ``Z`` plus the graph volume, the
degree vector and the column-mean ``zbar`` -- as a compact row-panel artifact
that readers (:mod:`repro.core.query`) stream without ever touching live
solver state.  ``SequenceDetector.push`` publishes here after each solve, so
an artifact is by construction a *committed* snapshot of the sketch: a crash
mid-publish leaves the previous embedding current, never a torn one.

The store reuses the :class:`~repro.store.tilestore.TileStore` durability
idioms exactly:

* every panel is written to a temp file and ``os.replace``d into place
  (atomic on POSIX); ``aux`` (vol / deg / zbar) likewise;
* an embedding id joins the manifest only once all its panels and the aux
  sidecar exist (commit-on-complete; re-opening after a crash sees only
  complete embeddings);
* the manifest is fingerprinted on (seed, k, codec, geometry) plus a
  caller-supplied ``meta`` dict -- re-creating a store under different
  parameters is rejected loudly instead of silently serving a stale sketch
  (a ``Z`` drawn under another seed is a *different random projection*; its
  distances are meaningless against this run's queries);
* panels are stored through the tile codecs: ``raw`` (fp32 .npy) or ``bf16``
  (uint16 bit patterns, half the bytes, decoded on-device by the query
  kernel).  ``zstd`` has no device-decodable stored form and is rejected --
  the query path is built around encoded panel shipping.

:class:`EmbeddingHandle` satisfies the snapshot-handle panel protocol
(``shape`` / ``dtype`` / ``panel_rows`` / ``read_panel`` /
``read_panel_info`` / ``read_panel_encoded_info``), so the generic
:class:`~repro.store.pipeline.PanelPipeline` streams ``Z`` row panels with
the same prefetch/accounting machinery the chain executors use.
"""

from __future__ import annotations

import json
import os
import shutil
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterator

import numpy as np

from repro.store.tilestore import MANIFEST_NAME, resolve_codec

_FORMAT_VERSION = 1
_AUX_NAME = "aux.npz"

# Codecs with a device-decodable stored form only: the query kernel ships
# panels encoded (uint16 bf16 bits widen in VMEM), which zstd cannot do.
EMB_CODECS = ("raw", "bf16")


@dataclass
class EmbManifest:
    """Static geometry + provenance fingerprint of every embedding artifact.

    ``seed`` is part of the fingerprint alongside (k, codec, geometry): two
    stores with equal shapes but different projection seeds hold incomparable
    sketches, and resuming one as the other must fail loudly.  ``meta`` is
    the caller's content label (dataset, generator params), with the same
    reject-on-mismatch contract as the snapshot store.
    """

    n: int
    k: int
    panel_rows: int
    dtype: str
    codec: str = "raw"
    seed: int = 0
    embeddings: list[str] = field(default_factory=list)
    meta: dict = field(default_factory=dict)
    version: int = _FORMAT_VERSION

    def __post_init__(self):
        if self.n < 1 or self.k < 1:
            raise ValueError(f"need n >= 1 and k >= 1, got n={self.n} k={self.k}")
        if self.panel_rows < 1 or self.n % self.panel_rows:
            raise ValueError(
                f"panel_rows {self.panel_rows} must divide n={self.n}"
            )

    @property
    def panels(self) -> int:
        return self.n // self.panel_rows

    def fingerprint(self) -> tuple:
        return (self.n, self.k, self.panel_rows, self.dtype, self.codec, self.seed)

    def to_json(self) -> str:
        return json.dumps(
            {
                "version": self.version,
                "kind": "embstore",
                "n": self.n,
                "k": self.k,
                "panel_rows": self.panel_rows,
                "dtype": self.dtype,
                "codec": self.codec,
                "seed": self.seed,
                "embeddings": list(self.embeddings),
                "meta": dict(self.meta),
            },
            indent=2,
        )

    @classmethod
    def from_json(cls, text: str) -> "EmbManifest":
        d = json.loads(text)
        if d.get("kind") != "embstore":
            raise ValueError(
                f"manifest kind {d.get('kind')!r} is not an embedding store "
                "(a TileStore directory cannot be opened as an EmbeddingStore)"
            )
        if d.get("version", 0) > _FORMAT_VERSION:
            raise ValueError(f"embstore format v{d['version']} is newer than this reader")
        return cls(
            n=int(d["n"]),
            k=int(d["k"]),
            panel_rows=int(d["panel_rows"]),
            dtype=str(d["dtype"]),
            codec=str(d.get("codec", "raw")),
            seed=int(d.get("seed", 0)),
            embeddings=[str(s) for s in d.get("embeddings", [])],
            meta=dict(d.get("meta", {})),
            version=int(d.get("version", _FORMAT_VERSION)),
        )


def default_panel_rows(n: int, want: int = 256) -> int:
    """The largest divisor of ``n`` <= ``want`` (MXU-alignment preferred)."""
    from repro.kernels.tiling import fit

    return fit(n, want)


class EmbeddingStore:
    """A sequence of committed (Z, vol, deg, zbar) embedding artifacts.

    Use :meth:`create` / :meth:`open` rather than the constructor::

        store = EmbeddingStore.create(dir_or_none, n=1024, k=14, seed=0)
        store.put_embedding("t0003", z, vol, deg)     # publish one artifact
        h = store.latest()                            # EmbeddingHandle
        for row0 in range(0, h.shape[0], h.panel_rows):
            panel = h.read_panel(row0, h.panel_rows)

    ``root=None`` selects the host-RAM backend (same API, dict of arrays).
    """

    def __init__(self, manifest: EmbManifest, root: str | Path | None):
        if manifest.codec not in EMB_CODECS:
            raise ValueError(
                f"embedding store codec must be one of {EMB_CODECS}, got "
                f"{manifest.codec!r} (the query kernel needs a device-"
                "decodable stored form)"
            )
        self.manifest = manifest
        self.root = Path(root) if root is not None else None
        self._ram_panels: dict[tuple[str, int], np.ndarray] = {}
        self._ram_aux: dict[str, dict[str, np.ndarray]] = {}
        self.codec = resolve_codec(manifest.codec, fallback=False)
        if self.codec.name == "bf16" and np.dtype(manifest.dtype) != np.float32:
            raise ValueError(
                f"bf16 codec stores float32 embeddings only, not {manifest.dtype}"
            )

    # -- lifecycle -----------------------------------------------------------

    @classmethod
    def create(
        cls,
        root: str | Path | None,
        *,
        n: int,
        k: int,
        panel_rows: int | None = None,
        dtype="float32",
        codec: str = "raw",
        seed: int = 0,
        meta: dict | None = None,
    ) -> "EmbeddingStore":
        """New store at ``root`` (made if missing); ``root=None`` = RAM-backed.

        Resuming an existing directory requires a matching fingerprint
        (seed, k, codec, geometry) AND matching meta -- committed artifacts
        from a differently-parameterized run are rejected, never served.
        """
        pr = default_panel_rows(n) if panel_rows is None else int(panel_rows)
        manifest = EmbManifest(
            n=n, k=k, panel_rows=pr, dtype=np.dtype(dtype).name,
            codec=resolve_codec(codec).name, seed=int(seed), meta=dict(meta or {}),
        )
        store = cls(manifest, root)
        if store.root is not None:
            store.root.mkdir(parents=True, exist_ok=True)
            existing = store.root / MANIFEST_NAME
            if existing.exists():
                old = EmbManifest.from_json(existing.read_text())
                if old.fingerprint() != manifest.fingerprint():
                    raise ValueError(
                        f"embedding store at {root} already exists with an "
                        f"incompatible fingerprint {old.fingerprint()} != "
                        f"requested {manifest.fingerprint()} "
                        "(n, k, panel_rows, dtype, codec, seed); use a fresh "
                        "directory -- a differently-seeded sketch is a "
                        "different random projection"
                    )
                if meta is not None and old.meta != manifest.meta:
                    if old.meta or old.embeddings:
                        raise ValueError(
                            f"embedding store at {root} holds different content: "
                            f"meta {old.meta or '<unlabeled, has embeddings>'} != "
                            f"requested {manifest.meta}; use a fresh directory"
                        )
                store.manifest = old  # resume: keep committed embeddings
                if meta is not None and old.meta != manifest.meta:
                    store.manifest.meta = manifest.meta
                    store._write_manifest()
            else:
                store._write_manifest()
        return store

    @classmethod
    def open(cls, root: str | Path) -> "EmbeddingStore":
        root = Path(root)
        manifest = EmbManifest.from_json((root / MANIFEST_NAME).read_text())
        return cls(manifest, root)

    def _write_manifest(self) -> None:
        if self.root is None:
            return
        tmp = self.root / (MANIFEST_NAME + ".tmp")
        tmp.write_text(self.manifest.to_json())
        os.replace(tmp, self.root / MANIFEST_NAME)

    def _refresh_manifest(self) -> None:
        """Read-modify-write guard: re-read the committed list before mutating
        (several instances may share one directory over a run's lifetime)."""
        if self.root is None:
            return
        path = self.root / MANIFEST_NAME
        if path.exists():
            self.manifest.embeddings = EmbManifest.from_json(
                path.read_text()
            ).embeddings

    # -- geometry ------------------------------------------------------------

    @property
    def n(self) -> int:
        return self.manifest.n

    @property
    def k(self) -> int:
        return self.manifest.k

    @property
    def panel_rows(self) -> int:
        return self.manifest.panel_rows

    @property
    def dtype(self) -> np.dtype:
        return np.dtype(self.manifest.dtype)

    @property
    def embedding_ids(self) -> list[str]:
        return list(self.manifest.embeddings)

    def __len__(self) -> int:
        return len(self.manifest.embeddings)

    # -- panel I/O -----------------------------------------------------------

    def _panel_path(self, emb_id: str, p: int) -> Path:
        assert self.root is not None
        return self.root / emb_id / f"z_{p:04d}{self.codec.suffix}"

    def _aux_path(self, emb_id: str) -> Path:
        assert self.root is not None
        return self.root / emb_id / _AUX_NAME

    def has_panel(self, emb_id: str, p: int) -> bool:
        if self.root is None:
            return (emb_id, p) in self._ram_panels
        return self._panel_path(emb_id, p).exists()

    def has_aux(self, emb_id: str) -> bool:
        if self.root is None:
            return emb_id in self._ram_aux
        return self._aux_path(emb_id).exists()

    def _load_stored(self, emb_id: str, p: int, *, mmap: bool = True) -> np.ndarray:
        if self.root is None:
            return self._ram_panels[(emb_id, p)]
        return np.load(self._panel_path(emb_id, p), mmap_mode="r" if mmap else None)

    def read_panel_stored(self, emb_id: str, p: int) -> np.ndarray:
        """One (panel_rows, k) panel in its *stored* form (raw fp32 or uint16
        bf16 bit patterns -- what the query kernel decodes on-device)."""
        if not (0 <= p < self.manifest.panels):
            raise IndexError(f"panel {p} outside {self.manifest.panels} panels")
        arr = np.asarray(self._load_stored(emb_id, p))
        want = (self.panel_rows, self.k)
        if arr.shape != want:
            raise ValueError(
                f"panel {p} of {emb_id!r} stored as {arr.shape}, manifest says {want}"
            )
        return arr

    def read_panel(self, emb_id: str, p: int) -> np.ndarray:
        """One (panel_rows, k) dense *decoded* panel."""
        stored = self.read_panel_stored(emb_id, p)
        arr = self.codec.decode(stored, self.panel_rows, self.dtype)
        return np.asarray(arr).reshape(self.panel_rows, self.k)

    def panel_nbytes_stored(self, emb_id: str, p: int) -> int:
        if self.root is None:
            return self.codec.stored_nbytes(self._ram_panels[(emb_id, p)])
        return self._panel_path(emb_id, p).stat().st_size

    def read_aux(self, emb_id: str) -> dict[str, np.ndarray]:
        """``{vol: (), deg: (n,), zbar: (k,)}`` -- the small fp32/fp64 sidecar."""
        if self.root is None:
            aux = self._ram_aux[emb_id]
        else:
            with np.load(self._aux_path(emb_id)) as z:
                aux = {name: np.asarray(z[name]) for name in z.files}
        for name in ("vol", "deg", "zbar"):
            if name not in aux:
                raise ValueError(f"aux sidecar of {emb_id!r} is missing {name!r}")
        return aux

    # -- write path ----------------------------------------------------------

    def put_embedding(
        self, emb_id: str, z, vol, deg, *, zbar=None
    ) -> "EmbeddingHandle":
        """Persist one committed embedding artifact and commit it.

        ``z`` is the (n, k) sketch (host array or jax array -- copied to host
        here, so the reader never aliases live solver buffers), ``vol`` the
        scalar graph volume, ``deg`` the (n,) degree vector.  ``zbar`` (the
        column mean of Z, which the centroid-anomaly query needs) defaults to
        being computed here.  Panels already on disk are skipped (resume);
        the id joins the manifest only once every panel and the aux sidecar
        exist.
        """
        if "/" in emb_id or emb_id in ("", ".", ".."):
            raise ValueError(f"bad embedding id {emb_id!r}")
        z = np.ascontiguousarray(np.asarray(z, dtype=self.dtype))
        if z.shape != (self.n, self.k):
            raise ValueError(
                f"embedding is {z.shape}, store holds ({self.n}, {self.k})"
            )
        deg = np.asarray(deg, dtype=np.float32).reshape(-1)
        if deg.shape != (self.n,):
            raise ValueError(f"deg is {deg.shape}, want ({self.n},)")
        zbar = (
            z.mean(axis=0, dtype=np.float64).astype(np.float32)
            if zbar is None
            else np.asarray(zbar, dtype=np.float32).reshape(self.k)
        )
        aux = {
            "vol": np.asarray(float(vol), dtype=np.float64),
            "deg": deg,
            "zbar": zbar,
        }
        pr = self.panel_rows
        for p in range(self.manifest.panels):
            if self.has_panel(emb_id, p):
                continue  # resume after a partial publish
            stored = self.codec.encode(z[p * pr : (p + 1) * pr])
            self._store_panel(emb_id, p, np.asarray(stored))
        self._store_aux(emb_id, aux)
        self._commit(emb_id)
        return self.embedding(emb_id)

    def _store_panel(self, emb_id: str, p: int, stored: np.ndarray) -> None:
        if self.root is None:
            self._ram_panels[(emb_id, p)] = np.array(stored, copy=True)
            return
        path = self._panel_path(emb_id, p)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(path.name + ".tmp")
        with open(tmp, "wb") as f:
            np.save(f, stored)
        os.replace(tmp, path)  # atomic: old or new, never torn

    def _store_aux(self, emb_id: str, aux: dict[str, np.ndarray]) -> None:
        if self.root is None:
            self._ram_aux[emb_id] = {k: np.array(v, copy=True) for k, v in aux.items()}
            return
        path = self._aux_path(emb_id)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(path.name + ".tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **aux)
        os.replace(tmp, path)

    def _commit(self, emb_id: str) -> None:
        missing = [
            p for p in range(self.manifest.panels) if not self.has_panel(emb_id, p)
        ]
        if missing or not self.has_aux(emb_id):
            raise ValueError(
                f"embedding {emb_id!r} incomplete: "
                f"{len(missing)} panels missing, aux={'ok' if self.has_aux(emb_id) else 'missing'}"
            )
        self._refresh_manifest()
        if emb_id not in self.manifest.embeddings:
            self.manifest.embeddings.append(emb_id)
            self._write_manifest()

    def remove_embedding(self, emb_id: str) -> None:
        """Drop an artifact (manifest entry first, then panels -- a crash in
        between leaves orphan panels, never a committed id without panels)."""
        if "/" in emb_id or emb_id in ("", ".", ".."):
            raise ValueError(f"bad embedding id {emb_id!r}")
        self._refresh_manifest()
        if emb_id in self.manifest.embeddings:
            self.manifest.embeddings.remove(emb_id)
            self._write_manifest()
        if self.root is None:
            for key in [k for k in self._ram_panels if k[0] == emb_id]:
                del self._ram_panels[key]
            self._ram_aux.pop(emb_id, None)
        else:
            emb_dir = self.root / emb_id
            if emb_dir.exists():
                shutil.rmtree(emb_dir)

    # -- read path -----------------------------------------------------------

    def embedding(self, emb_id: str) -> "EmbeddingHandle":
        if emb_id not in self.manifest.embeddings:
            raise KeyError(
                f"embedding {emb_id!r} not committed; have {self.manifest.embeddings}"
            )
        return EmbeddingHandle(self, emb_id)

    def latest(self) -> "EmbeddingHandle":
        """The most recently committed artifact (what "now" queries serve)."""
        if not self.manifest.embeddings:
            raise KeyError("embedding store is empty: nothing committed yet")
        return EmbeddingHandle(self, self.manifest.embeddings[-1])

    def iter_embeddings(self) -> Iterator["EmbeddingHandle"]:
        for eid in self.manifest.embeddings:
            yield EmbeddingHandle(self, eid)


@dataclass(frozen=True)
class EmbeddingHandle:
    """Store-backed stand-in for a resident (n, k) embedding ``Z``.

    Satisfies the panel-streaming protocol (``shape`` / ``dtype`` /
    ``panel_rows`` / ``read_panel`` / ``read_panel_info`` /
    ``read_panel_encoded_info``), so :class:`~repro.store.PanelPipeline`
    streams it exactly like a snapshot handle.  ``vol`` / ``deg`` / ``zbar``
    expose the aux sidecar (cached after the first read -- it is a few n
    floats, not an n^2 object).
    """

    store: EmbeddingStore
    emb_id: str

    @property
    def shape(self) -> tuple[int, int]:
        return (self.store.n, self.store.k)

    @property
    def dtype(self) -> np.dtype:
        return self.store.dtype

    @property
    def nbytes(self) -> int:
        return self.store.n * self.store.k * self.store.dtype.itemsize

    @property
    def panel_rows(self) -> int:
        return self.store.panel_rows

    def _aux(self) -> dict[str, np.ndarray]:
        cached = getattr(self, "_aux_cache", None)
        if cached is None:
            cached = self.store.read_aux(self.emb_id)
            object.__setattr__(self, "_aux_cache", cached)
        return cached

    @property
    def vol(self) -> float:
        return float(self._aux()["vol"])

    @property
    def deg(self) -> np.ndarray:
        return self._aux()["deg"]

    @property
    def zbar(self) -> np.ndarray:
        return self._aux()["zbar"]

    def inv_deg(self) -> np.ndarray:
        """1/deg with zero-degree nodes mapped to 0 (isolated nodes have no
        commute-time limit to correct against)."""
        deg = self.deg
        return np.where(deg > 0, 1.0 / np.maximum(deg, 1e-30), 0.0).astype(np.float32)

    def _panel_range(self, row0: int, height: int) -> tuple[int, int]:
        pr = self.store.panel_rows
        if row0 % pr or height % pr:
            raise ValueError(
                f"panel [{row0}:{row0 + height}] not panel-aligned (panel={pr})"
            )
        return row0 // pr, (row0 + height) // pr

    def read_panel(self, row0: int, height: int) -> np.ndarray:
        p_lo, p_hi = self._panel_range(row0, height)
        rows = [self.store.read_panel(self.emb_id, p) for p in range(p_lo, p_hi)]
        return rows[0] if len(rows) == 1 else np.concatenate(rows, axis=0)

    def read_panel_info(self, row0: int, height: int) -> tuple[np.ndarray, int]:
        panel = self.read_panel(row0, height)
        p_lo, p_hi = self._panel_range(row0, height)
        stored = sum(
            self.store.panel_nbytes_stored(self.emb_id, p) for p in range(p_lo, p_hi)
        )
        return panel, stored

    def read_panel_encoded_info(
        self, row0: int, height: int
    ) -> tuple[np.ndarray, int, int]:
        """Stored-form panel for on-device decode (bf16: uint16 bit patterns,
        half the decoded H2D bytes; raw: already the decoded form)."""
        if self.store.codec.name != "bf16":
            panel, stored = self.read_panel_info(row0, height)
            return panel, stored, panel.nbytes
        p_lo, p_hi = self._panel_range(row0, height)
        rows = [
            self.store.read_panel_stored(self.emb_id, p) for p in range(p_lo, p_hi)
        ]
        panel = rows[0] if len(rows) == 1 else np.concatenate(rows, axis=0)
        stored = sum(
            self.store.panel_nbytes_stored(self.emb_id, p) for p in range(p_lo, p_hi)
        )
        return panel, stored, panel.size * self.store.dtype.itemsize

    def read_rows(self, rows) -> np.ndarray:
        """Gather a few Z rows (query vectors) via panel reads on the host."""
        rows = np.asarray(rows).reshape(-1)
        pr = self.store.panel_rows
        out = np.empty((rows.size, self.store.k), self.store.dtype)
        for p in np.unique(rows // pr):
            panel = self.store.read_panel(self.emb_id, int(p))
            sel = rows // pr == p
            out[sel] = panel[rows[sel] - int(p) * pr]
        return out

    def to_numpy(self) -> np.ndarray:
        """Gather the whole sketch (tests / small n only)."""
        return np.asarray(self.read_panel(0, self.store.n))
