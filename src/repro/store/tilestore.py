"""Out-of-core graph snapshot store: dense adjacencies as grids of tiles.

The paper's premise is scoring graph sequences "without the need to load the
entire graph in memory": snapshots live on Lustre and Spark streams block
rows through the executors.  This module is the JAX-side equivalent: a
:class:`TileStore` keeps each n x n snapshot as a ``grid x grid`` array of
dense tiles, backed by host RAM or by one ``.npy`` file per tile on disk,
with a JSON manifest recording ``n``, ``grid``, ``dtype`` and the committed
snapshot order.  Devices never see a whole snapshot: the streaming executor
(:func:`repro.core.tiles.tile_stream`) fetches one row panel of tiles at a
time, so HBM residency is bounded by two panels, not by n^2.

Durability contract (resume after partial write): every tile is written to a
temp file and ``os.replace``d into place (atomic on POSIX), and a snapshot id
is appended to the manifest only by :meth:`SnapshotWriter.commit` once all
``grid**2`` tiles exist.  Re-opening a store after a crash therefore sees only
complete snapshots; re-running a writer skips tiles already on disk and
commits the remainder.

:class:`SnapshotHandle` is the object the core accepts wherever a resident
``jax.Array`` adjacency is accepted (``detect_anomalies``,
``SequenceDetector.push``, ``commute_time_embedding`` ...).  The core does not
import this module -- it duck-types on the handle protocol
(``shape`` / ``dtype`` / ``panel_rows`` / ``read_panel``), see
:func:`repro.core.tiles.is_streamable`.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Iterator

import numpy as np

MANIFEST_NAME = "manifest.json"
_FORMAT_VERSION = 1


# ---------------------------------------------------------------------------
# tile codecs: encode-on-write, decode-on-read
# ---------------------------------------------------------------------------
#
# Dense similarity tiles compress well, and out-of-core runs are disk-
# bandwidth-bound (an oocore chain writes ~2 d n^2 scratch bytes per build),
# so the store trades decode CPU for bytes on the capacity tier.  Decoding
# happens wherever ``read_tile`` runs -- for the streaming executors that is
# the PanelPipeline's prefetch thread, so decompression overlaps device
# compute.  The codec is part of the manifest fingerprint: a directory can
# hold tiles of exactly one codec, and re-creating it under a different codec
# errors loudly instead of mixing encodings.


def _f32_to_bf16_u16(a: np.ndarray) -> np.ndarray:
    """fp32 -> bf16 bit pattern (uint16), round-to-nearest-even."""
    try:
        from ml_dtypes import bfloat16  # jax dependency; RNE casts

        return np.asarray(a, dtype=bfloat16).view(np.uint16)
    except ImportError:  # pure-numpy fallback (no NaN payloads expected)
        bits = np.ascontiguousarray(a, dtype=np.float32).view(np.uint32).astype(np.uint64)
        return ((bits + 0x7FFF + ((bits >> 16) & 1)) >> 16).astype(np.uint16)


def _bf16_u16_to_f32(u: np.ndarray) -> np.ndarray:
    """bf16 bit pattern (uint16) -> fp32 (exact widening)."""
    return (np.asarray(u, dtype=np.uint32) << 16).view(np.float32)


def _zstd_backend():
    """The installed zstd implementation, or None (optional dependency)."""
    try:
        import zstandard

        return zstandard
    except ImportError:
        pass
    try:
        import zstd

        return zstd
    except ImportError:
        return None


class TileCodec:
    """Storage encoding of one tile.  ``encode`` maps a logical-dtype block to
    its stored form (an ndarray for .npy-backed codecs, bytes for compressed
    ones); ``decode`` inverts it.  ``stored_nbytes`` is what the backing tier
    actually holds -- the pre-decode number the bytes-read counters report."""

    name: str
    suffix: str  # tile filename suffix (codec-specific: mixed dirs can't alias)
    # Whether the *stored* form can ship to a device and decode there (the
    # stream-GEMM kernel path): true for raw (stored == decoded) and bf16
    # (uint16 bit patterns, widened in-kernel); false for zstd (compressed
    # byte streams must decompress on the host).
    device_decodable = False

    def encode(self, block: np.ndarray):
        raise NotImplementedError

    def decode(self, stored, tile_rows: int, dtype: np.dtype) -> np.ndarray:
        raise NotImplementedError

    def stored_nbytes(self, stored) -> int:
        return len(stored) if isinstance(stored, (bytes, bytearray)) else stored.nbytes


class RawCodec(TileCodec):
    """Tiles stored verbatim (.npy, mmap-able).  Bitwise round-trip."""

    name, suffix = "raw", ".npy"
    device_decodable = True  # stored form IS the decoded form

    def encode(self, block: np.ndarray) -> np.ndarray:
        return block

    def decode(self, stored, tile_rows: int, dtype: np.dtype) -> np.ndarray:
        return np.asarray(stored)


class Bf16Codec(TileCodec):
    """fp32 tiles stored as bf16 bit patterns (uint16 .npy): half the bytes.

    Accuracy contract: decode(encode(x)) == bf16-round(x) -- a one-time
    relative error <= 2^-8 ~= 4e-3 applied at write time; everything computed
    *from* the stored tiles is exact with respect to the rounded values.
    float32 stores only: silently squeezing a wider dtype through an 8-bit
    mantissa would break the store's errors-loudly contract
    (:class:`TileStore` rejects the combination at construction)."""

    name, suffix = "bf16", ".npy"
    device_decodable = True  # uint16 bit patterns widen in-kernel

    def encode(self, block: np.ndarray) -> np.ndarray:
        return _f32_to_bf16_u16(block)

    def decode(self, stored, tile_rows: int, dtype: np.dtype) -> np.ndarray:
        u = np.asarray(stored)
        if u.dtype != np.uint16:
            raise ValueError(f"bf16 tile stored as {u.dtype}, want uint16")
        return _bf16_u16_to_f32(u).astype(dtype, copy=False)


class ZstdCodec(TileCodec):
    """Tiles zstd-compressed (lossless; raw C-order buffer per tile).

    The backend (``zstandard`` or ``zstd``) is an optional import --
    :func:`resolve_codec` falls back to ``raw`` with a warning when neither is
    installed, and opening an existing zstd store without a backend raises."""

    name, suffix = "zstd", ".zst"

    def __init__(self):
        self._z = _zstd_backend()
        if self._z is None:
            raise ImportError(
                "zstd codec requires the 'zstandard' (or 'zstd') package; "
                "install one or use codec='raw'/'bf16'"
            )
        # zstandard contexts are reusable (the documented fast path) but not
        # safe under concurrent calls, and decode runs in prefetch threads --
        # several at once when a GEMM streams two operands.  Thread-locals
        # give each thread one long-lived compressor/decompressor pair.
        self._local = threading.local()

    def _ctxs(self):
        if not hasattr(self._local, "comp"):
            if hasattr(self._z, "ZstdCompressor"):  # zstandard
                self._local.comp = self._z.ZstdCompressor()
                self._local.decomp = self._z.ZstdDecompressor()
            else:  # the 'zstd' module is plain functions
                self._local.comp = self._local.decomp = None
        return self._local.comp, self._local.decomp

    def encode(self, block: np.ndarray) -> bytes:
        buf = np.ascontiguousarray(block).tobytes()
        comp, _ = self._ctxs()
        return comp.compress(buf) if comp is not None else self._z.compress(buf)

    def decode(self, stored, tile_rows: int, dtype: np.dtype) -> np.ndarray:
        _, decomp = self._ctxs()
        if decomp is not None:
            buf = decomp.decompress(bytes(stored))
        else:
            buf = self._z.decompress(bytes(stored))
        want = tile_rows * tile_rows * dtype.itemsize
        if len(buf) != want:
            raise ValueError(f"zstd tile decompressed to {len(buf)} bytes, want {want}")
        return np.frombuffer(buf, dtype=dtype).reshape(tile_rows, tile_rows)


CODECS = ("raw", "bf16", "zstd")


def resolve_codec(name: str, *, fallback: bool = True) -> TileCodec:
    """Codec instance for ``name``.

    ``fallback=True`` (writer path) degrades a backend-less ``zstd`` request
    to ``raw`` with a warning, so zstd-less environments run cleanly;
    ``fallback=False`` (reader path) raises instead -- an existing zstd store
    cannot be silently reinterpreted.
    """
    if name == "raw":
        return RawCodec()
    if name == "bf16":
        return Bf16Codec()
    if name == "zstd":
        try:
            return ZstdCodec()
        except ImportError:
            if not fallback:
                raise
            warnings.warn(
                "zstd backend not installed; falling back to codec='raw' "
                "(install 'zstandard' for compressed tiles)",
                stacklevel=3,
            )
            return RawCodec()
    raise ValueError(f"unknown tile codec {name!r}; want one of {CODECS}")


@dataclass
class StoreManifest:
    """Static geometry of every snapshot in the store + the committed order.

    ``meta`` is a caller-supplied content fingerprint (dataset name, seed,
    generator params ...).  Re-creating a store whose geometry matches but
    whose meta differs is rejected -- without it, a resumed write would
    silently skip committed ids and serve stale snapshots from a previous,
    differently-parameterized run.  ``codec`` names the storage encoding of
    every tile in the directory and is part of the same fingerprint: one
    store, one codec -- mixed-codec dirs error loudly.
    """

    n: int
    grid: int  # tiles per side; tile shape is (n/grid, n/grid)
    dtype: str
    codec: str = "raw"
    snapshots: list[str] = field(default_factory=list)
    meta: dict = field(default_factory=dict)
    version: int = _FORMAT_VERSION

    def __post_init__(self):
        if self.n < 1 or self.grid < 1:
            raise ValueError(f"need n >= 1 and grid >= 1, got n={self.n} grid={self.grid}")
        if self.n % self.grid:
            raise ValueError(f"grid {self.grid} must divide n={self.n}")

    @property
    def tile_rows(self) -> int:
        return self.n // self.grid

    def to_json(self) -> str:
        return json.dumps(
            {
                "version": self.version,
                "n": self.n,
                "grid": self.grid,
                "dtype": self.dtype,
                "codec": self.codec,
                "snapshots": list(self.snapshots),
                "meta": dict(self.meta),
            },
            indent=2,
        )

    @classmethod
    def from_json(cls, text: str) -> "StoreManifest":
        d = json.loads(text)
        if d.get("version", 0) > _FORMAT_VERSION:
            raise ValueError(f"store format v{d['version']} is newer than this reader")
        return cls(
            n=int(d["n"]),
            grid=int(d["grid"]),
            dtype=str(d["dtype"]),
            codec=str(d.get("codec", "raw")),  # pre-codec manifests are raw
            snapshots=[str(s) for s in d.get("snapshots", [])],
            meta=dict(d.get("meta", {})),
            version=int(d.get("version", _FORMAT_VERSION)),
        )


class TileStore:
    """A sequence of dense n x n snapshots, tiled grid x grid, RAM- or disk-backed.

    Use :meth:`create` / :meth:`open` rather than the constructor::

        store = TileStore.create(dir_or_none, n=1024, grid=8)
        store.put_snapshot("t000", a)                 # tile an in-memory array
        with store.writer("t001") as w:               # or tile-at-a-time
            for r, c in w.missing_tiles():
                w.put_tile(r, c, make_block(r, c))
        for snap in store.iter_snapshots():           # SnapshotHandles, in order
            det.push(snap)

    ``root=None`` selects the host-RAM backend (same API, dict of arrays) --
    useful for tests and for machines where host DRAM, not disk, is the
    capacity tier.
    """

    def __init__(self, manifest: StoreManifest, root: str | Path | None):
        self.manifest = manifest
        self.root = Path(root) if root is not None else None
        self._ram: dict[tuple[str, int, int], np.ndarray] = {}
        # Readers must not reinterpret existing tiles: no fallback here.
        self.codec = resolve_codec(manifest.codec, fallback=False)
        if self.codec.name == "bf16" and np.dtype(manifest.dtype) != np.float32:
            raise ValueError(
                f"bf16 codec stores float32 tiles only, not {manifest.dtype} "
                "(an 8-bit mantissa would silently destroy wider precision); "
                "use codec='raw' or 'zstd'"
            )

    # -- lifecycle -----------------------------------------------------------

    @classmethod
    def create(
        cls,
        root: str | Path | None,
        *,
        n: int,
        grid: int,
        dtype="float32",
        codec: str = "raw",
        meta: dict | None = None,
    ) -> "TileStore":
        """New store at ``root`` (made if missing); ``root=None`` = RAM-backed.

        ``meta`` fingerprints the content (dataset, seed, params).  Resuming
        an existing store requires matching geometry AND matching meta, so
        committed snapshots from a differently-parameterized run can't be
        silently served as this run's data.  ``codec`` selects the tile
        storage encoding (``raw`` / ``bf16`` / ``zstd``); it joins the
        geometry fingerprint, so resuming under a different codec errors
        rather than mixing encodings in one directory (a backend-less
        ``zstd`` request falls back to ``raw`` with a warning *before* the
        fingerprint is formed, so the manifest always records what the tiles
        actually are).
        """
        codec_name = resolve_codec(codec).name  # fallback resolves pre-fingerprint
        manifest = StoreManifest(
            n=n, grid=grid, dtype=np.dtype(dtype).name, codec=codec_name,
            meta=dict(meta or {}),
        )
        store = cls(manifest, root)
        if store.root is not None:
            store.root.mkdir(parents=True, exist_ok=True)
            existing = store.root / MANIFEST_NAME
            if existing.exists():
                old = StoreManifest.from_json(existing.read_text())
                if (old.n, old.grid, old.dtype, old.codec) != (
                    n, grid, manifest.dtype, codec_name,
                ):
                    raise ValueError(
                        f"store at {root} already exists with incompatible geometry "
                        f"(n={old.n} grid={old.grid} dtype={old.dtype} "
                        f"codec={old.codec}, requested codec={codec_name})"
                    )
                if meta is not None and old.meta != manifest.meta:
                    # Adopting a meta is only safe while nothing is committed:
                    # an unlabeled store with snapshots could be anything, and
                    # resuming it under a fresh label would serve stale data.
                    if old.meta or old.snapshots:
                        raise ValueError(
                            f"store at {root} holds different content: manifest meta "
                            f"{old.meta or '<unlabeled, has snapshots>'} != requested "
                            f"{manifest.meta}; use a fresh directory (or delete the "
                            "stale store)"
                        )
                store.manifest = old  # resume: keep committed snapshots
                if meta is not None and old.meta != manifest.meta:
                    store.manifest.meta = manifest.meta
                    store._write_manifest()
            else:
                store._write_manifest()
        return store

    @classmethod
    def open(cls, root: str | Path) -> "TileStore":
        root = Path(root)
        manifest = StoreManifest.from_json((root / MANIFEST_NAME).read_text())
        return cls(manifest, root)

    def _write_manifest(self) -> None:
        if self.root is None:
            return
        tmp = self.root / (MANIFEST_NAME + ".tmp")
        tmp.write_text(self.manifest.to_json())
        os.replace(tmp, self.root / MANIFEST_NAME)

    def _refresh_manifest(self) -> None:
        """Re-read the on-disk snapshot list before a manifest mutation.

        Several TileStore instances may share one directory over time (e.g.
        each out-of-core chain build opens the scratch dir anew while earlier
        builds' operators are still live); mutations must read-modify-write
        the current file state or a stale instance would clobber snapshots
        committed after it opened.
        """
        if self.root is None:
            return
        path = self.root / MANIFEST_NAME
        if path.exists():
            self.manifest.snapshots = StoreManifest.from_json(path.read_text()).snapshots

    # -- geometry ------------------------------------------------------------

    @property
    def n(self) -> int:
        return self.manifest.n

    @property
    def grid(self) -> int:
        return self.manifest.grid

    @property
    def dtype(self) -> np.dtype:
        return np.dtype(self.manifest.dtype)

    @property
    def tile_rows(self) -> int:
        return self.manifest.tile_rows

    @property
    def snapshot_nbytes(self) -> int:
        return self.n * self.n * self.dtype.itemsize

    @property
    def snapshot_ids(self) -> list[str]:
        return list(self.manifest.snapshots)

    def __len__(self) -> int:
        return len(self.manifest.snapshots)

    # -- tile I/O ------------------------------------------------------------

    def _tile_path(self, snap_id: str, r: int, c: int) -> Path:
        assert self.root is not None
        return self.root / snap_id / f"tile_{r:04d}_{c:04d}{self.codec.suffix}"

    def has_tile(self, snap_id: str, r: int, c: int) -> bool:
        if self.root is None:
            return (snap_id, r, c) in self._ram
        return self._tile_path(snap_id, r, c).exists()

    def _load_stored(self, snap_id: str, r: int, c: int, *, mmap: bool = True):
        """The stored (encoded) form of one tile: ndarray or bytes."""
        if self.root is None:
            return self._ram[(snap_id, r, c)]
        path = self._tile_path(snap_id, r, c)
        if self.codec.suffix == ".npy":
            return np.load(path, mmap_mode="r" if mmap else None)
        return path.read_bytes()

    def read_tile(self, snap_id: str, r: int, c: int, *, mmap: bool = True) -> np.ndarray:
        """One (tile_rows, tile_rows) dense *decoded* tile.

        Disk tiles of .npy-backed codecs are memmapped before decode; decode
        runs wherever the caller runs -- the streaming executors call this
        from the PanelPipeline prefetch thread, so decompression overlaps
        device compute.
        """
        g = self.grid
        if not (0 <= r < g and 0 <= c < g):
            raise IndexError(f"tile ({r}, {c}) outside {g}x{g} grid")
        tr = self.tile_rows
        arr = self.codec.decode(
            self._load_stored(snap_id, r, c, mmap=mmap), tr, self.dtype
        )
        if arr.shape != (tr, tr) or arr.dtype != self.dtype:
            raise ValueError(
                f"tile ({r}, {c}) of {snap_id!r} decodes to {arr.shape}/{arr.dtype}, "
                f"manifest says ({tr}, {tr})/{self.dtype}"
            )
        return arr

    def read_tile_stored(self, snap_id: str, r: int, c: int) -> np.ndarray:
        """One tile in its *stored* (encoded) form, for on-device decode.

        Only meaningful for device-decodable codecs (raw: the fp32 tile
        itself; bf16: the (tile_rows, tile_rows) uint16 bit-pattern array the
        stream-GEMM kernel widens in VMEM).  Compressed codecs have no
        device-decodable stored form and raise.
        """
        if not getattr(self.codec, "device_decodable", False):
            raise ValueError(
                f"codec {self.codec.name!r} has no device-decodable stored form; "
                "read_tile decodes on the host instead"
            )
        g = self.grid
        if not (0 <= r < g and 0 <= c < g):
            raise IndexError(f"tile ({r}, {c}) outside {g}x{g} grid")
        arr = np.asarray(self._load_stored(snap_id, r, c))
        tr = self.tile_rows
        if arr.shape != (tr, tr):
            raise ValueError(
                f"tile ({r}, {c}) of {snap_id!r} stored as {arr.shape}, "
                f"manifest says ({tr}, {tr})"
            )
        return arr

    def tile_nbytes_stored(self, snap_id: str, r: int, c: int) -> int:
        """Bytes the backing tier holds for one tile (pre-decode)."""
        if self.root is None:
            return self.codec.stored_nbytes(self._ram[(snap_id, r, c)])
        path = self._tile_path(snap_id, r, c)
        # .npy files carry a small header; the payload size is what matters
        # for bandwidth accounting, so use the file size as-is.
        return path.stat().st_size

    def _store_tile(self, snap_id: str, r: int, c: int, block: np.ndarray) -> None:
        tr = self.tile_rows
        block = np.ascontiguousarray(np.asarray(block, dtype=self.dtype))
        if block.shape != (tr, tr):
            raise ValueError(f"tile ({r}, {c}) has shape {block.shape}, want ({tr}, {tr})")
        stored = self.codec.encode(block)
        if self.root is None:
            # Always copy ndarray-encoded tiles: raw encode passes the caller's
            # array through, and a stored view would track later caller
            # mutation instead of the put-time snapshot.
            self._ram[(snap_id, r, c)] = (
                stored if isinstance(stored, bytes) else np.array(stored, copy=True)
            )
            return
        path = self._tile_path(snap_id, r, c)
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(path.name + ".tmp")
        with open(tmp, "wb") as f:
            if isinstance(stored, bytes):
                f.write(stored)
            else:
                np.save(f, stored)
        os.replace(tmp, path)  # atomic: a crash leaves either old or new, never torn

    # -- writers -------------------------------------------------------------

    def writer(self, snap_id: str) -> "SnapshotWriter":
        if "/" in snap_id or snap_id in ("", ".", ".."):
            raise ValueError(f"bad snapshot id {snap_id!r}")
        return SnapshotWriter(self, snap_id)

    def put_snapshot(self, snap_id: str, a) -> "SnapshotHandle":
        """Tile an in-memory (n, n) array into the store and commit it."""
        a = np.asarray(a)
        if a.shape != (self.n, self.n):
            raise ValueError(f"snapshot is {a.shape}, store holds ({self.n}, {self.n})")
        tr = self.tile_rows
        with self.writer(snap_id) as w:
            for r, c in w.missing_tiles():
                w.put_tile(r, c, a[r * tr : (r + 1) * tr, c * tr : (c + 1) * tr])
        return self.snapshot(snap_id)

    def put_snapshot_tiles(
        self, snap_id: str, tile_fn: Callable[[np.ndarray, np.ndarray], np.ndarray]
    ) -> "SnapshotHandle":
        """Out-of-core write: ``tile_fn(global_rows, global_cols) -> block``.

        The n x n snapshot is never materialized -- each tile is produced and
        written independently, so arbitrarily large graphs can be laid down
        from a (small) node-feature table.  Already-present tiles are skipped
        (resume after a partial write).
        """
        tr = self.tile_rows
        with self.writer(snap_id) as w:
            for r, c in w.missing_tiles():
                rows = np.arange(r * tr, (r + 1) * tr)
                cols = np.arange(c * tr, (c + 1) * tr)
                w.put_tile(r, c, tile_fn(rows, cols))
        return self.snapshot(snap_id)

    def _commit(self, snap_id: str) -> None:
        self._refresh_manifest()
        if snap_id not in self.manifest.snapshots:
            self.manifest.snapshots.append(snap_id)
            self._write_manifest()

    def remove_snapshot(self, snap_id: str) -> None:
        """Drop a snapshot's tiles (and its manifest entry, if committed).

        This is how out-of-core *working* matrices (the chain's S / T / P
        intermediates) are retired as soon as the recurrence no longer needs
        them, bounding scratch capacity by the live working set.  Removing an
        uncommitted (partially written) snapshot is allowed and cleans up its
        tiles.  The manifest entry goes first, the tiles second: a crash in
        between leaves only harmless orphan tiles, never a committed id whose
        tiles are gone (the "committed == complete" invariant).
        """
        if "/" in snap_id or snap_id in ("", ".", ".."):
            raise ValueError(f"bad snapshot id {snap_id!r}")
        self._refresh_manifest()
        if snap_id in self.manifest.snapshots:
            self.manifest.snapshots.remove(snap_id)
            self._write_manifest()
        if self.root is None:
            for key in [k for k in self._ram if k[0] == snap_id]:
                del self._ram[key]
        else:
            snap_dir = self.root / snap_id
            if snap_dir.exists():
                shutil.rmtree(snap_dir)

    # -- readers -------------------------------------------------------------

    def snapshot(self, snap_id: str) -> "SnapshotHandle":
        if snap_id not in self.manifest.snapshots:
            raise KeyError(f"snapshot {snap_id!r} not committed; have {self.manifest.snapshots}")
        return SnapshotHandle(self, snap_id)

    def iter_snapshots(self) -> Iterator["SnapshotHandle"]:
        """Handles in committed (sequence) order -- feed to SequenceDetector.run."""
        for sid in self.manifest.snapshots:
            yield SnapshotHandle(self, sid)


class SnapshotWriter:
    """Tile-at-a-time writer with commit-on-complete (context manager).

    ``missing_tiles()`` drives resumable writes: after a crash mid-snapshot,
    re-running the same writer recomputes only the absent tiles.  ``commit()``
    (called on clean ``with``-exit) appends the id to the manifest once every
    tile is present, and raises if any are still missing.
    """

    def __init__(self, store: TileStore, snap_id: str):
        self.store = store
        self.snap_id = snap_id

    def missing_tiles(self) -> list[tuple[int, int]]:
        g = self.store.grid
        return [
            (r, c)
            for r in range(g)
            for c in range(g)
            if not self.store.has_tile(self.snap_id, r, c)
        ]

    def put_tile(self, r: int, c: int, block: np.ndarray) -> None:
        self.store._store_tile(self.snap_id, r, c, block)

    def put_row_panel(self, row0: int, panel: np.ndarray) -> None:
        """Write a full-width (height, n) row panel as its constituent tiles.

        The streaming producers (out-of-core chain GEMMs, panel transforms)
        emit full-width row panels; this slices them back into the store's
        tile grid.  ``row0`` and the panel height must be tile-aligned.
        """
        tr = self.store.tile_rows
        n = self.store.n
        panel = np.asarray(panel)
        if panel.ndim != 2 or panel.shape[1] != n:
            raise ValueError(f"row panel must be (height, {n}), got {panel.shape}")
        if row0 % tr or panel.shape[0] % tr:
            raise ValueError(
                f"panel [{row0}:{row0 + panel.shape[0]}] not tile-aligned (tile={tr})"
            )
        r_lo = row0 // tr
        for i in range(panel.shape[0] // tr):
            for c in range(self.store.grid):
                self.put_tile(
                    r_lo + i, c, panel[i * tr : (i + 1) * tr, c * tr : (c + 1) * tr]
                )

    def commit(self) -> None:
        missing = self.missing_tiles()
        if missing:
            raise ValueError(
                f"snapshot {self.snap_id!r} incomplete: {len(missing)} tiles missing "
                f"(first: {missing[0]})"
            )
        self.store._commit(self.snap_id)

    def __enter__(self) -> "SnapshotWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.commit()


@dataclass(frozen=True)
class SnapshotHandle:
    """Store-backed stand-in for a resident (n, n) adjacency ``jax.Array``.

    Satisfies the streaming protocol the core duck-types on
    (:func:`repro.core.tiles.is_streamable`): ``shape``, ``dtype``,
    ``panel_rows`` and ``read_panel``.  Panels are assembled on the host from
    the snapshot's tile row (memmap reads), bounded by one panel of host RAM.
    """

    store: TileStore
    snap_id: str

    @property
    def shape(self) -> tuple[int, int]:
        return (self.store.n, self.store.n)

    @property
    def dtype(self) -> np.dtype:
        return self.store.dtype

    @property
    def nbytes(self) -> int:
        return self.store.snapshot_nbytes

    @property
    def panel_rows(self) -> int:
        """Preferred streaming unit: one tile row (full-width panel)."""
        return self.store.tile_rows

    def read_panel(self, row0: int, height: int) -> np.ndarray:
        """The (height, n) row panel starting at global row ``row0``."""
        tr = self.store.tile_rows
        if row0 % tr or height % tr:
            raise ValueError(f"panel [{row0}:{row0 + height}] not tile-aligned (tile={tr})")
        r_lo, r_hi = row0 // tr, (row0 + height) // tr
        g = self.store.grid
        rows = [
            np.concatenate(
                [self.store.read_tile(self.snap_id, r, c) for c in range(g)], axis=1
            )
            if g > 1
            else np.asarray(self.store.read_tile(self.snap_id, r, 0))
            for r in range(r_lo, r_hi)
        ]
        return rows[0] if len(rows) == 1 else np.concatenate(rows, axis=0)

    def read_panel_info(self, row0: int, height: int) -> tuple[np.ndarray, int]:
        """``(panel, stored_nbytes)``: the decoded panel plus the pre-decode
        bytes the backing tier served for it -- the pair the streaming
        pipeline's bytes-read / bytes-decoded counters are built from."""
        panel = self.read_panel(row0, height)
        tr = self.store.tile_rows
        g = self.store.grid
        stored = sum(
            self.store.tile_nbytes_stored(self.snap_id, r, c)
            for r in range(row0 // tr, (row0 + height) // tr)
            for c in range(g)
        )
        return panel, stored

    def read_panel_encoded_info(
        self, row0: int, height: int
    ) -> tuple[np.ndarray, int, int]:
        """``(panel, stored_nbytes, decoded_nbytes)`` with the panel in a
        *device-decodable stored form* (the stream-GEMM kernel path).

        For the bf16 codec the panel is the raw uint16 bit patterns -- half
        the decoded bytes; the H2D transfer ships the stored width and the
        kernel widens in VMEM.  Codecs whose stored form is already decoded
        (raw) or not device-decodable at all (zstd) fall back to the decoded
        read, with ``decoded_nbytes == panel.nbytes`` (nothing saved).
        """
        store = self.store
        if store.codec.name != "bf16":
            panel, stored = self.read_panel_info(row0, height)
            return panel, stored, panel.nbytes
        tr = store.tile_rows
        if row0 % tr or height % tr:
            raise ValueError(
                f"panel [{row0}:{row0 + height}] not tile-aligned (tile={tr})"
            )
        r_lo, r_hi = row0 // tr, (row0 + height) // tr
        g = store.grid
        rows = [
            np.concatenate(
                [store.read_tile_stored(self.snap_id, r, c) for c in range(g)], axis=1
            )
            if g > 1
            else np.asarray(store.read_tile_stored(self.snap_id, r, 0))
            for r in range(r_lo, r_hi)
        ]
        panel = rows[0] if len(rows) == 1 else np.concatenate(rows, axis=0)
        stored = sum(
            store.tile_nbytes_stored(self.snap_id, r, c)
            for r in range(r_lo, r_hi)
            for c in range(g)
        )
        decoded = panel.size * store.dtype.itemsize  # what a host decode would ship
        return panel, stored, decoded

    def to_numpy(self) -> np.ndarray:
        """Gather the whole snapshot (tests / small graphs only)."""
        return np.asarray(self.read_panel(0, self.store.n))
