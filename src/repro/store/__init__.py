"""Out-of-core snapshot store: tiled dense adjacencies on host RAM or disk.

Public API re-exports.
"""

from repro.store.tilestore import (
    MANIFEST_NAME,
    SnapshotHandle,
    SnapshotWriter,
    StoreManifest,
    TileStore,
)

__all__ = [
    "MANIFEST_NAME",
    "SnapshotHandle",
    "SnapshotWriter",
    "StoreManifest",
    "TileStore",
]
