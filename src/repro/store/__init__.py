"""Out-of-core snapshot store: tiled dense adjacencies on host RAM or disk.

Public API re-exports.
"""

from repro.store.embstore import (
    EMB_CODECS,
    EmbeddingHandle,
    EmbeddingStore,
    EmbManifest,
)
from repro.store.pipeline import (
    DEFAULT_PREFETCH_DEPTH,
    CachingHandle,
    PanelPipeline,
    fetch_panel_encoded_info,
    fetch_panel_info,
)
from repro.store.tilestore import (
    CODECS,
    MANIFEST_NAME,
    SnapshotHandle,
    SnapshotWriter,
    StoreManifest,
    TileCodec,
    TileStore,
    resolve_codec,
)

__all__ = [
    "CODECS",
    "CachingHandle",
    "DEFAULT_PREFETCH_DEPTH",
    "EMB_CODECS",
    "EmbManifest",
    "EmbeddingHandle",
    "EmbeddingStore",
    "MANIFEST_NAME",
    "PanelPipeline",
    "SnapshotHandle",
    "SnapshotWriter",
    "StoreManifest",
    "TileCodec",
    "TileStore",
    "fetch_panel_encoded_info",
    "fetch_panel_info",
    "resolve_codec",
]
