"""Unified panel I/O pipeline: all host->device staging for streamed panels.

Every out-of-core consumer in the core used to own a slice of this logic --
``tile_stream`` hand-rolled a depth-2 double buffer, the oochain GEMM fetched
panels sequentially with no prefetch at all, and the fuse_l chain build did
its own ``device_put`` loop.  :class:`PanelPipeline` owns the pattern once:

* a **background prefetch thread** walks the requested row-panel origins,
  fetching (and codec-decoding -- see :mod:`repro.store.tilestore`) each
  streamed operand's panel on the host, so disk reads and decompression
  overlap device compute;
* **per-operand ring buffers** of configurable depth (default
  ``DEFAULT_PREFETCH_DEPTH`` = 2) bound host staging and give backpressure --
  a slow consumer can never be buried under prefetched panels;
* the consumer-side iterator **stages panels onto devices one origin ahead**
  (the ``device_put`` of panel t+1 is issued before compute on panel t is
  dispatched), preserving the two-panels-per-operand device residency bound
  the streaming executors advertise regardless of the host-side depth;
* **cancellation on early exit**: closing the pipeline (or breaking out of
  the iterator) stops the producer promptly and releases the rings;
* **stats integration**: panels, H2D bytes and peak live device bytes are
  accounted exactly as the old double buffer did, plus the pre-/post-codec
  ``bytes_read`` / ``bytes_decoded`` pair, so ``stream_stats()`` tracks real
  backing-tier traffic.  All counter mutation goes through the stats
  object's atomic ``add`` (registry-backed, see
  :mod:`repro.obs.metrics`), so concurrent producers and a mid-run
  ``reset_stream_stats()`` can no longer lose updates;
* **observability**: the producer accumulates ``pipeline.producer_fetch_seconds``
  and the consumer ``pipeline.consumer_wait_seconds`` in the process metrics
  registry (their ratio is the prefetch-efficiency signal that says whether
  ``depth`` is right), and with tracing enabled each fetched panel carries a
  cross-thread span -- opened on the prefetch thread when the fetch starts,
  closed when the consumer pops it, rendered on the producer's track;
* **encoded shipping** (``encoded=True``, the stream-GEMM kernel path):
  panels of device-decodable codecs travel in their *stored* form -- bf16
  tiles as raw uint16 bit patterns, half the decoded bytes over H2D, widened
  to fp32 inside the kernel -- with the transfer gap accounted in
  ``bytes_h2d_saved``.  Sources without an encoded read degrade to the
  decoded panel (nothing saved, nothing broken);
* **pinned-host staging** where the backend supports it: staged panels hop
  through the ``pinned_host`` memory space so the H2D copy is an async DMA
  from pinned memory instead of a pageable-numpy transfer.  Probed once per
  pipeline; backends without a pinned memory space (CPU) silently keep the
  pageable path (``pipeline.pinned`` says which one is active).

Resident ``jax.Array`` operands are *not* routed through the thread: slicing
them is a device-side operation and jax dispatch stays on the consumer
thread.  The producer touches only host objects (numpy, files, codecs).

:class:`CachingHandle` is the iteration-batching companion: it wraps a
snapshot handle with a host-RAM panel cache so a consumer that re-streams the
same matrix (the Richardson solver re-reading P2 every iteration) hits the
backing store once per batch instead of once per pass -- replayed panels are
bitwise identical and report zero ``bytes_read``.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Iterator, Sequence

import numpy as np

from repro.obs import trace as obs_trace
from repro.obs.metrics import REGISTRY as _OBS_REGISTRY

DEFAULT_PREFETCH_DEPTH = 2


def _is_handle(x) -> bool:
    """Streamable snapshot handle (duck-typed, mirrors tiles.is_streamable)."""
    return hasattr(x, "read_panel") and hasattr(x, "panel_rows")


def fetch_panel_info(source, row0: int, height: int) -> tuple[np.ndarray, int]:
    """``(host_panel, stored_nbytes)`` for any panel source.

    Handles report their true pre-decode byte count via ``read_panel_info``
    (zero on a :class:`CachingHandle` hit); plain arrays fall back to the
    panel's own size.
    """
    if hasattr(source, "read_panel_info"):
        panel, stored = source.read_panel_info(row0, height)
        return np.asarray(panel), int(stored)
    if _is_handle(source):
        panel = np.asarray(source.read_panel(row0, height))
        return panel, panel.nbytes
    panel = np.asarray(source[row0 : row0 + height])
    return panel, panel.nbytes


def fetch_panel_encoded_info(
    source, row0: int, height: int
) -> tuple[np.ndarray, int, int]:
    """``(panel, stored_nbytes, decoded_nbytes)`` with the panel in its
    device-decodable stored form where the source supports it.

    The stream-GEMM kernel path: a bf16-codec handle returns raw uint16 bit
    patterns (half the decoded bytes; the kernel widens on-device) and
    ``decoded_nbytes`` records what a host-decoded transfer would have
    shipped.  Sources without encoded reads fall back to the decoded panel
    with ``decoded_nbytes == panel.nbytes`` -- nothing saved, same contract.
    """
    if hasattr(source, "read_panel_encoded_info"):
        panel, stored, decoded = source.read_panel_encoded_info(row0, height)
        return np.asarray(panel), int(stored), int(decoded)
    panel, stored = fetch_panel_info(source, row0, height)
    return panel, stored, panel.nbytes


class _Ring:
    """Bounded single-producer/single-consumer ring buffer (one per operand)."""

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"ring depth must be >= 1, got {depth}")
        self.depth = depth
        self._buf: deque = deque()
        self._cv = threading.Condition()
        self._closed = False

    def put(self, item) -> bool:
        """Block until a slot frees; False once the ring is closed."""
        with self._cv:
            while len(self._buf) >= self.depth and not self._closed:
                self._cv.wait()
            if self._closed:
                return False
            self._buf.append(item)
            self._cv.notify_all()
            return True

    def get(self):
        """Next item, blocking; None once closed (drained items still served)."""
        with self._cv:
            while not self._buf and not self._closed:
                self._cv.wait()
            if self._buf:
                item = self._buf.popleft()
                self._cv.notify_all()
                return item
            return None

    def close(self, *, drain: bool = False) -> None:
        """Stop accepting puts.  ``drain=True`` (producer-error path) keeps
        already-buffered panels poppable, so the consumer still receives
        everything fetched before the fault; ``drain=False`` (consumer
        cancellation) discards them -- nobody will pop."""
        with self._cv:
            self._closed = True
            if not drain:
                self._buf.clear()
            self._cv.notify_all()


class PanelPipeline:
    """Prefetching iterator over row panels of one or more operands.

    Yields ``(row0, panels)`` per origin, in origin order, where ``panels``
    holds one entry per operand.  Operands satisfying the snapshot-handle
    protocol are fetched (and decoded) in the background thread; anything
    else (resident ``jax.Array`` / host array) is sliced lazily on the
    consumer thread, keeping all jax dispatch off the producer.

    ``sharding=None`` yields host panels (the out-of-core GEMM wants the left
    panel on the host for block slicing); with a sharding, each streamed
    panel is ``device_put`` one origin ahead of consumption and the H2D /
    residency counters on ``stats`` are updated exactly as the retired
    double-buffer did.

    ``encoded=True`` ships streamed panels in their device-decodable stored
    form (bf16 -> uint16 bit patterns; see :func:`fetch_panel_encoded_info`)
    for on-device decode by the stream-GEMM kernels; the decoded-vs-stored
    transfer gap is accounted in ``stats.bytes_h2d_saved``.  ``pin`` controls
    pinned-host staging of device-bound panels (None = auto: on where the
    backend has a ``pinned_host`` memory space, silently off elsewhere).

    Use as a context manager (or call :meth:`close`) so an early exit --
    consumer exception, solver convergence, test breakage -- cancels the
    producer instead of leaving it blocked on a full ring.
    """

    def __init__(
        self,
        sources: Sequence,
        origins: Sequence[int],
        height: int,
        *,
        depth: int | None = None,
        sharding=None,
        stats=None,
        device_put=None,
        encoded: bool = False,
        pin: bool | None = None,
    ):
        self.sources = list(sources)
        self.origins = list(origins)
        self.height = int(height)
        self.depth = DEFAULT_PREFETCH_DEPTH if depth is None else int(depth)
        if self.depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {self.depth}")
        self.sharding = sharding
        self.stats = stats
        self._device_put = device_put
        self.encoded = bool(encoded)
        self._pin_want = pin is None or bool(pin)  # None/True: try; False: never
        self.pinned = False  # True once pinned staging is probed and active
        self._pinned_sharding = None
        self._pin_probed = False
        self._threaded = [_is_handle(s) for s in self.sources]
        self._rings = [
            _Ring(self.depth) if threaded else None for threaded in self._threaded
        ]
        self._cancel = threading.Event()
        self._error: BaseException | None = None
        self._thread: threading.Thread | None = None
        self.device_live_bytes = 0  # executor-owned panel bytes currently staged
        if any(self._threaded) and self.origins:
            self._thread = threading.Thread(
                target=self._produce, name="panel-prefetch", daemon=True
            )
            self._thread.start()

    # -- producer (background thread: host I/O + codec decode only) ----------

    def _produce(self) -> None:
        try:
            for row0 in self.origins:
                for i, (src, ring) in enumerate(zip(self.sources, self._rings)):
                    if ring is None:
                        continue
                    if self._cancel.is_set():
                        return
                    # Cross-thread span: opened here (producer tid), closed by
                    # the consumer when it pops the panel -- the trace shows
                    # each panel's fetch-to-consumption lifetime on this track.
                    sp = obs_trace.begin("prefetch.panel", row0=row0, operand=i)
                    t_f0 = time.perf_counter()
                    if self.encoded:
                        panel, stored, decoded = fetch_panel_encoded_info(
                            src, row0, self.height
                        )
                    else:
                        panel, stored = fetch_panel_info(src, row0, self.height)
                        decoded = panel.nbytes
                    _OBS_REGISTRY.add_named(
                        {
                            "pipeline.producer_fetch_seconds": (
                                time.perf_counter() - t_f0
                            ),
                            "pipeline.panels_fetched": 1.0,
                        }
                    )
                    if self.stats is not None and stored:
                        # stored == 0 means a host-RAM replay (CachingHandle
                        # hit): no backing-tier read, no decode performed.
                        # Encoded panels skip the host decode entirely: the
                        # prefetch thread produced the stored form, which is
                        # exactly panel.nbytes either way.
                        self.stats.add(bytes_read=stored, bytes_decoded=panel.nbytes)
                    if not ring.put((panel, decoded, sp)):
                        obs_trace.end(sp, cancelled=True)
                        return  # closed under us: cancelled
        except BaseException as e:  # propagate to the consumer, then stop
            self._error = e
            self._cancel.set()
            for ring in self._rings:
                if ring is not None:
                    ring.close(drain=True)  # serve what was fetched pre-fault

    # -- consumer ------------------------------------------------------------

    def _next_host_bundle(self, row0: int) -> tuple[list, list]:
        """Panels (+ decoded-byte metadata) for one origin: ring pops for
        handles, lazy slices (decoded == None) for everything else."""
        bundle, decs = [], []
        for src, ring in zip(self.sources, self._rings):
            if ring is None:
                bundle.append(src[row0 : row0 + self.height])
                decs.append(None)
                continue
            t_w0 = time.perf_counter()
            item = ring.get()
            _OBS_REGISTRY.add_named(
                {
                    "pipeline.consumer_wait_seconds": time.perf_counter() - t_w0,
                    "pipeline.consumer_waits": 1.0,
                }
            )
            if item is None:
                if self._error is not None:
                    raise RuntimeError(
                        f"panel prefetch failed at row {row0}"
                    ) from self._error
                raise RuntimeError("panel pipeline closed while panels were pending")
            panel, decoded, sp = item
            obs_trace.end(sp)  # closes the producer-side prefetch.panel span
            bundle.append(panel)
            decs.append(decoded)
        return bundle, decs

    def _pin_host(self, panel: np.ndarray):
        """Stage one host panel into pinned memory when the backend has it.

        Probed once per pipeline: backends without a ``pinned_host`` memory
        space (the CPU backend) keep the pageable-numpy path, and a probe
        that succeeds but whose puts later fail degrades permanently rather
        than erroring the stream.
        """
        if not self._pin_probed:
            self._pin_probed = True
            if self._pin_want:
                try:
                    import jax

                    jax.devices()[0].memory("pinned_host")  # capability probe
                    self._pinned_sharding = self.sharding.with_memory_kind(
                        "pinned_host"
                    )
                    self.pinned = True
                except Exception:
                    self._pinned_sharding = None
        if self._pinned_sharding is None:
            return np.ascontiguousarray(panel)
        try:
            return self._device_put(
                np.ascontiguousarray(panel), self._pinned_sharding
            )
        except Exception:
            self._pinned_sharding = None  # partial support: fall back for good
            self.pinned = False
            return np.ascontiguousarray(panel)

    def _stage(self, row0: int) -> tuple[int, list, int]:
        """Fetch/pop one origin's bundle and (optionally) put it on device."""
        bundle, decs = self._next_host_bundle(row0)
        if self.sharding is None:
            return row0, bundle, 0
        staged, nbytes = [], 0
        put = self._device_put
        for panel, decoded, threaded in zip(bundle, decs, self._threaded):
            if threaded:
                dev = put(self._pin_host(panel), self.sharding)
                nbytes += dev.nbytes
                if self.stats is not None:
                    inc = {"panels": 1, "bytes_h2d": dev.nbytes}
                    if decoded is not None and decoded > dev.nbytes:
                        # Encoded shipping: the gap between what a host-
                        # decoded transfer would have cost and what crossed.
                        inc["bytes_h2d_saved"] = decoded - dev.nbytes
                    self.stats.add(**inc)
                staged.append(dev)
            else:
                staged.append(panel)  # already device-resident; sliced lazily
        return row0, staged, nbytes

    def __iter__(self) -> Iterator[tuple[int, list]]:
        if self._device_put is None and self.sharding is not None:
            import jax  # deferred so host-mode pipelines never touch jax

            self._device_put = jax.device_put
        try:
            if not self.origins:
                return
            if self.sharding is None:
                for row0 in self.origins:
                    yield row0, self._next_host_bundle(row0)[0]
                return
            # Device mode: stage origin t+1 before yielding origin t, so the
            # H2D copy overlaps the compute the consumer dispatches on t.
            prev_row0, prev, prev_bytes = self._stage(self.origins[0])
            for row0 in self.origins[1:]:
                _, cur, cur_bytes = self._stage(row0)
                self.device_live_bytes = prev_bytes + cur_bytes
                if self.stats is not None:
                    self.stats._note_live(self.device_live_bytes)
                yield prev_row0, prev
                prev_row0, prev, prev_bytes = row0, cur, cur_bytes
            self.device_live_bytes = prev_bytes
            if self.stats is not None:
                self.stats._note_live(prev_bytes)
            yield prev_row0, prev
            self.device_live_bytes = 0
        finally:
            self.close()

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Cancel the producer and release the rings (idempotent)."""
        self._cancel.set()
        for ring in self._rings:
            if ring is not None:
                ring.close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "PanelPipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


class CachingHandle:
    """Snapshot-handle wrapper with a host-RAM panel cache (solver batching).

    The Richardson solver re-streams P2 (n^2 bytes) from the scratch store on
    every iteration; wrapping the handle in a :class:`CachingHandle` makes
    iteration batches read the store once and replay the decoded panels from
    host RAM -- bitwise identical panels, ``bytes_read`` counted only on the
    filling pass.  :meth:`refresh` drops the cache (the start of the next
    batch streams from the store again).

    Host cost: up to one full decoded matrix (n^2 x itemsize) while the cache
    is warm -- the premise of a disk-backed scratch is exactly that host RAM
    is the roomier tier.
    """

    def __init__(self, handle):
        if not _is_handle(handle):
            raise TypeError(f"{handle!r} does not satisfy the snapshot-handle protocol")
        self.handle = handle
        self._cache: dict[tuple[int, int], np.ndarray] = {}
        self.fills = 0  # store reads (cache misses)
        self.replays = 0  # cache hits

    @property
    def shape(self):
        return self.handle.shape

    @property
    def dtype(self):
        return self.handle.dtype

    @property
    def nbytes(self):
        return self.handle.nbytes

    @property
    def panel_rows(self) -> int:
        return self.handle.panel_rows

    def refresh(self) -> None:
        """Drop cached panels; the next pass streams from the store again."""
        self._cache.clear()

    def read_panel_info(self, row0: int, height: int) -> tuple[np.ndarray, int]:
        key = (row0, height)
        cached = self._cache.get(key)
        if cached is not None:
            self.replays += 1
            return cached, 0  # zero backing-store bytes: a host-RAM replay
        panel, stored = fetch_panel_info(self.handle, row0, height)
        self._cache[key] = panel
        self.fills += 1
        return panel, stored

    def read_panel_encoded_info(
        self, row0: int, height: int
    ) -> tuple[np.ndarray, int, int]:
        """Encoded (stored-form) read with the same replay semantics.

        Cached separately from decoded panels -- a consumer mixing both read
        forms (the kernel-path solver after an XLA-path chi build) must never
        replay a decoded fp32 panel where uint16 bits were requested.
        """
        key = (row0, height, "enc")
        cached = self._cache.get(key)
        if cached is not None:
            self.replays += 1
            panel, decoded = cached
            return panel, 0, decoded  # host-RAM replay: no backing-store bytes
        panel, stored, decoded = fetch_panel_encoded_info(self.handle, row0, height)
        self._cache[key] = (panel, decoded)
        self.fills += 1
        return panel, stored, decoded

    def read_panel(self, row0: int, height: int) -> np.ndarray:
        return self.read_panel_info(row0, height)[0]
