from repro.data.pipeline import DataConfig, Prefetcher, global_batch_for, host_batch

__all__ = ["DataConfig", "Prefetcher", "global_batch_for", "host_batch"]
