"""Deterministic synthetic token pipeline with per-host sharding + prefetch.

Every batch is a pure function of (seed, step): any rank can (re)generate any
shard without coordination, which is what makes restart/elastic-remesh exact
-- after restoring step k, the pipeline at step k+1 produces bit-identical
data regardless of host count (the same property the counter-based edge RNG
gives the CADDeLaG core).

Tokens follow a skewed (Zipf-ish) distribution with a deterministic
next-token structure so small models can measurably learn; labels are the
next-token shift.  For multi-host runs, ``global_batch_for`` builds the
jax.Array from per-host shards via ``make_array_from_callback``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import rng as crng


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frames_dim: int = 0  # >0: also emit frame embeddings (enc-dec stub)


def _tokens_for(cfg: DataConfig, step: int, rows: np.ndarray) -> np.ndarray:
    """(len(rows), seq_len) int32 tokens for the given global row indices."""
    s = np.arange(cfg.seq_len, dtype=np.uint32)[None, :]
    r = rows.astype(np.uint32)[:, None]
    h = np.asarray(
        crng.hash_u32(np.uint32(cfg.seed), r * np.uint32(1_000_003) + np.uint32(step), s)
    )
    # Zipf-ish skew: square the uniform so low ids dominate, then add a
    # learnable structure: every 4th token is a function of the previous one.
    u = (h.astype(np.float64) / 2**32) ** 2
    tok = (u * cfg.vocab).astype(np.int64)
    for j in range(1, cfg.seq_len, 4):
        tok[:, j] = (tok[:, j - 1] * 31 + 7) % cfg.vocab
    return tok.astype(np.int32)


def host_batch(cfg: DataConfig, step: int) -> dict:
    """Whole global batch on this host (single-process path)."""
    rows = np.arange(cfg.global_batch)
    tok = _tokens_for(cfg, step, rows)
    labels = np.concatenate([tok[:, 1:], tok[:, :1]], axis=1)
    out = {"tokens": tok, "labels": labels}
    if cfg.frames_dim:
        h = np.asarray(
            crng.hash_u32(
                np.uint32(cfg.seed + 1),
                rows.astype(np.uint32)[:, None, None],
                np.arange(cfg.seq_len, dtype=np.uint32)[None, :, None],
                np.arange(cfg.frames_dim, dtype=np.uint32)[None, None, :],
            )
        )
        out["frames"] = (h.astype(np.float32) / 2**31 - 1.0).astype(np.float32)
    return out


def global_batch_for(cfg: DataConfig, step: int, mesh: Mesh, spec: P) -> dict:
    """Build the sharded global batch; each device's shard is generated
    locally from the counter RNG (no host gathers, no cross-host traffic)."""
    sharding = NamedSharding(mesh, spec)

    def make(name, shape, dtype, gen):
        def cb(index):
            # index: tuple of slices into the global array for one device
            rows = np.arange(*index[0].indices(shape[0]))
            full = gen(rows)
            slc = tuple([slice(None)] + [index[i] for i in range(1, len(index))])
            return full[slc]

        return jax.make_array_from_callback(shape, sharding, cb)

    b, s = cfg.global_batch, cfg.seq_len
    tok_gen = lambda rows: _tokens_for(cfg, step, rows)

    def lab_gen(rows):
        t = tok_gen(rows)
        return np.concatenate([t[:, 1:], t[:, :1]], axis=1)

    out = {
        "tokens": make("tokens", (b, s), np.int32, tok_gen),
        "labels": make("labels", (b, s), np.int32, lab_gen),
    }
    return out


class Prefetcher:
    """One-batch-ahead prefetch on a background thread."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, make=host_batch):
        import queue
        import threading

        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._stop = threading.Event()

        def work():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._q.put(make(cfg, step), timeout=0.5)
                    step += 1
                except Exception:
                    continue

        self._t = threading.Thread(target=work, daemon=True)
        self._t.start()

    def next(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
