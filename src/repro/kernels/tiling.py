"""Tile-shape fitting shared by the kernels.

``fit(dim, want)`` returns the largest divisor of ``dim`` that is <= ``want``,
preferring MXU-aligned (multiple of 128) tiles, then 8-aligned, then anything.
Keeps kernel call sites robust to odd shard shapes without padding.
"""

from __future__ import annotations


def fit(dim: int, want: int) -> int:
    want = min(want, dim)
    best = 1
    for align in (128, 8, 1):
        t = (want // align) * align
        while t >= align:
            if dim % t == 0:
                return t
            t -= align
    return best
