"""Pallas TPU kernels for the compute hot-spots (validated interpret=True on CPU).

- ``block_matmul``    -- the paper's per-block GEMM on the MXU (fp32 accum)
- ``edge_projection`` -- fused sqrt(A).Q row-reduce with in-kernel counter RNG
- ``cad_scores``      -- fused commute-distance + |dA| gate + row reduction
- ``flash_attention`` -- online-softmax attention for the LM substrate

Each has a jit'd wrapper in :mod:`repro.kernels.ops` and a pure-jnp oracle in
:mod:`repro.kernels.ref`.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
