"""Fused streaming panel-GEMM Pallas kernels: the out-of-core hot path.

Generalizes :mod:`repro.kernels.block_matmul` for the panel-streaming
executors (``core/oochain.py`` GEMM steps, the streamed solve driver):

* **On-device bf16 decode**: operands may arrive as raw bf16 bit patterns
  (``uint16``, exactly what the store's bf16 codec holds on disk).  The
  kernel widens them to fp32 inside VMEM (``bitcast -> bf16 -> f32``, the
  same exact widening as the host codec), so the panel pipeline ships the
  *stored* bytes -- half the H2D traffic of host-decoded fp32 -- and the
  host prefetch thread stops paying the decode.  Encoded-ness is inferred
  from the operand dtype: ``uint16`` means bf16 bits, anything else is cast
  to fp32 as the XLA path does.
* **Double buffering**: the grid walks (m/bm, n/bn, k/bk) with k innermost;
  Pallas pipelines the next block's HBM->VMEM DMA under the current dot, so
  the copy of block k+1 overlaps compute on block k (same schedule as
  ``block_matmul``, see its VMEM budget note).
* **Fused accumulate-into**: ``stream_gemm(a, b, init)`` computes
  ``init + sign * (a @ b)`` in one kernel -- the per-K-step body of the
  out-of-core GEMM (`acc <- acc + block @ right`) without a separate add.
* **Fused solve epilogue**: :func:`fused_panel_matvec` folds the streamed
  solver's per-iteration update into the mat-vec itself -- one kernel pass
  over a P2 row panel yields the Richardson update ``gy = chi + y - P2 @ y``
  *and* the deflated-residual partials (per-column sums and the sum of
  squares of ``delta = chi - P2 @ y``), so each iteration is exactly one
  pass over the panel stream with no separate epilogue dispatches.

Numerics: fp32 accumulation in VMEM scratch regardless of input encoding.
With unblocked K the ``init``-form is bitwise identical to the XLA
``acc + dot`` step; blocked K reorders the reduction (allclose).  Interpret
mode runs the same kernel bodies on non-TPU backends.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dec(x, encoded: bool):
    """Widen one VMEM block to fp32; ``encoded`` blocks are bf16 bit patterns.

    ``bitcast(uint16 -> bf16) -> f32`` is the exact widening the host codec
    (:func:`repro.store.tilestore._bf16_u16_to_f32`) performs -- decoded
    values are bitwise identical, only the decode site moves on-device.
    """
    if encoded:
        return lax.bitcast_convert_type(x, jnp.bfloat16).astype(jnp.float32)
    return x.astype(jnp.float32)


def _stream_gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps, a_enc, b_enc, neg):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        _dec(a_ref[...], a_enc), _dec(b_ref[...], b_enc),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        acc = acc_ref[...]
        o_ref[...] = (-acc if neg else acc).astype(o_ref.dtype)


def _stream_gemm_init_kernel(
    a_ref, b_ref, i_ref, o_ref, acc_ref, *, k_steps, a_enc, b_enc, neg
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        _dec(a_ref[...], a_enc), _dec(b_ref[...], b_enc),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        acc = acc_ref[...]
        base = i_ref[...].astype(jnp.float32)
        o_ref[...] = (base - acc if neg else base + acc).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sign", "bm", "bk", "bn", "out_dtype", "interpret"),
)
def stream_gemm(
    a: jax.Array,
    b: jax.Array,
    init: jax.Array | None = None,
    *,
    sign: float = 1.0,
    bm: int = 256,
    bk: int = 256,
    bn: int = 256,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
) -> jax.Array:
    """``init + sign * (A @ B)`` (init optional), fp32 accumulation.

    ``A`` (m, k) and ``B`` (k, n) may independently be fp32/bf16 values or
    raw bf16 bit patterns (``uint16``), decoded on-device per block; ``init``
    (m, n), when given, is added at the output flush -- with unblocked K this
    is bitwise the XLA ``init + dot`` / ``init - dot`` GEMM step.  ``sign``
    must be +/-1.0 (it selects add vs subtract; no scaling is performed).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    if init is not None and init.shape != (m, n):
        raise ValueError(f"init is {init.shape}, output is {(m, n)}")
    if sign not in (1.0, -1.0):
        raise ValueError(f"sign selects add/subtract and must be +-1.0, got {sign}")
    a_enc = a.dtype == jnp.uint16
    b_enc = b.dtype == jnp.uint16
    from repro.kernels.tiling import fit

    bm, bk, bn = fit(m, bm), fit(k, bk), fit(n, bn)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (m // bm, n // bn, k // bk)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [a, b]
    kwargs = dict(k_steps=grid[2], a_enc=a_enc, b_enc=b_enc, neg=sign < 0)
    if init is None:
        kernel = functools.partial(_stream_gemm_kernel, **kwargs)
    else:
        kernel = functools.partial(_stream_gemm_init_kernel, **kwargs)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
        operands.append(init)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)


def _fused_matvec_kernel(
    p_ref, y_ref, chi_ref, yp_ref, gy_ref, cs_ref, ss_ref, acc_ref, *, k_steps, enc
):
    i = pl.program_id(0)
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The reduction outputs map every grid point to block (0, 0): they live
    # in VMEM across the whole (sequential) grid walk, initialized once and
    # accumulated at each row block's last K step.
    @pl.when(jnp.logical_and(i == 0, kk == 0))
    def _init_reductions():
        cs_ref[...] = jnp.zeros_like(cs_ref)
        ss_ref[...] = jnp.zeros_like(ss_ref)

    acc_ref[...] += jnp.dot(
        _dec(p_ref[...], enc), y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kk == k_steps - 1)
    def _epilogue():
        mv = acc_ref[...]
        chi = chi_ref[...].astype(jnp.float32)
        gy_ref[...] = (chi + yp_ref[...].astype(jnp.float32) - mv).astype(gy_ref.dtype)
        delta = chi - mv  # == gy - y, the residual's panel contribution
        cs_ref[...] += jnp.sum(delta, axis=0, keepdims=True)
        ss_ref[...] += jnp.sum(delta * delta).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def fused_panel_matvec(
    p_panel: jax.Array,
    y: jax.Array,
    chi_panel: jax.Array,
    y_panel: jax.Array,
    *,
    bm: int = 256,
    bk: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One fused solve-iteration pass over a P2 row panel.

    ``(gy, colsum, sumsq)`` with ``gy = chi_panel + y_panel - p_panel @ y``
    (the Richardson update restricted to this panel's rows) and the
    deflated-residual partials of ``delta = chi_panel - p_panel @ y``:
    ``colsum`` (1, q) holds per-column sums, ``sumsq`` (1, 1) the total sum
    of squares.  The caller reduces panels via
    ``res^2 = sum(sumsq) - sum(colsum^2) / n`` (the mean-subtracted
    Frobenius norm), so mat-vec + AXPY + residual cost one panel pass.

    ``p_panel`` (ph, K) may be fp32 or raw bf16 bit patterns (uint16,
    decoded on-device); ``y`` is (K, q), ``chi_panel`` / ``y_panel`` are
    the (ph, q) row slices of chi / y matching this panel.
    """
    ph, kdim = p_panel.shape
    k2, q = y.shape
    if kdim != k2:
        raise ValueError(f"inner dims mismatch: {p_panel.shape} @ {y.shape}")
    if chi_panel.shape != (ph, q) or y_panel.shape != (ph, q):
        raise ValueError(
            f"chi/y panels must be {(ph, q)}, got {chi_panel.shape}/{y_panel.shape}"
        )
    enc = p_panel.dtype == jnp.uint16
    from repro.kernels.tiling import fit

    bm, bk = fit(ph, bm), fit(kdim, bk)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (ph // bm, kdim // bk)
    k_steps = grid[1]
    return pl.pallas_call(
        functools.partial(_fused_matvec_kernel, k_steps=k_steps, enc=enc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, kk: (i, kk)),
            pl.BlockSpec((bk, q), lambda i, kk: (kk, 0)),
            pl.BlockSpec((bm, q), lambda i, kk: (i, 0)),
            pl.BlockSpec((bm, q), lambda i, kk: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bm, q), lambda i, kk: (i, 0)),
            pl.BlockSpec((1, q), lambda i, kk: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, kk: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((ph, q), jnp.float32),
            jax.ShapeDtypeStruct((1, q), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((bm, q), jnp.float32)],
        interpret=interpret,
    )(p_panel, y, chi_panel, y_panel)
