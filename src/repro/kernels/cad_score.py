"""Fused CAD anomaly-score Pallas kernel (paper Algorithm 4 lines 3-6).

F_i = sum_j |A1[i,j] - A2[i,j]| * |c1(i,j) - c2(i,j)|,
c_t(i,j) = V_t * (||Z_t[i]||^2 + ||Z_t[j]||^2 - 2 Z_t[i].Z_t[j]).

The n x n commute-distance matrices D_1, D_2 of the paper are NEVER
materialized: each (bm, bn) grid cell reconstructs both distance tiles from
the embedding rows (two skinny (bm,k)x(k,bn) MXU dots), applies the |dA| gate,
and row-reduces into the (bm, 1) output, accumulated across the column walk.
HBM traffic: 2 adjacency tiles + 4 skinny Z tiles in, bm floats out --
vs 2 extra n^2 matrices for the unfused path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_tile(zi, zj, vol):
    zi = zi.astype(jnp.float32)
    zj = zj.astype(jnp.float32)
    sq_i = jnp.sum(zi * zi, axis=-1)
    sq_j = jnp.sum(zj * zj, axis=-1)
    cross = jnp.dot(zi, zj.T, preferred_element_type=jnp.float32)
    return vol * (sq_i[:, None] + sq_j[None, :] - 2.0 * cross)


def _cad_kernel(a1_ref, a2_ref, z1i_ref, z1j_ref, z2i_ref, z2j_ref, v_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    v1, v2 = v_ref[0, 0], v_ref[0, 1]
    d1 = _dist_tile(z1i_ref[...], z1j_ref[...], v1)
    d2 = _dist_tile(z2i_ref[...], z2j_ref[...], v2)
    de = jnp.abs(a1_ref[...].astype(jnp.float32) - a2_ref[...].astype(jnp.float32)) * jnp.abs(
        d1 - d2
    )
    o_ref[...] += jnp.sum(de, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def cad_scores_tile(
    a1: jax.Array,
    a2: jax.Array,
    z1i: jax.Array,
    z1j: jax.Array,
    z2i: jax.Array,
    z2j: jax.Array,
    vol1: jax.Array,
    vol2: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Partial row scores (m,) for one rectangular (m, n) adjacency tile.

    ``z*i`` are the embedding rows for the tile's global rows, ``z*j`` for its
    global columns -- so a shard_map tile program can run the fused kernel on
    its local block and psum the partial sums across the column axis.
    """
    m, n = a1.shape
    k = z1i.shape[1]
    from repro.kernels.tiling import fit

    bm, bn = fit(m, bm), fit(n, bn)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    vols = jnp.stack([vol1, vol2]).astype(jnp.float32).reshape(1, 2)
    grid = (m // bm, n // bn)
    out = pl.pallas_call(
        _cad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(a1, a2, z1i, z1j, z2i, z2j, vols)
    return out[:, 0]


def cad_scores(
    a1: jax.Array,
    a2: jax.Array,
    z1: jax.Array,
    z2: jax.Array,
    vol1: jax.Array,
    vol2: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Node anomaly scores F (n,) from two embeddings, fused (square case)."""
    return cad_scores_tile(
        a1, a2, z1, z1, z2, z2, vol1, vol2, bm=bm, bn=bn, interpret=interpret
    )
