"""RWKV6 WKV recurrence Pallas kernel (chunked linear attention).

One kernel instance owns one (batch, head) pair and walks the sequence in
chunks, carrying the (dk, dv) state in VMEM across grid steps (the TPU grid
executes the chunk axis sequentially, so the scratch state persists):

    y_t = r_t . (S + u (.) k_t v_t^T)          (bonus on the current token)
    S  <- diag(w_t) S + k_t v_t^T              (per-channel decay)

Within a chunk the pairwise decay ratios turn the recurrence into two
masked MXU matmuls (same math as models/rwkv6.wkv_chunked); across chunks
only the state flows -- O(S*C) work, O(dk*dv) carried bytes.

Layout: r/k (BH, S, dk), v (BH, S, dv), lw (BH, S, dk) log-decay <= 0.
dk = dv = 64 for all assigned configs (rwkv6-3b) -- one MXU tile.

Numerical range: the factorized intra-chunk form computes exp(cum_{t-1}) *
exp(-cum_i); pick ``chunk`` so the cumulative per-chunk log-decay stays
above ~-30 (|cum| <= 30) or precision degrades -- trained RWKV decays
(w ~ exp(-1e-2..1e-3)) allow chunks of 128-512; adversarially strong decay
needs smaller chunks (see tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)  # (C, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (1, dk)

    cum = jnp.cumsum(lw, axis=0)  # inclusive log decay
    cum_tm1 = cum - lw  # exclusive
    r_dec = r * jnp.exp(cum_tm1)
    k_dec = k * jnp.exp(jnp.minimum(-cum, 40.0))
    scores = jnp.dot(r_dec, k_dec.T, preferred_element_type=jnp.float32)
    c = r.shape[0]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (c, c), 1))  # strictly lower
    scores = jnp.where(mask, scores, 0.0)
    bonus = jnp.sum(r * u * k, axis=1, keepdims=True)  # (C, 1)
    y = jnp.dot(scores, v, preferred_element_type=jnp.float32) + bonus * v

    # inter-chunk: y += (r_t (x) W_{t-1}) . S_prev
    y = y + jnp.dot(r_dec, s_ref[...], preferred_element_type=jnp.float32)

    # state update: S <- diag(W_C) S + sum_i diag(W_C / W_i) k_i (x) v_i
    tail = jnp.exp(cum[-1:] - cum)  # (C, dk)
    s_ref[...] = s_ref[...] * jnp.exp(cum[-1:]).T + jnp.dot(
        (tail * k).T, v, preferred_element_type=jnp.float32
    )
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r, k, v, lw, u, *, chunk: int = 128, interpret: bool | None = None):
    """(BH, S, dk) x ... -> (BH, S, dv); u (BH, dk) bonus."""
    bh, s, dk = r.shape
    dv = v.shape[-1]
    from repro.kernels.tiling import fit

    c = fit(s, chunk)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (bh, s // c)
    return pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, dk), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, c, dk), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, c, dv), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, c, dk), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, 1, dk), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, dv), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), r.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u[:, None, :])
