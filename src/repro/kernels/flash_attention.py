"""Flash-attention (online-softmax) Pallas kernel for the LM substrate.

Causal multi-head attention without materializing the (S, S) score matrix:
the grid walks (batch*heads, q_blocks, kv_blocks); each step rescales the
running (max, sum, accumulator) triple by the new block max -- the standard
online softmax -- entirely in VMEM.  KV blocks beyond the causal frontier of
a q block are skipped via ``pl.when`` (no HBM read is wasted on them because
the index map still walks them, but the FLOPs are gated; on real TPU the
comparison is cheap relative to the dots).

Layout: q, k, v are (B*H, S, D) -- heads flattened into the leading grid dim
so one kernel instance handles one (head, q-tile) strip.  D is the head dim
(128-aligned for the MXU).  fp32 softmax statistics regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, bq, bk, scale, causal, kv_steps):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def attend():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            q_pos = qi * bq + jnp.arange(bq)
            k_pos = ki * bk + jnp.arange(bk)
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, _NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32
        )
        m_ref[...], l_ref[...] = m_new, l_new

    if causal:
        # Skip fully-masked KV blocks (block start beyond the q block's end).
        pl.when(ki * bk <= qi * bq + bq - 1)(attend)
    else:
        attend()

    @pl.when(ki == kv_steps - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """(BH, S, D) x (BH, T, D) x (BH, T, D) -> (BH, S, D) flash attention."""
    bh, s, d = q.shape
    _, t, _ = k.shape
    from repro.kernels.tiling import fit

    bq, bk = fit(s, bq), fit(t, bk)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = 1.0 / (d**0.5)
    grid = (bh, s // bq, t // bk)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, bq=bq, bk=bk, scale=scale, causal=causal, kv_steps=grid[2]
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
