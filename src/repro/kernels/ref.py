"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import rng as crng


def block_matmul(a: jax.Array, b: jax.Array, *, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def edge_projection(a: jax.Array, *, seed: int, k: int) -> jax.Array:
    n0, n1 = a.shape
    rows = jnp.arange(n0, dtype=jnp.uint32)
    cols = jnp.arange(n1, dtype=jnp.uint32)
    s = jnp.sqrt(jnp.maximum(a.astype(jnp.float32), 0.0))

    def one_col(c):
        q = crng.edge_rademacher(seed, rows[:, None], cols[None, :], c)
        return jnp.sum(s * q, axis=1)

    y = jax.vmap(one_col, out_axes=1)(jnp.arange(k, dtype=jnp.uint32))
    return y * (1.0 / jnp.sqrt(jnp.float32(k)))


def cad_scores(a1, a2, z1, z2, vol1, vol2) -> jax.Array:
    def dist(z, vol):
        z = z.astype(jnp.float32)
        sq = jnp.sum(z * z, axis=-1)
        return vol * (sq[:, None] + sq[None, :] - 2.0 * z @ z.T)

    de = jnp.abs(a1.astype(jnp.float32) - a2.astype(jnp.float32)) * jnp.abs(
        dist(z1, vol1) - dist(z2, vol2)
    )
    return jnp.sum(de, axis=1)


def flash_attention(q, k, v, *, causal: bool = True) -> jax.Array:
    bh, s, d = q.shape
    t = k.shape[1]
    scale = 1.0 / (d**0.5)
    logits = jnp.einsum(
        "hsd,htd->hst", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    if causal:
        mask = jnp.arange(s)[:, None] >= jnp.arange(t)[None, :]
        logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hst,htd->hsd", p, v.astype(jnp.float32)).astype(q.dtype)


def wkv(r, k, v, lw, u):
    """Per-step WKV recurrence oracle; r/k/lw (BH,S,dk), v (BH,S,dv), u (BH,dk)."""
    from repro.models.rwkv6 import wkv_reference

    # reshape (BH, S, D) -> (B=1, S, H=BH, D) for the model-layer oracle
    r4 = r.swapaxes(0, 1)[None]
    k4 = k.swapaxes(0, 1)[None]
    v4 = v.swapaxes(0, 1)[None]
    lw4 = lw.swapaxes(0, 1)[None]
    y, _ = wkv_reference(r4, k4, v4, lw4, u)
    return y[0].swapaxes(0, 1)
