"""Jit'd public wrappers over the Pallas kernels.

``interpret=None`` auto-selects: compiled Pallas on TPU, interpret-mode
(Python execution of the kernel body) on CPU -- so the same call sites run
everywhere and tests exercise the kernel bodies on this CPU container.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.block_matmul import block_matmul
from repro.kernels.cad_score import cad_scores, cad_scores_tile
from repro.kernels.edge_projection import edge_projection
from repro.kernels.flash_attention import flash_attention
from repro.kernels.stream_gemm import fused_panel_matvec, stream_gemm
from repro.kernels.wkv import wkv

__all__ = [
    "block_matmul",
    "cad_scores",
    "cad_scores_tile",
    "edge_projection",
    "flash_attention",
    "fused_panel_matvec",
    "stream_gemm",
    "wkv",
]
