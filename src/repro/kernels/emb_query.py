"""Fused tiled distance/top-k Pallas kernel: the query-scale read path.

One kernel pass over a streamed ``Z`` row panel answers "which of this
panel's nodes are among my queries' best k so far":

* **Fused distance evaluation**: for the (q, k_RP) query block and a
  (panel_rows, k_RP) panel of the persisted sketch, the squared distances
  ``||z_q - z_j||^2`` are two skinny MXU GEMM-shaped reductions plus a
  rank-1 broadcast -- the n x n commute matrix is never materialized, and
  neither is an n-wide score row (scores live per block column chunk).
* **On-device bf16 decode**: panels may arrive as raw bf16 bit patterns
  (``uint16``, the embedding store's stored form), widened to fp32 in VMEM
  exactly like :mod:`repro.kernels.stream_gemm` -- the pipeline ships half
  the decoded bytes.
* **von Luxburg correction epilogue** (``corrected=True``): large dense
  graphs degenerate raw commute times to ``vol * (1/deg_i + 1/deg_j)``
  (arXiv 1003.1266), so the corrected scorer rescales to ``C / vol`` and
  subtracts the degree term -- applied per score block before selection, so
  raw and corrected queries are the same single pass.
* **Running per-query top-k merge**: the kernel carries the best-(k) values
  AND global node ids in VMEM scratch across the grid walk, merging each
  block's candidates by an unrolled masked-extremum selection (top-k is
  static and small; ``argmax``-free, so the body lowers on TPU Pallas and
  interpret mode alike).  The running state is threaded *through* the kernel
  as operands, so a whole-store query is: seed state, one kernel call per
  streamed panel, read back (q, topk) -- device residency stays two panels +
  the O(q k) state, and every panel uses one compiled program.

Interpret mode runs the same body off-TPU, as everywhere in
:mod:`repro.kernels`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.stream_gemm import _dec


def _select_topk(vals, idx, *, topk: int, largest: bool):
    """(q, topk) best values/ids of a (q, m) candidate block, order preserved.

    Unrolled masked-extremum selection (topk is static and small): each round
    takes the per-row best remaining candidate, breaking ties toward the
    lower *position* -- so earlier candidates (the running state, then lower
    node ids) win ties, matching ``lax.top_k``'s stability.  Built from
    max/min/where/iota only: no argmax, no gather, TPU-Pallas lowerable.
    """
    q, m = vals.shape
    work = vals if largest else -vals
    pos = lax.broadcasted_iota(jnp.int32, (q, m), 1)
    out_v, out_i = [], []
    for _ in range(topk):
        best = jnp.max(work, axis=-1, keepdims=True)
        first = jnp.min(
            jnp.where(work == best, pos, jnp.int32(m)), axis=-1, keepdims=True
        )
        sel = pos == first
        out_v.append(jnp.sum(jnp.where(sel, vals, 0.0), axis=-1))
        out_i.append(jnp.sum(jnp.where(sel, idx, 0), axis=-1))
        work = jnp.where(sel, -jnp.inf, work)
    return jnp.stack(out_v, axis=-1), jnp.stack(out_i, axis=-1)


def _panel_topk_kernel(
    zq_ref, zp_ref, idq_ref, idp_ref, vol_ref, row0_ref, ex_ref,
    rv_ref, ri_ref, ov_ref, oi_ref, accv_ref, acci_ref,
    *, k_steps, bj, topk, enc, corrected, largest,
):
    kk = pl.program_id(0)

    @pl.when(kk == 0)
    def _seed():
        # The running state enters as operands: a whole-store query threads
        # (vals, ids) through one kernel call per panel.
        accv_ref[...] = rv_ref[...]
        acci_ref[...] = ri_ref[...]

    zq = zq_ref[...].astype(jnp.float32)
    zb = _dec(zp_ref[...], enc)
    sq_q = jnp.sum(zq * zq, axis=-1, keepdims=True)
    sq_j = jnp.sum(zb * zb, axis=-1)[None, :]
    dist2 = sq_q + sq_j - 2.0 * jnp.dot(
        zq, zb.T, preferred_element_type=jnp.float32
    )
    dist2 = jnp.maximum(dist2, 0.0)  # clamp the rank-1 cancellation noise
    if corrected:
        # C_amp = C/vol - 1/deg_i - 1/deg_j (and C/vol is exactly dist2):
        # the degenerate dense-graph limit subtracts out, structure remains.
        scores = dist2 - idq_ref[...] - idp_ref[...]
    else:
        scores = vol_ref[0, 0] * dist2
    q = scores.shape[0]
    cidx = (
        row0_ref[0, 0]
        + kk * bj
        + lax.broadcasted_iota(jnp.int32, (q, bj), 1)
    )
    worst = jnp.float32(-jnp.inf if largest else jnp.inf)
    scores = jnp.where(cidx == ex_ref[...], worst, scores)  # self-exclusion
    vals = jnp.concatenate([accv_ref[...], scores], axis=1)
    idx = jnp.concatenate([acci_ref[...], cidx], axis=1)
    mv, mi = _select_topk(vals, idx, topk=topk, largest=largest)
    accv_ref[...] = mv
    acci_ref[...] = mi

    @pl.when(kk == k_steps - 1)
    def _flush():
        ov_ref[...] = accv_ref[...]
        oi_ref[...] = acci_ref[...]


def topk_init(nq: int, topk: int, *, largest: bool) -> tuple[jax.Array, jax.Array]:
    """The seed running state: worst-possible values, id -1 (empty slots)."""
    worst = -jnp.inf if largest else jnp.inf
    return (
        jnp.full((nq, topk), worst, jnp.float32),
        jnp.full((nq, topk), -1, jnp.int32),
    )


@functools.partial(
    jax.jit, static_argnames=("topk", "corrected", "largest", "interpret")
)
def panel_topk_update(
    run_vals: jax.Array,
    run_idx: jax.Array,
    zq: jax.Array,
    z_panel: jax.Array,
    inv_deg_q: jax.Array,
    inv_deg_panel: jax.Array,
    vol: jax.Array,
    row0,
    exclude: jax.Array,
    *,
    topk: int,
    corrected: bool = False,
    largest: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Merge one Z row panel into the running per-query top-k.

    ``run_vals`` / ``run_idx`` (q, topk) are the state from
    :func:`topk_init` or a previous call; ``zq`` (q, k) the resident query
    block; ``z_panel`` (ph, k) the streamed panel -- fp32 values or raw bf16
    bit patterns (``uint16``, decoded on-device); ``inv_deg_q`` (q, 1) /
    ``inv_deg_panel`` (1, ph) the correction terms (ignored unless
    ``corrected``); ``vol`` the scalar graph volume (ignored when
    ``corrected`` -- the amplified score is volume-free); ``row0`` the
    panel's global row origin (an *operand*, so every panel reuses one
    compiled program); ``exclude`` (q, 1) int32 global ids masked to the
    worst score per query (-1 for none) -- nearest-neighbor queries drop
    their own node in-kernel.

    Returns the merged (vals, ids); ids are global node indices, -1 in slots
    not yet filled (topk > rows seen so far).
    """
    q, kdim = zq.shape
    ph, k2 = z_panel.shape
    if kdim != k2:
        raise ValueError(f"query dim mismatch: {zq.shape} vs panel {z_panel.shape}")
    if run_vals.shape != (q, topk) or run_idx.shape != (q, topk):
        raise ValueError(
            f"running state must be {(q, topk)}, got "
            f"{run_vals.shape}/{run_idx.shape}"
        )
    if inv_deg_q.shape != (q, 1) or inv_deg_panel.shape != (1, ph):
        raise ValueError(
            f"inv_deg blocks must be {(q, 1)}/{(1, ph)}, got "
            f"{inv_deg_q.shape}/{inv_deg_panel.shape}"
        )
    if exclude.shape != (q, 1):
        raise ValueError(f"exclude must be {(q, 1)}, got {exclude.shape}")
    from repro.kernels.tiling import fit

    bj = fit(ph, 256)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (ph // bj,)
    vol2 = jnp.asarray(vol, jnp.float32).reshape(1, 1)
    row02 = jnp.asarray(row0, jnp.int32).reshape(1, 1)
    kernel = functools.partial(
        _panel_topk_kernel,
        k_steps=grid[0], bj=bj, topk=topk,
        enc=z_panel.dtype == jnp.uint16, corrected=corrected, largest=largest,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, kdim), lambda kk: (0, 0)),
            pl.BlockSpec((bj, kdim), lambda kk: (kk, 0)),
            pl.BlockSpec((q, 1), lambda kk: (0, 0)),
            pl.BlockSpec((1, bj), lambda kk: (0, kk)),
            pl.BlockSpec((1, 1), lambda kk: (0, 0)),
            pl.BlockSpec((1, 1), lambda kk: (0, 0)),
            pl.BlockSpec((q, 1), lambda kk: (0, 0)),
            pl.BlockSpec((q, topk), lambda kk: (0, 0)),
            pl.BlockSpec((q, topk), lambda kk: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((q, topk), lambda kk: (0, 0)),
            pl.BlockSpec((q, topk), lambda kk: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((q, topk), jnp.float32),
            jax.ShapeDtypeStruct((q, topk), jnp.int32),
        ),
        scratch_shapes=[
            pltpu.VMEM((q, topk), jnp.float32),
            pltpu.VMEM((q, topk), jnp.int32),
        ],
        interpret=interpret,
    )(
        zq, z_panel, inv_deg_q, inv_deg_panel, vol2, row02, exclude,
        run_vals, run_idx,
    )
