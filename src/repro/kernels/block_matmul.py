"""Tiled MXU matmul Pallas kernel -- the per-block GEMM of the paper.

The paper's per-block product (``numpy`` GEMM on a Spark executor, their
``O(p^{2+zeta})`` term) becomes a Pallas kernel on the TPU MXU: the grid walks
(m/bm, n/bn, k/bk) tiles, streams A(bm,bk) / B(bk,bn) HBM->VMEM via BlockSpec,
and accumulates the (bm,bn) product in an fp32 VMEM scratch across the k-steps
(the innermost, sequential grid dimension), writing the output tile once on the
last step.  MXU alignment: all tile dims are multiples of 128 by default;
fp32 accumulation regardless of storage dtype (bf16 in the chain product).

VMEM budget (defaults bm=bk=bn=256, bf16 in / fp32 acc):
    A tile 128 KiB + B tile 128 KiB + acc 256 KiB + out 128 KiB < 1 MiB,
well inside the ~16 MiB/core VMEM of v5e, leaving room for double buffering
(Pallas pipelines the next HBM->VMEM copy under the current dot).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bn", "out_dtype", "interpret"),
)
def block_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 256,
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """C = A @ B, (m,k)x(k,n), tiled for the MXU with fp32 accumulation."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    out_dtype = out_dtype or a.dtype
    from repro.kernels.tiling import fit

    bm, bk, bn = fit(m, bm), fit(k, bk), fit(n, bn)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
