"""Fused edge-space random projection Pallas kernel.

Computes Y[i, c] = sum_j sqrt(A[i, j]) * Q_c[i, j] -- i.e. Y = B^T W^{1/2} Q
for ``k`` Rademacher columns -- WITHOUT materializing the m = n^2 edge space.
The antisymmetric Rademacher field Q is regenerated inside the kernel from the
same splitmix32 counter hash as :mod:`repro.core.rng` (bit-identical: the hash
is plain jnp uint32 ops and runs on the VPU), so the kernel reads only the
adjacency tile and writes only the (bm, k) output tile: arithmetic intensity
k ops/byte of A, zero bytes of stored randomness.

Grid: (rows/bm, cols/bn) with the column walk innermost and sequential; the
output row-tile is accumulated across the column steps in-place (output
revisiting), matching the TPU grid execution order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import rng as crng


def _edge_proj_kernel(a_ref, o_ref, *, seed: int, k: int, bm: int, bn: int, col_steps: int):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    rows = i * bm + jnp.arange(bm, dtype=jnp.uint32)
    cols = j * bn + jnp.arange(bn, dtype=jnp.uint32)
    s = jnp.sqrt(jnp.maximum(a_ref[...].astype(jnp.float32), 0.0))
    # (bm, bn, k) Rademacher tile, regenerated -- identical hash to core.rng.
    q = crng.edge_rademacher(
        seed,
        rows[:, None, None],
        cols[None, :, None],
        jnp.arange(k, dtype=jnp.uint32)[None, None, :],
    )
    o_ref[...] += jnp.einsum("ij,ijc->ic", s, q, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("seed", "k", "bm", "bn", "interpret")
)
def edge_projection(
    a: jax.Array,
    *,
    seed: int,
    k: int,
    bm: int = 256,
    bn: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Y (n, k) = B^T W^{1/2} Q with JL 1/sqrt(k) normalization."""
    m, n = a.shape
    from repro.kernels.tiling import fit

    bm, bn = fit(m, bm), fit(n, bn)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grid = (m // bm, n // bn)
    y = pl.pallas_call(
        functools.partial(
            _edge_proj_kernel, seed=seed, k=k, bm=bm, bn=bn, col_steps=grid[1]
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
    )(a)
    return y * (1.0 / jnp.sqrt(jnp.float32(k)))
