"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness (the assigned-arch requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(7)


# Heaviest smoke configs (10-60s each on CI CPU): deselected from tier-1 by
# the default -m "not slow"; the weekly scheduled job runs them.
_SLOW_ARCHS = {
    "zamba2-7b",
    "seamless-m4t-medium",
    "deepseek-67b",
    "rwkv6-3b",
    "granite-moe-3b-a800m",
    "llama4-maverick-400b-a17b",
}


def _arch_params(ids):
    return [
        pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a for a in ids
    ]


def _batch(cfg, key, b=2, s=16):
    tok = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    if cfg.input_mode == "frames":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", _arch_params(configs.ARCH_IDS))
def test_train_step_smoke(arch_id, key):
    cfg = configs.get_smoke(arch_id)
    spec = lm.build_spec(cfg)
    params = lm.init_params(spec, key)
    batch = _batch(cfg, key)
    loss, metrics = jax.jit(lambda p, b: lm.loss_fn(spec, p, b))(params, batch)
    assert np.isfinite(float(loss)), f"{arch_id}: loss {loss}"
    assert np.isfinite(float(metrics["xent"]))
    # grads exist and are finite for every param
    g = jax.grad(lambda p: lm.loss_fn(spec, p, batch)[0])(params)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), f"{arch_id}: NaN grad at {path}"


@pytest.mark.parametrize("arch_id", _arch_params(configs.ARCH_IDS))
def test_prefill_decode_smoke(arch_id, key):
    cfg = configs.get_smoke(arch_id)
    spec = lm.build_spec(cfg)
    params = lm.init_params(spec, key)
    batch = _batch(cfg, key)
    logits, cache = lm.prefill(spec, params, batch, s_max=24)
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(tok.max()) < cfg.vocab, "padded-vocab logits must never win argmax"
    logits2, cache = lm.decode_step(spec, params, tok, cache)
    assert logits2.shape == (2, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert int(cache["pos"]) == 17


@pytest.mark.parametrize(
    "arch_id",
    _arch_params(["granite-3-2b", "zamba2-7b", "rwkv6-3b", "seamless-m4t-medium"]),
)
def test_decode_matches_prefill(arch_id, key):
    """Teacher-forced forward at position t == prefill(t-1) + decode(1)."""
    cfg = configs.get_smoke(arch_id).replace(compute_dtype="float32")
    spec = lm.build_spec(cfg)
    params = lm.init_params(spec, key)
    b = _batch(cfg, key, b=2, s=12)
    lp, cache = lm.prefill(spec, params, b, s_max=16)
    nxt = jnp.argmax(lp, -1).astype(jnp.int32)
    ld, _ = lm.decode_step(spec, params, nxt, cache)
    b2 = dict(b)
    # decoder tokens extend; encoder frames (if any) stay fixed
    b2["tokens"] = jnp.concatenate([b["tokens"], nxt[:, None]], axis=1)
    lp2, _ = lm.prefill(spec, params, b2, s_max=16)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lp2), rtol=1e-4, atol=1e-4)


def test_all_cells_enumerated():
    cells = configs.all_cells()
    assert len(cells) == 32  # 40 assigned minus 8 documented long_500k skips
    long_archs = {a for a, s in cells if s.name == "long_500k"}
    assert long_archs == {"zamba2-7b", "rwkv6-3b"}


def test_param_counts_match_billing():
    """Full configs land near their advertised parameter counts."""
    expect = {
        "granite-3-2b": (2.0, 3.1),
        "qwen2-1.5b": (1.3, 1.9),
        "deepseek-67b": (60, 70),
        "stablelm-1.6b": (1.4, 1.9),
        "zamba2-7b": (6.3, 7.7),
        "llama4-maverick-400b-a17b": (380, 420),
        "granite-moe-3b-a800m": (2.9, 3.7),
        "rwkv6-3b": (2.7, 3.4),
        "chameleon-34b": (30, 38),
        "seamless-m4t-medium": (0.8, 1.6),
    }
    for aid, (lo, hi) in expect.items():
        cfg = configs.get_config(aid)
        spec = lm.build_spec(cfg)
        shapes = jax.eval_shape(lambda k: lm.init_params(spec, k), jax.random.PRNGKey(0))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes)) / 1e9
        assert lo <= n <= hi, f"{aid}: {n:.2f}B params outside [{lo}, {hi}]"
