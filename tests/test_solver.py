"""Pluggable solver subsystem: driver, methods, stopping, telemetry.

The acceptance bars (ISSUE 5): Chebyshev and adaptive Richardson stay
allclose (rtol <= 1e-4) to the fixed-q Richardson baseline on 1x1 AND 2x2
meshes, resident and out-of-core; and at equal tolerance Chebyshev reads
strictly fewer (>= 1.5x fewer) scratch bytes than Richardson on an
out-of-core solve.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    CommuteConfig,
    SolverSpec,
    chain_product,
    detect_anomalies,
    estimate_rho,
    estimate_solution,
    residual_norm,
    reset_stream_stats,
    solve,
    stream_stats,
)
from repro.core import laplacian as lap
from repro.core.solvers import SolveReport, iters_from_delta
from repro.core.solvers.driver import deflate_constant
from repro.graphs import gmm_graph_sequence
from repro.store import TileStore


@pytest.fixture(params=["ctx1", "ctx22"])
def ctx(request):
    return request.getfixturevalue(request.param)


def _clustered(ctx, n=64, seed=0):
    """GMM similarity graph: well-separated clusters -> lambda_2 near 1, so
    the solve actually needs iterations (rho(S^{2^d}) stays substantial)."""
    return gmm_graph_sequence(ctx, n=n, seed=seed).a1


def _rhs(ctx, n, k=4, seed=0):
    b = np.random.default_rng(seed).normal(size=(n, k)).astype(np.float32)
    b -= b.mean(0, keepdims=True)
    return ctx.put_rowblock(b)


# ---------------------------------------------------------------------------
# spec / contract
# ---------------------------------------------------------------------------


def test_delta_derives_paper_iteration_bound():
    """q = ceil(log 1/delta): the paper default delta=1e-4 gives q=10, i.e.
    9 refinement steps -- matching the CommuteConfig default q."""
    assert iters_from_delta(1e-4) == 10
    assert SolverSpec(delta=1e-4).max_steps() == 9
    assert SolverSpec(delta=0.5).max_steps() == 1
    # precedence: explicit cap > delta > tolerance cap > fixed q
    assert SolverSpec(max_iters=3, delta=1e-4).max_steps() == 3
    assert SolverSpec(tolerance=1e-6).max_steps() == 300
    assert SolverSpec().max_steps(fixed_q=7) == 6
    with pytest.raises(ValueError, match="delta"):
        SolverSpec(delta=1.5)
    with pytest.raises(ValueError, match="solver"):
        SolverSpec(method="conjugate_gradient")


def test_commute_config_builds_spec():
    cfg = CommuteConfig(solver="chebyshev", solver_tol=1e-5, delta=1e-3)
    spec = cfg.solver_spec()
    assert spec.method == "chebyshev"
    assert spec.tolerance == 1e-5
    assert spec.max_steps() == iters_from_delta(1e-3) - 1


def test_rho_cached_on_operator_and_survives_pytree(ctx1):
    a = _clustered(ctx1)
    op = chain_product(ctx1, a, d_len=4, schedule="xla")
    assert op.rho is not None and 0.0 < op.rho < 1.0
    leaves, treedef = jax.tree_util.tree_flatten(op)
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert op2.rho == op.rho and op2.prefetch_depth == op.prefetch_depth
    # the direct estimator agrees with the build-time cache (same seed/iters)
    assert estimate_rho(ctx1, op.p2) == pytest.approx(op.rho)


def test_fixed_q_shim_matches_driver_contract(ctx1):
    """estimate_solution(q) is the fixed-iteration driver: q=1 returns chi
    exactly (zero refinement steps), and the report counts q-1 mat-vecs."""
    from repro.core.distmatrix import matmul_rowblock

    a = _clustered(ctx1)
    op = chain_product(ctx1, a, d_len=4, schedule="xla")
    b = _rhs(ctx1, 64)
    chi = deflate_constant(ctx1, matmul_rowblock(ctx1, op.p1, b))
    np.testing.assert_array_equal(
        np.asarray(estimate_solution(ctx1, op, b, q_iters=1)), np.asarray(chi)
    )
    _, rep = solve(ctx1, op, b, SolverSpec(), fixed_q=6)
    assert rep.iterations == 5 and rep.converged and rep.method == "richardson"
    with pytest.raises(ValueError, match="q must be"):
        estimate_solution(ctx1, op, b, q_iters=0)


# ---------------------------------------------------------------------------
# solver equivalence: adaptive richardson + chebyshev vs fixed-q baseline,
# 1x1 AND 2x2 meshes, resident AND out-of-core
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("storage", ["resident", "oocore"])
def test_methods_allclose_to_fixed_q_baseline(ctx, storage):
    n, d, tol = 64, 5, 3e-5
    a = _clustered(ctx, n)
    if storage == "oocore":
        store = TileStore.create(None, n=n, grid=8)
        src = store.put_snapshot("a", np.asarray(a))
    else:
        src = a
    op = chain_product(ctx, src, d, schedule="xla", oocore=storage == "oocore")
    b = _rhs(ctx, n)

    sols, reports = {}, {}
    for method in ("richardson", "chebyshev", "cg"):
        sols[method], reports[method] = solve(
            ctx, op, b, SolverSpec(method=method, tolerance=tol)
        )
        assert reports[method].converged, reports[method]
        assert reports[method].streamed == (storage == "oocore")
    # fixed-q baseline at the adaptive Richardson iteration count
    q_fix = reports["richardson"].iterations + 1
    ref = np.asarray(estimate_solution(ctx, op, b, q_fix))
    for method, x in sols.items():
        np.testing.assert_allclose(
            np.asarray(x), ref, rtol=1e-4, atol=1e-3, err_msg=method
        )
    # the accelerators actually accelerated (rho is large on this graph)
    assert reports["chebyshev"].iterations < reports["richardson"].iterations
    assert reports["cg"].iterations < reports["chebyshev"].iterations
    op.release_scratch()


def test_chebyshev_cuts_oocore_iterations_and_scratch_bytes(ctx1):
    """Acceptance: at equal tolerance, Chebyshev reduces BOTH the iteration
    count and stream_stats().bytes_read of an out-of-core solve by >= 1.5x,
    and strictly reads fewer scratch bytes than Richardson."""
    n, d, tol = 64, 4, 1e-5
    store = TileStore.create(None, n=n, grid=8)
    h = store.put_snapshot("a", np.asarray(_clustered(ctx1, n)))
    op = chain_product(ctx1, h, d, oocore=True)
    b = _rhs(ctx1, n)

    bread, reports = {}, {}
    for method in ("richardson", "chebyshev"):
        reset_stream_stats()
        _, rep = solve(ctx1, op, b, SolverSpec(method=method, tolerance=tol))
        bread[method] = stream_stats().bytes_read
        reports[method] = rep
        assert rep.converged, rep
        # the report's own counters agree with the global stats delta
        assert rep.bytes_read == bread[method]
    op.release_scratch()
    r, c = reports["richardson"], reports["chebyshev"]
    assert r.iterations >= 1.5 * c.iterations, (r.iterations, c.iterations)
    assert bread["richardson"] >= 1.5 * bread["chebyshev"], bread
    assert bread["chebyshev"] < bread["richardson"]  # strictly fewer


def test_chebyshev_solver_batch_replays_bitwise(ctx1):
    """Iteration batching composes with Chebyshev: CachingHandle replays are
    bitwise, so solver_batch cannot change the accelerated solution either."""
    n = 64
    store = TileStore.create(None, n=n, grid=8)
    h = store.put_snapshot("a", np.asarray(_clustered(ctx1, n)))
    op = chain_product(ctx1, h, 4, oocore=True)
    b = _rhs(ctx1, n)
    sols, reads = {}, {}
    for batch in (1, 4):
        reset_stream_stats()
        x, _ = solve(
            ctx1, op, b, SolverSpec(method="chebyshev", tolerance=1e-5),
            solver_batch=batch,
        )
        sols[batch], reads[batch] = np.asarray(x), stream_stats().bytes_read
    op.release_scratch()
    np.testing.assert_array_equal(sols[1], sols[4])
    assert reads[4] < reads[1]


def test_scores_allclose_and_telemetry_end_to_end(ctx1):
    """End-to-end acceptance: chebyshev-to-tolerance scores allclose
    (rtol <= 1e-4) to the fixed-q Richardson baseline, and the CADResult
    carries both endpoints' SolveReports."""
    seq = gmm_graph_sequence(ctx1, n=64, seed=3, inject_p=0.02)
    base = CommuteConfig(eps_rp=1e-2, d=5, q=61, schedule="xla", k_override=4)
    cheb = CommuteConfig(
        eps_rp=1e-2, d=5, q=61, schedule="xla", k_override=4,
        solver="chebyshev", solver_tol=1e-5,
    )
    res_base = detect_anomalies(ctx1, seq.a1, seq.a2, base, top_k=5)
    res_cheb = detect_anomalies(ctx1, seq.a1, seq.a2, cheb, top_k=5)
    np.testing.assert_allclose(
        np.asarray(res_cheb.scores), np.asarray(res_base.scores),
        rtol=1e-4, atol=1e-3,
    )
    assert len(res_cheb.solve_reports) == 2
    for rep in res_cheb.solve_reports:
        assert isinstance(rep, SolveReport)
        assert rep.method == "chebyshev" and rep.converged
        assert rep.iterations < 60  # far under the fixed-q worst case
    for rep in res_base.solve_reports:
        assert rep.method == "richardson" and rep.iterations == 60


# ---------------------------------------------------------------------------
# residual_norm over a store-backed Laplacian (adaptive stopping oocore)
# ---------------------------------------------------------------------------


def test_residual_norm_streamed_matches_resident(ctx):
    n = 64
    a = _clustered(ctx, n)
    deg = lap.degrees(ctx, a)
    l_mat = lap.laplacian(ctx, a, deg)
    store = TileStore.create(None, n=n, grid=8)
    l_handle = store.put_snapshot("L", np.asarray(l_mat))

    op = chain_product(ctx, a, d_len=6, schedule="xla")
    b = _rhs(ctx, n)
    x = estimate_solution(ctx, op, b, q_iters=8)
    r_res = float(residual_norm(ctx, l_mat, x, b))
    r_str = float(residual_norm(ctx, l_handle, x, b, prefetch_depth=2))
    assert r_str == pytest.approx(r_res, rel=1e-5)
    # sanity: the metric is meaningful (solver actually reduced the residual)
    assert r_res < 0.5


# ---------------------------------------------------------------------------
# release_scratch diagnosability
# ---------------------------------------------------------------------------


def test_release_scratch_warns_on_store_failure(ctx1, monkeypatch):
    n = 32
    store = TileStore.create(None, n=n, grid=4)
    h = store.put_snapshot("a", np.asarray(_clustered(ctx1, n)))
    op = chain_product(ctx1, h, 3, oocore=True)
    work = op.p1.store

    def wedged(snap_id):
        raise OSError("scratch dir wedged")

    monkeypatch.setattr(work, "remove_snapshot", wedged)
    with pytest.warns(RuntimeWarning, match="scratch"):
        op.release_scratch()
    monkeypatch.undo()
    op.release_scratch()  # real removal still works afterwards
    assert not [s for s in work.snapshot_ids if "P1" in s or "P2" in s]


def test_release_scratch_raises_on_unexpected_error(ctx1, monkeypatch):
    """Only the expected store errors are swallowed -- a genuine bug (wrong
    type, attribute error) must surface, not vanish into a warning."""
    n = 32
    store = TileStore.create(None, n=n, grid=4)
    h = store.put_snapshot("a", np.asarray(_clustered(ctx1, n)))
    op = chain_product(ctx1, h, 3, oocore=True)

    def buggy(snap_id):
        raise TypeError("programming error")

    monkeypatch.setattr(op.p1.store, "remove_snapshot", buggy)
    with pytest.raises(TypeError):
        op.release_scratch()


# ---------------------------------------------------------------------------
# warm starts: y0 seeds the solve, cold and warm share one compiled program
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["richardson", "chebyshev", "cg"])
def test_warm_start_from_solution_converges_immediately(ctx, method):
    """Seeding y0 with the converged solution collapses the solve to <= 2
    steps (the first measured residual is already under tolerance) while the
    warm solution stays allclose to the cold one -- warm starting changes the
    iteration count, never the answer."""
    a = _clustered(ctx)
    op = chain_product(ctx, a, d_len=4, schedule="xla")
    b = _rhs(ctx, 64)
    tol = 1e-5
    cold, rep_c = solve(ctx, op, b, SolverSpec(method=method, tolerance=tol))
    warm, rep_w = solve(ctx, op, b, SolverSpec(method=method, tolerance=tol), y0=cold)
    assert rep_c.converged and not rep_c.warm_start
    assert rep_w.converged and rep_w.warm_start
    assert rep_w.iterations <= 2 < rep_c.iterations
    np.testing.assert_allclose(
        np.asarray(warm), np.asarray(cold), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("method", ["chebyshev", "cg"])
def test_warm_start_streamed(ctx1, method):
    """Out-of-core warm start: the streamed solve accepts y0 too, and a
    solve seeded with the resident solution converges in <= 2 passes."""
    n = 64
    store = TileStore.create(None, n=n, grid=8)
    a = _clustered(ctx1, n)
    h = store.put_snapshot("a", np.asarray(a))
    op_res = chain_product(ctx1, a, 4, schedule="xla")
    op_str = chain_product(ctx1, h, 4, oocore=True)
    b = _rhs(ctx1, n)
    cold, _ = solve(ctx1, op_res, b, SolverSpec(method=method, tolerance=1e-5))
    warm, rep = solve(
        ctx1, op_str, b, SolverSpec(method=method, tolerance=1e-5), y0=cold
    )
    op_str.release_scratch()
    assert rep.streamed and rep.warm_start and rep.converged
    assert rep.iterations <= 2
    np.testing.assert_allclose(
        np.asarray(warm), np.asarray(cold), rtol=1e-4, atol=1e-5
    )


def test_warm_start_shape_mismatch_raises(ctx1):
    a = _clustered(ctx1)
    op = chain_product(ctx1, a, d_len=4, schedule="xla")
    b = _rhs(ctx1, 64, k=4)
    bad = _rhs(ctx1, 64, k=3)
    with pytest.raises(ValueError, match="warm start"):
        solve(ctx1, op, b, SolverSpec(tolerance=1e-5), y0=bad)


# ---------------------------------------------------------------------------
# adaptive Chebyshev interval (Manteuffel-style)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("storage", ["resident", "oocore"])
def test_chebyshev_adapts_underestimated_interval(ctx1, storage):
    """An operator carrying a badly underestimated rho used to stall or
    diverge Chebyshev; the adaptive interval grows it from the measured
    contraction and the solve still converges to the same answer.  A correct
    rho must NOT adapt (rho_final == rho)."""
    import dataclasses

    n, tol = 64, 1e-5
    a = _clustered(ctx1, n)
    if storage == "oocore":
        store = TileStore.create(None, n=n, grid=8)
        src = store.put_snapshot("a", np.asarray(a))
    else:
        src = a
    op = chain_product(ctx1, src, 4, schedule="xla", oocore=storage == "oocore")
    b = _rhs(ctx1, n)
    ref, rep_ref = solve(ctx1, op, b, SolverSpec(method="chebyshev", tolerance=tol))
    assert rep_ref.converged
    assert rep_ref.rho_final == pytest.approx(rep_ref.rho)  # no false trigger

    op_lo = dataclasses.replace(op, rho=0.5 * op.rho)
    x, rep = solve(ctx1, op_lo, b, SolverSpec(method="chebyshev", tolerance=tol))
    op.release_scratch()
    assert rep.converged, rep
    assert rep.rho_final is not None and rep.rho_final > rep.rho
    np.testing.assert_allclose(
        np.asarray(x), np.asarray(ref), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# non-convergence is reported, not hidden
# ---------------------------------------------------------------------------


def test_unreachable_tolerance_reports_not_converged(ctx1):
    a = _clustered(ctx1)
    op = chain_product(ctx1, a, d_len=4, schedule="xla")
    b = _rhs(ctx1, 64)
    _, rep = solve(
        ctx1, op, b, SolverSpec(method="richardson", tolerance=1e-6, max_iters=3)
    )
    assert rep.iterations == 3 and not rep.converged
    assert rep.max_iters == 3 and rep.residual > 1e-6


@pytest.mark.parametrize("storage", ["resident", "oocore"])
def test_zero_iteration_budget_reports_no_residual(ctx1, storage):
    """max_iters=0 measures nothing: the report must say NaN residual and
    converged=False (it used to claim residual 0.0 / converged=True)."""
    import math

    n = 32
    a = _clustered(ctx1, n)
    if storage == "oocore":
        store = TileStore.create(None, n=n, grid=4)
        src = store.put_snapshot("a", np.asarray(a))
    else:
        src = a
    op = chain_product(ctx1, src, 3, schedule="xla", oocore=storage == "oocore")
    b = _rhs(ctx1, n)
    y, rep = solve(
        ctx1, op, b, SolverSpec(method="richardson", tolerance=1e-5, max_iters=0)
    )
    op.release_scratch()
    assert rep.iterations == 0
    assert math.isnan(rep.residual)
    assert not rep.converged
    assert rep.residuals == ()
    assert np.asarray(y).shape == (n, 4)  # still returns chi


# ---------------------------------------------------------------------------
# residual-history ring buffer
# ---------------------------------------------------------------------------


def test_residual_history_rotates_past_ring_capacity(ctx1, monkeypatch):
    """Runs longer than the ring capacity must return the last CAP residuals
    in chronological order -- the raw buffer is rotated by iters mod cap
    (it used to come back unrotated, splicing newest and oldest entries)."""
    from repro.core.solvers import driver as drv

    a = _clustered(ctx1)
    op = chain_product(ctx1, a, d_len=4, schedule="xla")
    b = _rhs(ctx1, 64)
    spec = SolverSpec(method="richardson", tolerance=1e-30, max_iters=20)
    _, full = solve(ctx1, op, b, spec)
    assert len(full.residuals) == 20
    assert full.residuals[-1] == pytest.approx(full.residual)

    # RES_HIST_CAP is part of the program cache key, so shrinking it compiles
    # a fresh program rather than replaying the stale 512-slot one.
    monkeypatch.setattr(drv, "RES_HIST_CAP", 8)
    _, small = solve(ctx1, op, b, spec)
    assert len(small.residuals) == 8
    np.testing.assert_array_equal(
        np.asarray(small.residuals), np.asarray(full.residuals[-8:])
    )
    assert small.residuals[-1] == pytest.approx(small.residual)


# ---------------------------------------------------------------------------
# full bench grid (weekly CI): richardson vs chebyshev x resident/oocore x mesh
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_bench_solver_grid_passes():
    import pathlib
    import sys

    root = pathlib.Path(__file__).resolve().parents[1]
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    from benchmarks.bench_solver import run

    res = run(n=96, d=4, tol=1e-5, out=lambda *a, **k: None)
    assert res["verdicts"], "no oocore verdicts produced"
    assert res["all_pass"], res["verdicts"]
