"""Distribution invariants: sharded == single-device results, multi-pod
rules, spec sanitization, compressed gradient sync."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_cpu_mesh
from repro.models import common as cm
from repro.models import lm
from repro.models.common import ArchConfig
from repro.training import OptConfig, make_train_step
from repro.training.train_step import (
    compressed_pod_allreduce,
    init_state,
)

TINY = ArchConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, remat=False, compute_dtype="float32",
)


def _batch(b=4, s=32):
    key = jax.random.PRNGKey(0)
    tok = jax.random.randint(key, (b, s), 0, 256)
    return {"tokens": tok, "labels": tok}


@pytest.mark.slow
def test_loss_invariant_to_mesh(mesh22):
    """Same params + batch -> same loss on 1x1 and 2x2 meshes."""
    spec = lm.build_spec(TINY)
    batch = _batch()
    ocfg = OptConfig(lr=1e-3)
    losses = {}
    for mesh in (make_cpu_mesh(1, 1), mesh22):
        step, *_ = make_train_step(spec, mesh, ocfg, donate=False)
        params, opt = init_state(spec, mesh, ocfg, seed=0)
        with mesh:
            _, _, m = step(params, opt, batch)
        losses[mesh.devices.size] = float(m["loss"])
    assert losses[1] == pytest.approx(losses[4], rel=1e-5)


def test_multipod_rules_train_step(mesh_pod):
    """Train step lowers + runs on a (pod, data, model) mesh."""
    spec = lm.build_spec(TINY)
    ocfg = OptConfig(lr=1e-3)
    step, *_ = make_train_step(spec, mesh_pod, ocfg, donate=False)
    params, opt = init_state(spec, mesh_pod, ocfg)
    with mesh_pod:
        _, _, m = step(params, opt, _batch(b=8))
    assert np.isfinite(float(m["loss"]))


def test_sanitize_spec_drops_nondivisible(mesh22):
    s = cm.sanitize_spec(P("model", "data"), (6, 4), mesh22)  # 6 % 2 == 0 ok
    assert tuple(s) == ("model", "data")
    s = cm.sanitize_spec(P("model", "data"), (5, 4), mesh22)  # 5 % 2 != 0
    assert tuple(s) == (None, "data")
    s = cm.sanitize_spec(P(("data", "model"), None), (6, 4), mesh22)  # 6 % 4
    assert tuple(s) == (None, None)


def test_constrain_safe_without_mesh():
    x = jnp.ones((4, 4))
    out = cm.constrain(x, ("batch", None), dict(cm.DEFAULT_RULES))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_compressed_pod_allreduce(mesh_pod):
    """int8 error-feedback sync: mean over pods within quantization error,
    residual carries the rounding for the next step."""
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(2, 64)).astype(np.float32))}
    ef = {"w": jnp.zeros((64,), jnp.float32)}

    def f(g, e):
        return compressed_pod_allreduce(g, e, axis="pod")

    g_sharded = {"w": grads["w"]}
    from repro.core.tiles import shard_map

    out, new_ef = jax.jit(
        shard_map(
            f,
            mesh=mesh_pod,
            in_specs=({"w": P("pod", None)}, {"w": P()}),
            out_specs=({"w": P("pod", None)}, {"w": P("pod", None)}),
            check=False,
        )
    )(g_sharded, ef)
    # each pod's synced grad == mean over pods (within int8 error)
    expect = grads["w"].reshape(2, 64).mean(axis=0)
    got = np.asarray(out["w"])
    for podrow in got.reshape(2, 64):
        np.testing.assert_allclose(podrow, expect, atol=0.05)
    # error feedback residual = local grad - dequantized local grad
    assert np.all(np.isfinite(np.asarray(new_ef["w"])))


def test_param_specs_cover_all_leaves():
    spec = lm.build_spec(TINY)
    pspecs = lm.param_specs(spec, cm.DEFAULT_RULES)
    pshape = jax.eval_shape(lambda k: lm.init_params(spec, k), jax.random.PRNGKey(0))
    sl, pl = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P)), jax.tree.leaves(pshape)
    assert len(sl) == len(pl)
    for s, p in zip(sl, pl):
        assert len(tuple(s)) <= p.ndim


def test_seqshard_rules_same_loss(mesh22):
    """The seq-sharded (ring-attention-style) preset computes the SAME loss
    as the baseline rules -- a pure re-sharding, not a math change."""
    from repro.launch.dryrun import seqshard_rules

    spec = lm.build_spec(TINY)
    params = lm.init_params(spec, jax.random.PRNGKey(3))
    batch = _batch(b=4, s=32)
    base = cm.attach_axis_sizes(dict(cm.DEFAULT_RULES), mesh22)
    seqs = cm.attach_axis_sizes(seqshard_rules(mesh22), mesh22)
    with mesh22:
        l0, _ = jax.jit(lambda p, b: lm.loss_fn(spec, p, b, rules=base))(params, batch)
        l1, _ = jax.jit(lambda p, b: lm.loss_fn(spec, p, b, rules=seqs))(params, batch)
    assert float(l0) == pytest.approx(float(l1), rel=1e-5)


def test_fsdp_rules_same_loss(mesh22):
    """The ZeRO-3 full-flat-batch preset is numerically identical too."""
    from repro.launch.dryrun import fsdp_rules

    spec = lm.build_spec(TINY)
    params = lm.init_params(spec, jax.random.PRNGKey(3))
    batch = _batch(b=4, s=32)
    base = cm.attach_axis_sizes(dict(cm.DEFAULT_RULES), mesh22)
    fs = cm.attach_axis_sizes(fsdp_rules(mesh22), mesh22)
    with mesh22:
        l0, _ = jax.jit(lambda p, b: lm.loss_fn(spec, p, b, rules=base))(params, batch)
        l1, _ = jax.jit(lambda p, b: lm.loss_fn(spec, p, b, rules=fs))(params, batch)
    assert float(l0) == pytest.approx(float(l1), rel=1e-5)
