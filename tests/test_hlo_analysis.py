"""Trip-count-aware HLO analysis: scanned == unrolled programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as ha


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_equals_unroll_flops():
    def f_scan(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    def f_unroll(x, w):
        h = x
        for _ in range(10):
            h = jnp.tanh(h @ w)
        return h

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    rs = ha.analyze(_compile(f_scan, x, w))
    ru = ha.analyze(_compile(f_unroll, x, w))
    assert rs["dot_flops"] == ru["dot_flops"] == 20 * 256**3


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(h, _):
            def inner(h2, _):
                return h2 @ w, None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = ha.analyze(_compile(f, x, w))
    assert r["dot_flops"] == 15 * 2 * 128**3


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    r = ha.analyze(_compile(f, a, b))
    assert r["dot_flops"] == 2 * 4 * 64 * 32 * 16


def test_cost_analysis_undercounts_loops():
    """Documents WHY this module exists: XLA counts while bodies once."""
    def f_scan(x, w):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f_scan).lower(x, w).compile()
    ca = c.cost_analysis()
    ca = ca if isinstance(ca, dict) else ca[0]
    assert ca["flops"] == pytest.approx(2 * 128**3, rel=0.01)  # one body!
    assert ha.analyze(c.as_text())["dot_flops"] == 10 * 2 * 128**3
