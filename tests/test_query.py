"""Query-scale read path: EmbeddingStore artifacts, the fused distance/top-k
kernel, and the query API -- pinned against the exact eigendecomposition
oracle and brute-force numpy on 1x1 AND 2x2 meshes.
"""

import numpy as np
import pytest

from repro.core import CommuteConfig, SequenceDetector
from repro.core.embedding import (
    commute_distance_block,
    commute_time_embedding,
    exact_commute_distances,
)
from repro.core.query import (
    commute_block,
    nearest_neighbors,
    rank_auc,
    top_anomalies_from_store,
)
from repro.graphs import gmm_graph_sequence, gmm_snapshot_sequence
from repro.obs import REGISTRY
from repro.store.embstore import EmbeddingStore

CFG = CommuteConfig(eps_rp=1e-3, d=8, q=12, schedule="xla", k_override=64)


def _publish(ctx, n=128, *, root=None, codec="raw", seed_graph=0):
    """One embedding pushed through the detector into a store; returns
    (store, resident Embedding, adjacency)."""
    seq = gmm_graph_sequence(ctx, n, seed=seed_graph, inject_p=0.02)
    emb = commute_time_embedding(ctx, seq.a1, CFG)
    store = EmbeddingStore.create(
        root, n=n, k=CFG.k_override, codec=codec, seed=CFG.seed
    )
    store.put_embedding("t0000", emb.z, emb.vol, emb.op.deg)
    return store, emb, np.asarray(seq.a1)


# ---------------------------------------------------------------------------
# EmbeddingStore artifact lifecycle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["raw", "bf16"])
def test_embstore_roundtrip(tmp_path, codec):
    rng = np.random.default_rng(0)
    n, k = 96, 16
    z = rng.normal(size=(n, k)).astype(np.float32)
    deg = rng.uniform(1.0, 3.0, size=n).astype(np.float32)
    store = EmbeddingStore.create(
        tmp_path, n=n, k=k, codec=codec, seed=3, panel_rows=32
    )
    store.put_embedding("t0000", z, 123.5, deg)

    reopened = EmbeddingStore.open(tmp_path)
    h = reopened.embedding("t0000")
    assert h.shape == (n, k)
    tol = dict(rtol=1e-2, atol=1e-2) if codec == "bf16" else dict(rtol=0, atol=0)
    np.testing.assert_allclose(h.to_numpy(), z, **tol)
    np.testing.assert_allclose(h.deg, deg)
    assert h.vol == 123.5
    np.testing.assert_allclose(h.zbar, z.mean(0), rtol=1e-2, atol=1e-2)
    rows = [0, 17, n - 1]
    np.testing.assert_allclose(h.read_rows(rows), z[rows], **tol)
    # panels round through the (row0, height) protocol PanelPipeline speaks
    pr = h.panel_rows
    np.testing.assert_allclose(h.read_panel(pr, pr), z[pr : 2 * pr], **tol)


def test_embstore_bf16_stored_form_is_half_width(tmp_path):
    z = np.random.default_rng(1).normal(size=(64, 8)).astype(np.float32)
    store = EmbeddingStore.create(tmp_path, n=64, k=8, codec="bf16")
    store.put_embedding("t0000", z, 1.0, np.ones(64))
    stored = store.read_panel_stored("t0000", 0)
    assert stored.dtype == np.uint16
    assert stored.nbytes * 2 == z[: store.panel_rows].nbytes


def test_embstore_fingerprint_mismatch_rejected(tmp_path):
    EmbeddingStore.create(tmp_path, n=64, k=8, seed=0)
    with pytest.raises(ValueError, match="fingerprint"):
        EmbeddingStore.create(tmp_path, n=64, k=16, seed=0)  # different k
    with pytest.raises(ValueError, match="fingerprint"):
        EmbeddingStore.create(tmp_path, n=64, k=8, seed=1)  # different sketch


def test_embstore_commit_on_complete(tmp_path):
    """An artifact is served only once every panel AND the aux sidecar exist;
    a torn publish (missing aux) never reaches the manifest."""
    store = EmbeddingStore.create(tmp_path, n=64, k=8)
    z = np.zeros((64, 8), np.float32)
    stored = store.codec.encode(z[: store.panel_rows])
    store._store_panel("torn", 0, np.asarray(stored))  # crash before aux
    with pytest.raises(ValueError, match="incomplete"):
        store._commit("torn")
    assert "torn" not in store.embedding_ids
    with pytest.raises(KeyError):
        store.embedding("torn")
    # resume: put_embedding completes the torn publish in place
    h = store.put_embedding("torn", z, 1.0, np.ones(64))
    assert h.emb_id in store.embedding_ids


def test_embstore_rejects_tilestore_dir(tmp_path):
    from repro.store import TileStore

    TileStore.create(tmp_path / "tiles", n=64, grid=2)
    with pytest.raises(ValueError):
        EmbeddingStore.open(tmp_path / "tiles")


# ---------------------------------------------------------------------------
# query path vs oracle / brute force (1x1 and 2x2 meshes)
# ---------------------------------------------------------------------------


def _ctx(request, name):
    return request.getfixturevalue(name)


@pytest.mark.parametrize("ctxname", ["ctx1", "ctx22"])
def test_store_commute_block_matches_resident(request, ctxname, tmp_path):
    ctx = _ctx(request, ctxname)
    store, emb, _ = _publish(ctx, root=tmp_path)
    rows, cols = np.arange(0, 128, 7), np.arange(3, 128, 11)
    resident = np.asarray(commute_distance_block(emb, rows, cols))
    from_store = commute_block(store, rows, cols)
    np.testing.assert_allclose(from_store, resident, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("ctxname", ["ctx1", "ctx22"])
def test_store_block_approximates_exact(request, ctxname, tmp_path):
    """Store-backed distances carry the same oracle accuracy as the resident
    embedding (the artifact adds no error beyond the sketch's own)."""
    ctx = _ctx(request, ctxname)
    store, _, a = _publish(ctx, root=tmp_path)
    exact = np.asarray(exact_commute_distances(a))
    idx = np.arange(128)
    approx = commute_block(store, idx, idx)
    mask = ~np.eye(128, dtype=bool)
    rel = np.abs(approx - exact)[mask] / np.maximum(exact[mask], 1e-9)
    assert np.median(rel) < 0.25, f"median rel err {np.median(rel)}"


@pytest.mark.parametrize("ctxname", ["ctx1", "ctx22"])
@pytest.mark.parametrize("corrected", [False, True])
def test_top_anomalies_matches_bruteforce(request, ctxname, corrected):
    ctx = _ctx(request, ctxname)
    store, _, _ = _publish(ctx)  # RAM-backed
    h = store.latest()
    res = top_anomalies_from_store(store, 12, corrected=corrected)

    z = h.to_numpy().astype(np.float64)
    dist2 = ((z - z.mean(0)) ** 2).sum(1)
    if corrected:
        brute = dist2 - h.inv_deg().mean() - h.inv_deg()
    else:
        brute = h.vol * dist2
    order = np.argsort(-brute)[:12]
    np.testing.assert_allclose(res.val, brute[order], rtol=1e-4, atol=1e-4)
    assert set(res.idx.tolist()) == set(order.tolist())
    assert res.panels == 128 // h.panel_rows
    assert res.emb_id == "t0000"


@pytest.mark.parametrize("ctxname", ["ctx1", "ctx22"])
def test_nearest_neighbors_matches_bruteforce(request, ctxname):
    ctx = _ctx(request, ctxname)
    store, _, _ = _publish(ctx)
    h = store.latest()
    node = 41
    res = nearest_neighbors(store, node, 8)

    z = h.to_numpy().astype(np.float64)
    d = h.vol * ((z - z[node]) ** 2).sum(1)
    d[node] = np.inf  # self excluded in-kernel
    order = np.argsort(d)[:8]
    np.testing.assert_allclose(res.val, d[order], rtol=1e-4, atol=1e-3)
    assert set(res.idx.tolist()) == set(order.tolist())
    assert node not in res.idx


def test_bf16_artifact_query_close_to_raw(ctx1, tmp_path):
    store_raw, emb, _ = _publish(ctx1, root=tmp_path / "raw")
    store_bf16 = EmbeddingStore.create(
        tmp_path / "bf16", n=128, k=CFG.k_override, codec="bf16", seed=CFG.seed
    )
    store_bf16.put_embedding("t0000", emb.z, emb.vol, emb.op.deg)
    r_raw = top_anomalies_from_store(store_raw, 10)
    r_bf16 = top_anomalies_from_store(store_bf16, 10)
    # half-width storage, same ranking to within bf16 rounding
    assert len(set(r_raw.idx.tolist()) & set(r_bf16.idx.tolist())) >= 8
    np.testing.assert_allclose(r_bf16.val, r_raw.val, rtol=2e-2)
    assert r_bf16.bytes_read < r_raw.bytes_read


def test_topk_larger_than_n_pads_with_minus_one(ctx1):
    store, _, _ = _publish(ctx1, n=64)
    res = top_anomalies_from_store(store, 500)
    assert (res.idx >= 0).sum() == 64
    assert len(res.idx) == 64  # clamped to n, not padded past it


def test_query_registry_counters(ctx1):
    store, _, _ = _publish(ctx1)
    m0 = REGISTRY.snapshot()
    top_anomalies_from_store(store, 5)
    d = REGISTRY.delta(m0)
    assert d.get("query.calls") == 1
    assert d.get("query.panels", 0) >= 1
    assert d.get("query.bytes_read", 0) > 0
    assert d.get("query.latency_ms", 0) > 0


# ---------------------------------------------------------------------------
# index validation + warm-start satellites
# ---------------------------------------------------------------------------


def test_commute_distance_block_rejects_bad_indices(ctx1):
    seq = gmm_graph_sequence(ctx1, 32, seed=0)
    cfg = CommuteConfig(eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4)
    emb = commute_time_embedding(ctx1, seq.a1, cfg)
    with pytest.raises(IndexError, match=r"rows index 32 .*n=32"):
        commute_distance_block(emb, np.array([0, 32]), np.array([1]))
    with pytest.raises(IndexError, match=r"cols index -33 .*n=32"):
        commute_distance_block(emb, np.array([0]), np.array([-33]))


def test_store_queries_reject_bad_indices(ctx1):
    store, _, _ = _publish(ctx1, n=64)
    with pytest.raises(IndexError, match=r"node index 64 .*n=64"):
        nearest_neighbors(store, 64)
    with pytest.raises(IndexError, match=r"rows index 99 .*n=64"):
        commute_block(store, [99], [0])


def test_warm_from_shape_mismatch_warns_and_counts(ctx1):
    seq = gmm_graph_sequence(ctx1, 32, seed=0)
    cfg = CommuteConfig(eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4)
    stale = np.zeros((32, 9), np.float32)  # k changed mid-stream
    m0 = REGISTRY.snapshot()
    with pytest.warns(RuntimeWarning, match="warm_from shape"):
        emb = commute_time_embedding(ctx1, seq.a1, cfg, warm_from=stale)
    assert REGISTRY.delta(m0).get("solve.warm_skipped") == 1
    assert emb.z.shape == (32, 4)  # cold solve still delivered


# ---------------------------------------------------------------------------
# labeled fixture + rank AUC
# ---------------------------------------------------------------------------


def test_labeled_fixture_plants_outliers(ctx1):
    seq = gmm_snapshot_sequence(ctx1, 64, 2, seed=0, anomaly_nodes=5, dim_nodes=6)
    assert seq.labels is not None and seq.labels.sum() == 5
    plain = gmm_snapshot_sequence(ctx1, 64, 2, seed=0)
    assert plain.labels is None
    # the clump is structurally planted: snapshot builds still work sharded
    a = np.asarray(next(iter(seq.snapshots())))
    assert a.shape == (64, 64) and np.isfinite(a).all()


def test_rank_auc():
    labels = np.array([0, 0, 0, 1, 1])
    assert rank_auc(labels, np.array([0.1, 0.2, 0.3, 0.8, 0.9])) == 1.0
    assert rank_auc(labels, np.array([0.9, 0.8, 0.7, 0.2, 0.1])) == 0.0
    assert rank_auc(labels, np.ones(5)) == 0.5  # all tied
    with pytest.raises(ValueError):
        rank_auc(np.zeros(4), np.arange(4))


def test_detector_publishes_to_store(ctx1, tmp_path):
    cfg = CommuteConfig(eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4)
    store = EmbeddingStore.create(tmp_path, n=32, k=4, seed=cfg.seed)
    seq = gmm_snapshot_sequence(ctx1, 32, 3, seed=0)
    det = SequenceDetector(ctx1, cfg, emb_store=store)
    for a in seq.snapshots():
        det.push(a)
    assert store.embedding_ids == ["t0000", "t0001", "t0002"]
    # the artifact is query-ready straight off the detector
    res = top_anomalies_from_store(store, 3)
    assert (res.idx >= 0).all()
