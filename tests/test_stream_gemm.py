"""Fused Pallas stream-GEMM kernel path: interpret-mode parity + accounting.

Three layers of guarantees, all runnable off-TPU (interpret mode):

* kernel primitives -- ``stream_gemm`` fp32 is *bitwise* the XLA
  ``_gemm_step`` with unblocked K; the in-kernel bf16 bit-pattern decode is
  bitwise the host codec's widening; the fused mat-vec epilogue's residual
  moments satisfy the deflation identity;
* solve parity -- the fused-epilogue streamed solve stays allclose (<= 1e-4)
  to the two-pass XLA driver on 1x1 AND 2x2 meshes, and the raw-codec kernel
  path stays allclose to the fully resident solve;
* traffic accounting -- stored-form bf16 shipping halves solve-phase H2D
  (<= 0.55x the fp32-decode baseline), ``bytes_h2d_saved`` records the gap,
  and each fused iteration makes exactly one pass over the panel stream.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.chain import chain_product
from repro.core.oochain import _gemm_step, _gemm_step_neg
from repro.core.solvers import SolverSpec, solve
from repro.core.tiles import reset_stream_stats, stream_stats
from repro.kernels.stream_gemm import fused_panel_matvec, stream_gemm


def _rng(seed=0):
    return np.random.default_rng(seed)


def _sym(n, seed=0):
    a = _rng(seed).uniform(0.1, 1.0, (n, n)).astype(np.float32)
    a = (a + a.T) / 2
    np.fill_diagonal(a, 0.0)
    return a


def _bf16_bits(x: np.ndarray) -> np.ndarray:
    """Host bf16 round-to-nearest-even encode -> uint16 bit patterns."""
    return np.asarray(jnp.asarray(x).astype(jnp.bfloat16)).view(np.uint16)


def _host_decode(u: np.ndarray) -> np.ndarray:
    return (u.astype(np.uint32) << 16).view(np.float32)


# ---------------------------------------------------------------------------
# kernel primitives
# ---------------------------------------------------------------------------


def test_stream_gemm_fp32_bitwise_vs_xla_step():
    r = _rng(1)
    a = r.normal(size=(32, 48)).astype(np.float32)
    b = r.normal(size=(48, 24)).astype(np.float32)
    init = r.normal(size=(32, 24)).astype(np.float32)
    # whole-dim K block: identical reduction order to the single XLA dot
    got = stream_gemm(jnp.asarray(a), jnp.asarray(b), jnp.asarray(init))
    want = _gemm_step(jnp.asarray(init), jnp.asarray(a), jnp.asarray(b))
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_stream_gemm_negative_sign_bitwise():
    r = _rng(2)
    a = r.normal(size=(16, 32)).astype(np.float32)
    b = r.normal(size=(32, 16)).astype(np.float32)
    init = r.normal(size=(16, 16)).astype(np.float32)
    got = stream_gemm(jnp.asarray(a), jnp.asarray(b), jnp.asarray(init), sign=-1.0)
    want = _gemm_step_neg(jnp.asarray(init), jnp.asarray(a), jnp.asarray(b))
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_stream_gemm_no_init_is_plain_dot():
    r = _rng(3)
    a = r.normal(size=(16, 16)).astype(np.float32)
    b = r.normal(size=(16, 8)).astype(np.float32)
    got = stream_gemm(jnp.asarray(a), jnp.asarray(b))
    want = jnp.dot(jnp.asarray(a), jnp.asarray(b),
                   preferred_element_type=jnp.float32)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_stream_gemm_blocked_k_allclose():
    r = _rng(4)
    a = r.normal(size=(64, 128)).astype(np.float32)
    b = r.normal(size=(128, 32)).astype(np.float32)
    got = stream_gemm(jnp.asarray(a), jnp.asarray(b), bm=32, bk=32, bn=32)
    want = a.astype(np.float64) @ b.astype(np.float64)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_in_kernel_bf16_decode_bitwise_vs_host_codec():
    r = _rng(5)
    a_bits = _bf16_bits(r.normal(size=(32, 64)).astype(np.float32))
    b = r.normal(size=(64, 16)).astype(np.float32)
    got = stream_gemm(jnp.asarray(a_bits), jnp.asarray(b))
    want = jnp.dot(jnp.asarray(_host_decode(a_bits)), jnp.asarray(b),
                   preferred_element_type=jnp.float32)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_fused_panel_matvec_epilogue():
    r = _rng(6)
    ph, n, q = 32, 64, 5
    p = r.normal(size=(ph, n)).astype(np.float32)
    y = r.normal(size=(n, q)).astype(np.float32)
    chi_p = r.normal(size=(ph, q)).astype(np.float32)
    y_p = y[:ph]
    gy, cs, ss = fused_panel_matvec(
        jnp.asarray(p), jnp.asarray(y), jnp.asarray(chi_p), jnp.asarray(y_p)
    )
    mv = p.astype(np.float64) @ y.astype(np.float64)
    np.testing.assert_allclose(np.asarray(gy), chi_p + y_p - mv,
                               rtol=1e-5, atol=1e-5)
    delta = chi_p - mv
    np.testing.assert_allclose(np.asarray(cs)[0], delta.sum(0),
                               rtol=1e-5, atol=1e-5)
    # the deflation identity the solver relies on:
    #   ||delta - colmean(delta)||_F^2 = ss - sum_c cs_c^2 / n_rows
    ss_v = float(np.asarray(ss)[0, 0])
    cs_v = np.asarray(cs, np.float64)[0]
    defl = ((delta - delta.mean(0, keepdims=True)) ** 2).sum()
    np.testing.assert_allclose(ss_v - (cs_v ** 2).sum() / ph, defl,
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# solve parity + traffic accounting (1x1 and 2x2 meshes)
# ---------------------------------------------------------------------------


def _build_and_solve(ctx, n, codec, kernel, *, d=3, q=5, k=4, seed=0):
    a = jax.device_put(_sym(n, seed), ctx.sharding(ctx.matrix_spec))
    op = chain_product(ctx, a, d, oocore=True, tile_codec=codec,
                       use_gemm_kernel=kernel)
    b = _rng(seed + 100).normal(size=(n, k)).astype(np.float32)
    b = jax.device_put(b, ctx.sharding(ctx.rowblock_spec))
    st = stream_stats()
    h2d0, panels0 = st.bytes_h2d, st.panels
    y, rep = solve(ctx, op, b, SolverSpec(), fixed_q=q)
    st = stream_stats()
    op.release_scratch()
    return (np.asarray(y), rep,
            st.bytes_h2d - h2d0, st.panels - panels0)


def _resident_solve(ctx, n, *, d=3, q=5, k=4, seed=0):
    a = jax.device_put(_sym(n, seed), ctx.sharding(ctx.matrix_spec))
    op = chain_product(ctx, a, d)
    b = _rng(seed + 100).normal(size=(n, k)).astype(np.float32)
    b = jax.device_put(b, ctx.sharding(ctx.rowblock_spec))
    y, _ = solve(ctx, op, b, SolverSpec(), fixed_q=q)
    return np.asarray(y)


@pytest.mark.parametrize("mesh", ["ctx1", "ctx22"])
def test_fused_solve_allclose_vs_two_pass_driver(mesh, request):
    ctx = request.getfixturevalue(mesh)
    n = 64
    y_xla, _, _, _ = _build_and_solve(ctx, n, "raw", False)
    y_ker, _, _, _ = _build_and_solve(ctx, n, "raw", True)
    np.testing.assert_allclose(y_ker, y_xla, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mesh", ["ctx1", "ctx22"])
def test_fused_solve_bf16_allclose_vs_xla_same_codec(mesh, request):
    ctx = request.getfixturevalue(mesh)
    n = 64
    y_xla, _, _, _ = _build_and_solve(ctx, n, "bf16", False)
    y_ker, _, _, _ = _build_and_solve(ctx, n, "bf16", True)
    np.testing.assert_allclose(y_ker, y_xla, rtol=1e-4, atol=1e-4)


def test_raw_kernel_path_allclose_vs_resident(ctx1):
    n = 64
    y_res = _resident_solve(ctx1, n)
    y_ker, _, _, _ = _build_and_solve(ctx1, n, "raw", True)
    np.testing.assert_allclose(y_ker, y_res, rtol=1e-4, atol=1e-4)


def test_bf16_kernel_halves_solve_h2d(ctx1):
    """Stored-form bf16 shipping: solve-phase H2D <= 0.55x the fp32-decode
    baseline at equal accuracy (the PR acceptance bound)."""
    n = 64
    reset_stream_stats()
    y_xla, rep_x, h2d_xla, panels_xla = _build_and_solve(ctx1, n, "bf16", False)
    y_ker, rep_k, h2d_ker, panels_ker = _build_and_solve(ctx1, n, "bf16", True)
    np.testing.assert_allclose(y_ker, y_xla, rtol=1e-4, atol=1e-4)
    # per-pass comparison: iteration counts may differ by an early stop when
    # the kernel's exact residual moments cancel to zero at convergence
    per_pass_xla = h2d_xla / panels_xla
    per_pass_ker = h2d_ker / panels_ker
    assert per_pass_ker <= 0.55 * per_pass_xla
    assert h2d_ker <= 0.55 * h2d_xla * (panels_ker / panels_xla) + 1e-9


def test_bytes_h2d_saved_counter(ctx1):
    reset_stream_stats()
    saved0 = stream_stats().bytes_h2d_saved
    _build_and_solve(ctx1, 64, "bf16", True)
    st = stream_stats()
    assert st.bytes_h2d_saved > saved0
    # raw-codec kernel path ships fp32 either way: nothing saved
    reset_stream_stats()
    _build_and_solve(ctx1, 64, "raw", True)
    assert stream_stats().bytes_h2d_saved == 0


def test_fused_iteration_is_one_panel_pass(ctx1):
    """Each fused solve iteration streams the P2 scratch exactly once."""
    n = 64
    a = jax.device_put(_sym(n, 0), ctx1.sharding(ctx1.matrix_spec))
    op = chain_product(ctx1, a, 3, oocore=True, tile_codec="bf16",
                       use_gemm_kernel=True)
    b = _rng(100).normal(size=(n, 4)).astype(np.float32)
    b = jax.device_put(b, ctx1.sharding(ctx1.rowblock_spec))
    n_panels = n // int(np.lcm(int(op.p2.panel_rows), ctx1.n_row_shards))
    st = stream_stats()
    p0 = st.panels
    y, rep = solve(ctx1, op, b, SolverSpec(), fixed_q=5)
    panels = stream_stats().panels - p0
    op.release_scratch()
    # one chi pass (P1) + one pass per iteration (P2), nothing else
    assert panels == n_panels * (rep.iterations + 1)


def test_pinned_host_fallback_on_cpu(ctx1):
    """The pinned-host staging probe degrades cleanly where the backend has
    no pinned_host memory space (CPU): panels still flow, pipeline.pinned
    stays False."""
    from repro.store import PanelPipeline, TileStore

    n = 64
    store = TileStore.create(None, n=n, grid=4)
    h = store.put_snapshot("a", _sym(n, 0))
    sharding = ctx1.sharding(ctx1.matrix_spec)
    with PanelPipeline([h], range(0, n, 16), 16, sharding=sharding) as pipe:
        seen = 0
        for r0, (panel,) in pipe:
            assert panel.shape == (16, n)
            seen += 1
        assert seen == 4
        assert pipe.pinned is False


@pytest.mark.slow
def test_stream_gemm_blocked_grid_bitwise_bf16(ctx1):
    """Heavier grid: blocked M/N with whole K, bf16 bits, still bitwise vs
    the host-decoded XLA dot (per-output-tile reduction order matches)."""
    r = _rng(7)
    a_bits = _bf16_bits(r.normal(size=(256, 128)).astype(np.float32))
    b = r.normal(size=(128, 256)).astype(np.float32)
    init = r.normal(size=(256, 256)).astype(np.float32)
    got = stream_gemm(jnp.asarray(a_bits), jnp.asarray(b), jnp.asarray(init),
                      bm=64, bk=128, bn=64)
    want = _gemm_step(jnp.asarray(init), jnp.asarray(_host_decode(a_bits)),
                      jnp.asarray(b))
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.slow
def test_fused_solve_2x2_bf16_end_to_end_scores(ctx22):
    """2x2 mesh, bf16 scratch, kernel path vs same-codec XLA path at a
    larger n -- the full distributed epilogue (psum moments, row slicing)."""
    n = 128
    y_xla, _, _, _ = _build_and_solve(ctx22, n, "bf16", False, d=4, q=6)
    y_ker, _, _, _ = _build_and_solve(ctx22, n, "bf16", True, d=4, q=6)
    np.testing.assert_allclose(y_ker, y_xla, rtol=1e-4, atol=1e-4)
