"""PanelPipeline semantics under fault injection: order, backpressure,
cancellation, error propagation, and the CachingHandle replay contract.

The fixture handle serves panels of an in-memory matrix with injectable
per-origin delays (so fetches *complete* out of order relative to a uniform
schedule) and optional failures, and logs every fetch -- the assertions prove
the pipeline's ordering and cancellation guarantees rather than assuming
them.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.tiles import StreamStats
from repro.store import CachingHandle, PanelPipeline, TileStore

# Depth sweep: tier-1 checks the default depth, the weekly `full` job sweeps.
DEPTHS = [
    pytest.param(1, marks=pytest.mark.slow),
    2,
    pytest.param(4, marks=pytest.mark.slow),
]


class DelayHandle:
    """Streamable snapshot handle with injectable delays/failures + fetch log."""

    def __init__(self, a: np.ndarray, panel_rows: int, delays=None, fail_at=None):
        self.a = np.asarray(a)
        self._panel_rows = panel_rows
        self.delays = dict(delays or {})
        self.fail_at = fail_at
        self.fetch_log: list[int] = []
        self._lock = threading.Lock()

    @property
    def shape(self):
        return self.a.shape

    @property
    def dtype(self):
        return self.a.dtype

    @property
    def panel_rows(self) -> int:
        return self._panel_rows

    @property
    def fetches(self) -> int:
        with self._lock:
            return len(self.fetch_log)

    def read_panel(self, row0: int, height: int) -> np.ndarray:
        time.sleep(self.delays.get(row0, 0.0))
        if self.fail_at is not None and row0 == self.fail_at:
            raise IOError(f"injected fault at row {row0}")
        with self._lock:
            self.fetch_log.append(row0)
        return self.a[row0 : row0 + height]


def _mat(n: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).normal(size=(n, n)).astype(np.float32)


# ---------------------------------------------------------------------------
# ordering: origin order survives adversarial fetch timing, per operand
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", DEPTHS)
def test_panels_arrive_in_origin_order(depth):
    n, ph = 64, 8
    a, b = _mat(n, 0), _mat(n, 1)
    # Adversarial timing: early panels are the *slowest*, so a naive
    # completion-ordered queue would yield later origins first.
    delays = {r0: 0.02 * max(0, 4 - r0 // ph) for r0 in range(0, n, ph)}
    ha = DelayHandle(a, ph, delays=delays)
    hb = DelayHandle(b, ph)  # second operand fetches instantly (skewed pair)
    origins = list(range(0, n, ph))
    got = []
    with PanelPipeline([ha, hb], origins, ph, depth=depth) as pipe:
        for row0, (pa, pb) in pipe:
            got.append(row0)
            np.testing.assert_array_equal(pa, a[row0 : row0 + ph])
            np.testing.assert_array_equal(pb, b[row0 : row0 + ph])
    assert got == origins
    # every origin fetched exactly once per operand, in order
    assert ha.fetch_log == origins
    assert hb.fetch_log == origins


@pytest.mark.parametrize("depth", DEPTHS)
def test_repeated_origin_walk(depth):
    """The oochain GEMM walks the right operand g times (nested K loop)."""
    n, ph = 32, 8
    a = _mat(n, 2)
    origins = [k0 for _ in range(0, n, ph) for k0 in range(0, n, ph)]
    h = DelayHandle(a, ph)
    with PanelPipeline([h], origins, ph, depth=depth) as pipe:
        walked = [(row0, panels[0].sum()) for row0, panels in pipe]
    assert [w[0] for w in walked] == origins
    assert h.fetch_log == origins


# ---------------------------------------------------------------------------
# backpressure: the ring bounds how far the producer can run ahead
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", DEPTHS)
def test_backpressure_bounds_prefetch(depth):
    n, ph = 128, 8
    a = _mat(n, 3)
    h = DelayHandle(a, ph)
    origins = list(range(0, n, ph))
    with PanelPipeline([h], origins, ph, depth=depth) as pipe:
        it = iter(pipe)
        next(it)
        time.sleep(0.15)  # stalled consumer: producer must block on the ring
        # consumed 1 + ring capacity + 1 in-flight fetch
        assert h.fetches <= 1 + depth + 1
        for _ in it:
            pass
    assert h.fetches == len(origins)


# ---------------------------------------------------------------------------
# cancellation on early exit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", DEPTHS)
def test_close_cancels_producer(depth):
    n, ph = 128, 8
    a = _mat(n, 4)
    h = DelayHandle(a, ph, delays={r0: 0.005 for r0 in range(0, n, ph)})
    origins = list(range(0, n, ph))
    pipe = PanelPipeline([h], origins, ph, depth=depth)
    it = iter(pipe)
    next(it)
    next(it)
    pipe.close()
    assert pipe._thread is None  # producer joined
    fetched = h.fetches
    assert fetched < len(origins)  # early exit really did stop the walk
    time.sleep(0.1)
    assert h.fetches == fetched  # ... and nothing fetched after close


def test_break_out_of_iteration_cancels():
    """A consumer `break` (the solver converging early) cancels the producer."""
    n, ph = 128, 8
    a = _mat(n, 5)
    h = DelayHandle(a, ph, delays={r0: 0.005 for r0 in range(0, n, ph)})
    with PanelPipeline([h], list(range(0, n, ph)), ph, depth=2) as pipe:
        for row0, _ in pipe:
            if row0 >= 2 * ph:
                break
    time.sleep(0.1)
    assert h.fetches < n // ph


def test_close_is_idempotent():
    h = DelayHandle(_mat(16, 6), 8)
    pipe = PanelPipeline([h], [0, 8], 8, depth=2)
    pipe.close()
    pipe.close()
    with pytest.raises(RuntimeError):
        next(iter(pipe))  # closed pipelines don't serve panels


# ---------------------------------------------------------------------------
# error propagation
# ---------------------------------------------------------------------------


def test_fetch_error_reaches_consumer():
    n, ph = 64, 8
    h = DelayHandle(_mat(n, 7), ph, fail_at=3 * ph)
    got = []
    with pytest.raises(RuntimeError, match="panel prefetch failed") as ei:
        with PanelPipeline([h], list(range(0, n, ph)), ph, depth=2) as pipe:
            for row0, _ in pipe:
                got.append(row0)
    assert isinstance(ei.value.__cause__, IOError)
    assert got == [0, ph, 2 * ph]  # everything before the fault was served


def test_bad_depth_rejected():
    h = DelayHandle(_mat(16, 8), 8)
    with pytest.raises(ValueError, match="depth"):
        PanelPipeline([h], [0, 8], 8, depth=0)


# ---------------------------------------------------------------------------
# device staging + stats integration
# ---------------------------------------------------------------------------


def test_device_mode_counts_and_bounds(ctx1):
    n, ph = 64, 8
    a = _mat(n, 9)
    h = DelayHandle(a, ph)
    st = StreamStats()
    sharding = ctx1.sharding(ctx1.matrix_spec)
    out = []
    with PanelPipeline(
        [h], list(range(0, n, ph)), ph, depth=2, sharding=sharding, stats=st
    ) as pipe:
        for row0, (panel,) in pipe:
            out.append(np.asarray(panel))
    np.testing.assert_array_equal(np.concatenate(out, axis=0), a)
    panel_bytes = ph * n * 4
    assert st.panels == n // ph
    assert st.bytes_h2d == (n // ph) * panel_bytes
    assert st.bytes_decoded == (n // ph) * panel_bytes
    assert st.bytes_read == (n // ph) * panel_bytes  # raw handle: pre == post
    # one-origin device lookahead: at most two panels staged per operand
    assert st.peak_live_bytes <= 2 * panel_bytes


def test_store_handle_reports_precodec_bytes(tmp_path):
    """bf16 store tiles: bytes_read tracks the halved stored form."""
    n, ph = 32, 16
    a = _mat(n, 10)
    store = TileStore.create(tmp_path / "s", n=n, grid=n // ph, codec="bf16")
    h = store.put_snapshot("t", a)
    st = StreamStats()
    with PanelPipeline([h], list(range(0, n, ph)), ph, depth=2, stats=st) as pipe:
        for _ in pipe:
            pass
    assert st.bytes_decoded == n * n * 4
    # stored tiles are uint16 (+ .npy headers): well under the decoded bytes
    assert n * n * 2 <= st.bytes_read < n * n * 4


# ---------------------------------------------------------------------------
# CachingHandle: the solver's stream-once-apply-b-times contract
# ---------------------------------------------------------------------------


def test_caching_handle_replays_bitwise_and_free():
    n, ph = 64, 8
    a = _mat(n, 11)
    inner = DelayHandle(a, ph)
    cached = CachingHandle(inner)
    first = [cached.read_panel_info(r0, ph) for r0 in range(0, n, ph)]
    second = [cached.read_panel_info(r0, ph) for r0 in range(0, n, ph)]
    for (p1, s1), (p2, s2) in zip(first, second):
        np.testing.assert_array_equal(p1, p2)  # bitwise replay
        assert s1 > 0 and s2 == 0  # replays report zero backing-store bytes
    assert inner.fetches == n // ph  # the store was read exactly once
    assert cached.fills == n // ph and cached.replays == n // ph
    cached.refresh()
    cached.read_panel(0, ph)
    assert inner.fetches == n // ph + 1  # refresh really re-streams


def test_caching_handle_rejects_non_handles():
    with pytest.raises(TypeError):
        CachingHandle(np.zeros((4, 4)))
