"""Retrace budget: the tile-program compile cache pays each compile once.

Guards the "a T-snapshot run retraces the same ~5 programs T times"
regression (ROADMAP) forever: tile bodies execute in Python only while jax
traces them, so ``program_cache_stats().traces`` is an exact count of tile
program (re)traces, and a steady-state snapshot push must add zero.
"""

import numpy as np
import pytest

from repro.core import (
    CommuteConfig,
    SequenceDetector,
    detect_anomalies,
    program_cache_stats,
)
from repro.core.tiles import tile_map

CFG = CommuteConfig(eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4)


def _sym(n: int, seed: int) -> np.ndarray:
    a = np.abs(np.random.default_rng(seed).normal(size=(n, n))).astype(np.float32)
    a = (a + a.T) / 2.0
    np.fill_diagonal(a, 0.0)
    return a


def test_tile_map_traces_body_once(ctx1):
    """Trace-counting body: repeated tile_map calls with the same body and
    geometry reuse one compiled program (the body's Python code runs once)."""
    traces = []

    def body(tile, blk):
        traces.append(1)
        return blk

    x = ctx1.put_matrix(np.zeros((16, 16), np.float32))
    tile_map(ctx1, body, x)
    tile_map(ctx1, body, x)
    tile_map(ctx1, body, x)
    assert len(traces) == 1

    # a different geometry is a different program: exactly one more trace
    y = ctx1.put_matrix(np.zeros((32, 32), np.float32))
    tile_map(ctx1, body, y)
    assert len(traces) == 2


def test_fresh_lambda_misses_safely(ctx1):
    """Per-call lambdas (which may close over data) never false-hit."""
    x = ctx1.put_matrix(np.full((16, 16), 2.0, np.float32))
    outs = []
    for scale in (1.0, 3.0):
        outs.append(np.asarray(tile_map(ctx1, lambda tile, blk: blk * scale, x)))
    np.testing.assert_allclose(outs[0], 2.0)
    np.testing.assert_allclose(outs[1], 6.0)


@pytest.mark.parametrize("schedule", ["xla", "cannon"])
def test_second_transition_zero_new_compiles(ctx1, schedule):
    """Acceptance: the second snapshot pair compiles nothing new."""
    cfg = CommuteConfig(eps_rp=1e-2, d=3, q=3, schedule=schedule, k_override=4)
    n = 32
    detect_anomalies(ctx1, ctx1.put_matrix(_sym(n, 0)), ctx1.put_matrix(_sym(n, 1)), cfg)
    st = program_cache_stats()
    t0, m0 = st.traces, st.misses
    detect_anomalies(ctx1, ctx1.put_matrix(_sym(n, 2)), ctx1.put_matrix(_sym(n, 3)), cfg)
    assert st.traces == t0, "second transition retraced a tile program"
    assert st.misses == m0, "second transition missed the program cache"


def test_sequence_retrace_budget(ctx1):
    """4-snapshot SequenceDetector run: every tile program compiles exactly
    once.  Snapshot 1 compiles the chain/embedding programs, snapshot 2 adds
    only the (first-use) scorer programs; snapshots 3 and 4 add zero."""
    snaps = [_sym(32, 10 + t) for t in range(4)]
    det = SequenceDetector(ctx1, CFG, top_k=5)
    st = program_cache_stats()
    det.push(ctx1.put_matrix(snaps[0]))
    after_first = st.traces
    det.push(ctx1.put_matrix(snaps[1]))  # first transition: scorer compiles
    warm_traces, warm_misses = st.traces, st.misses
    det.push(ctx1.put_matrix(snaps[2]))
    det.push(ctx1.put_matrix(snaps[3]))
    res = det.finalize()
    assert len(res.transitions) == 3
    assert st.traces == warm_traces, "steady-state push retraced a tile program"
    assert st.misses == warm_misses, "steady-state push missed the program cache"
    assert st.hits > 0
    assert after_first > 0  # sanity: the cold build did trace programs


def test_adaptive_solver_retrace_budget(ctx1):
    """The lax.while_loop solve driver keeps the retrace budget: steady-state
    pushes add ZERO traces/program-cache misses, and because the tolerance,
    the step cap and the Chebyshev interval bound are *operands* (not trace
    constants), changing them between runs must not compile anything new."""
    from dataclasses import replace

    cfg = CommuteConfig(
        eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4,
        solver="chebyshev", solver_tol=1e-4,
    )
    snaps = [_sym(32, 40 + t) for t in range(4)]
    det = SequenceDetector(ctx1, cfg, top_k=5)
    det.push(ctx1.put_matrix(snaps[0]))
    det.push(ctx1.put_matrix(snaps[1]))
    st = program_cache_stats()
    warm_traces, warm_misses = st.traces, st.misses
    det.push(ctx1.put_matrix(snaps[2]))
    det.push(ctx1.put_matrix(snaps[3]))
    assert st.traces == warm_traces, "steady-state adaptive push retraced"
    assert st.misses == warm_misses, "steady-state adaptive push missed the cache"

    # different tolerance / cap, same geometry: still zero new programs
    det2 = SequenceDetector(
        ctx1, replace(cfg, solver_tol=1e-6, solver_max_iters=7), top_k=5
    )
    det2.push(ctx1.put_matrix(snaps[0]))
    det2.push(ctx1.put_matrix(snaps[1]))
    assert st.traces == warm_traces, "tolerance change retraced a program"
    assert st.misses == warm_misses, "tolerance leaked into a program cache key"


def test_warm_cg_retrace_budget(ctx1):
    """Warm-started CG keeps the retrace budget: y0 is an *operand* of one
    compiled program (cold pushes pass y0 = chi through the same program), so
    steady-state pushes of a warm CG sequence add ZERO traces and ZERO cache
    misses -- and a different tolerance / step cap still compiles nothing."""
    from dataclasses import replace

    cfg = CommuteConfig(
        eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4,
        solver="cg", solver_tol=1e-4, warm_start=True,
    )
    snaps = [_sym(32, 60 + t) for t in range(4)]
    det = SequenceDetector(ctx1, cfg, top_k=5)
    det.push(ctx1.put_matrix(snaps[0]))  # cold solve compiles the CG program
    det.push(ctx1.put_matrix(snaps[1]))  # first warm solve: same program
    st = program_cache_stats()
    warm_traces, warm_misses = st.traces, st.misses
    det.push(ctx1.put_matrix(snaps[2]))
    det.push(ctx1.put_matrix(snaps[3]))
    assert st.traces == warm_traces, "steady-state warm CG push retraced"
    assert st.misses == warm_misses, "steady-state warm CG push missed the cache"

    # tolerance / cap are operands of the CG program too
    det2 = SequenceDetector(
        ctx1, replace(cfg, solver_tol=1e-5, solver_max_iters=9), top_k=5
    )
    det2.push(ctx1.put_matrix(snaps[0]))
    det2.push(ctx1.put_matrix(snaps[1]))
    assert st.traces == warm_traces, "tolerance change retraced the CG program"
    assert st.misses == warm_misses, "tolerance leaked into the CG cache key"


def test_incremental_chain_retrace_budget(ctx1):
    """The delta-chain path keeps the retrace budget: its factor algebra runs
    eagerly (host QR/SVD + rowblock passes), so the only new compiled program
    is the corrected resident solve loop -- keyed once by correction rank on
    the FIRST incremental push.  Steady-state incremental pushes add ZERO
    traces and ZERO program-cache misses."""
    from repro.core import CommuteConfig as _Cfg

    cfg = _Cfg(
        eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4,
        solver="cg", solver_tol=1e-4, warm_start=True,
        incremental_chain=True, delta_rank=4, delta_budget=0.5,
    )
    # slowly-drifting snapshots: a0 plus a small symmetric perturbation per
    # step, so the drift monitor accepts every transition after the base build
    a0 = _sym(32, 70)
    snaps = [
        np.abs(a0 + 2e-3 * t * _sym(32, 71 + t)).astype(np.float32)
        for t in range(4)
    ]
    det = SequenceDetector(ctx1, cfg, top_k=5)
    det.push(ctx1.put_matrix(snaps[0]))  # full base build
    det.push(ctx1.put_matrix(snaps[1]))  # first delta: corrected CG compiles
    st = program_cache_stats()
    warm_traces, warm_misses = st.traces, st.misses
    det.push(ctx1.put_matrix(snaps[2]))
    det.push(ctx1.put_matrix(snaps[3]))
    res = det.finalize()
    assert st.traces == warm_traces, "steady-state incremental push retraced"
    assert st.misses == warm_misses, "steady-state incremental push missed the cache"
    # sanity: the steady-state pushes really were delta updates, not rebuilds
    for m in res.transition_metrics[1:]:
        assert m.get("chain.incremental_updates", 0.0) == 1.0


def test_streamed_sequence_retrace_budget(ctx1):
    """The retrace budget holds out-of-core too: store-backed snapshots and
    the oocore chain reuse one compiled program set across the sequence."""
    from repro.store import TileStore

    n = 32
    cfg = CommuteConfig(eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4, oocore=True)
    store = TileStore.create(None, n=n, grid=4)
    for t in range(4):
        store.put_snapshot(f"t{t}", _sym(n, 20 + t))
    det = SequenceDetector(ctx1, cfg, top_k=5)
    it = store.iter_snapshots()
    det.push(next(it))
    det.push(next(it))
    st = program_cache_stats()
    warm_traces, warm_misses = st.traces, st.misses
    det.push(next(it))
    det.push(next(it))
    assert st.traces == warm_traces
    assert st.misses == warm_misses


def test_query_path_retrace_budget(ctx1):
    """Artifact publishing and repeated queries stay off the retrace path:
    ``push`` publishes with host numpy only (zero tile programs), and every
    panel of every query reuses one compiled kernel program (the running
    top-k state threads through as operands, so shapes never change)."""
    from repro.core.query import nearest_neighbors, top_anomalies_from_store
    from repro.store.embstore import EmbeddingStore

    n = 32
    store = EmbeddingStore.create(
        None, n=n, k=CFG.k_override, panel_rows=8, seed=CFG.seed
    )
    det = SequenceDetector(ctx1, CFG, top_k=5, emb_store=store)
    det.push(ctx1.put_matrix(_sym(n, 40)))
    det.push(ctx1.put_matrix(_sym(n, 41)))
    top_anomalies_from_store(store, 5)  # warm-up: kernel compiles here
    nearest_neighbors(store, 3, 5)
    st = program_cache_stats()
    warm_traces, warm_misses = st.traces, st.misses
    det.push(ctx1.put_matrix(_sym(n, 42)))
    det.push(ctx1.put_matrix(_sym(n, 43)))
    for _ in range(3):
        top_anomalies_from_store(store, 5)
        top_anomalies_from_store(store, 5, corrected=True)
        nearest_neighbors(store, 7, 5)
    assert st.traces == warm_traces, "query path retraced a tile program"
    assert st.misses == warm_misses, "query path missed the program cache"
