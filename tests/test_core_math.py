"""CADDeLaG core math: chain product, solver, embedding, CAD scoring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CommuteConfig,
    chain_product,
    commute_time_embedding,
    detect_anomalies,
    estimate_solution,
    exact_commute_distances,
    matmul,
    residual_norm,
)
from repro.core import laplacian as lap
from repro.core.embedding import commute_distance_block, edge_projection
from repro.core import rng as crng
from repro.graphs import gmm_graph_sequence


def _graph(ctx, n=96, seed=0):
    return gmm_graph_sequence(ctx, n=n, seed=seed)


# ---------------------------------------------------------------------------
# matmul schedules
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", ["xla", "summa", "cannon"])
def test_matmul_schedules_agree(ctx22, schedule):
    rng = np.random.default_rng(0)
    a = ctx22.put_matrix(rng.normal(size=(64, 64)).astype(np.float32))
    b = ctx22.put_matrix(rng.normal(size=(64, 64)).astype(np.float32))
    ref = np.asarray(a) @ np.asarray(b)
    out = matmul(ctx22, a, b, schedule=schedule)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-4)


def test_cannon_requires_square_grid(ctx22):
    from repro.core.distmatrix import DistContext

    # 2x2 is square -- build a 1x4 context to trigger the error
    import jax as _jax
    from jax.sharding import Mesh

    dev = np.array(_jax.devices()[:4]).reshape(1, 4)
    ctx14 = DistContext(mesh=Mesh(dev, ("data", "model")))
    a = ctx14.put_matrix(np.eye(64, dtype=np.float32))
    with pytest.raises(ValueError, match="square"):
        matmul(ctx14, a, a, schedule="cannon")


# ---------------------------------------------------------------------------
# SDD solver (Algorithm 2)
# ---------------------------------------------------------------------------


def test_chain_solver_residual(ctx1):
    seq = _graph(ctx1)
    a = seq.a1
    deg = lap.degrees(ctx1, a)
    l_mat = lap.laplacian(ctx1, a, deg)
    op = chain_product(ctx1, a, d_len=8, schedule="xla")
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=(96, 4)).astype(np.float32))
    b = b - b.mean(0, keepdims=True)  # 1-orthogonal RHS
    x = estimate_solution(ctx1, op, b, q_iters=12)
    r = float(residual_norm(ctx1, l_mat, x, b))
    assert r < 1e-3, f"residual {r}"


def test_longer_chain_reduces_residual(ctx1):
    seq = _graph(ctx1)
    a = seq.a1
    deg = lap.degrees(ctx1, a)
    l_mat = lap.laplacian(ctx1, a, deg)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.normal(size=(96, 2)).astype(np.float32))
    b = b - b.mean(0, keepdims=True)
    res = []
    for d in (2, 5, 8):
        op = chain_product(ctx1, a, d_len=d, schedule="xla")
        x = estimate_solution(ctx1, op, b, q_iters=3)
        res.append(float(residual_norm(ctx1, l_mat, x, b)))
    assert res[2] < res[0], f"residuals not improving: {res}"


def test_fuse_l_matches_materialized(ctx1):
    seq = _graph(ctx1)
    op1 = chain_product(ctx1, seq.a1, d_len=5, schedule="xla", fuse_l=False)
    op2 = chain_product(ctx1, seq.a1, d_len=5, schedule="xla", fuse_l=True)
    np.testing.assert_allclose(np.asarray(op1.p2), np.asarray(op2.p2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# commute-time embedding (Algorithm 3) vs exact eigendecomposition
# ---------------------------------------------------------------------------


def test_embedding_approximates_exact(ctx1):
    seq = _graph(ctx1, n=128)
    cfg = CommuteConfig(eps_rp=1e-3, d=8, q=12, schedule="xla", k_override=64)
    emb = commute_time_embedding(ctx1, seq.a1, cfg)
    exact = np.asarray(exact_commute_distances(np.asarray(seq.a1)))
    idx = jnp.arange(128)
    approx = np.asarray(commute_distance_block(emb, idx, idx))
    mask = ~np.eye(128, dtype=bool)
    rel = np.abs(approx - exact)[mask] / np.maximum(exact[mask], 1e-9)
    assert np.median(rel) < 0.25, f"median rel err {np.median(rel)}"


def test_edge_projection_matches_dense_incidence(ctx1):
    """Y = B^T W^{1/2} q computed via the counter RNG == dense construction."""
    n, k, seed = 24, 3, 5
    rng = np.random.default_rng(0)
    a = np.abs(rng.normal(size=(n, n))).astype(np.float32)
    a = (a + a.T) / 2
    np.fill_diagonal(a, 0.0)
    y = np.asarray(edge_projection(ctx1, ctx1.put_matrix(a), seed, k))

    # dense oracle: enumerate edges (i<j), B (m,n), W (m,m), q from same hash
    for c in range(k):
        yc = np.zeros(n)
        for i in range(n):
            for j in range(i + 1, n):
                q = float(np.asarray(crng.edge_rademacher(seed, i, j, c)))
                w = np.sqrt(a[i, j])
                yc[i] += w * q
                yc[j] -= w * q
        np.testing.assert_allclose(y[:, c], yc / np.sqrt(k), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# CAD anomaly detection (Algorithm 4)
# ---------------------------------------------------------------------------


def test_cad_recovers_injected_anomalies(ctx1):
    seq = gmm_graph_sequence(ctx1, n=128, seed=0, inject_p=0.02)
    cfg = CommuteConfig(eps_rp=1e-3, d=8, q=12, schedule="xla")
    res = detect_anomalies(ctx1, seq.a1, seq.a2, cfg, top_k=20)
    truth = set(seq.anomalous_nodes.tolist())
    found = set(np.asarray(res.top_idx).tolist())
    precision = len(truth & found) / 20
    assert precision >= 0.5, f"precision@20 = {precision}"


@pytest.mark.slow
def test_cad_sharded_matches_single(ctx1, ctx22):
    seq1 = gmm_graph_sequence(ctx1, n=64, seed=3, inject_p=0.02)
    seq2 = gmm_graph_sequence(ctx22, n=64, seed=3, inject_p=0.02)
    cfg = CommuteConfig(eps_rp=1e-2, d=6, q=8, schedule="summa")
    r1 = detect_anomalies(ctx1, seq1.a1, seq1.a2, cfg, top_k=5)
    r2 = detect_anomalies(ctx22, seq2.a1, seq2.a2, cfg, top_k=5)
    np.testing.assert_allclose(
        np.asarray(r1.scores), np.asarray(r2.scores), rtol=1e-3, atol=1e-2
    )


def test_cad_symmetric_inputs_score_zero(ctx1):
    """identical graphs -> all anomaly scores ~0."""
    seq = _graph(ctx1, n=64)
    cfg = CommuteConfig(eps_rp=1e-2, d=5, q=6, schedule="xla")
    res = detect_anomalies(ctx1, seq.a1, seq.a1, cfg, top_k=5)
    assert float(jnp.max(jnp.abs(res.scores))) < 1e-3
