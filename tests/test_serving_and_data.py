"""Serving engine + data pipeline integration tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.data import DataConfig, Prefetcher, global_batch_for, host_batch
from repro.launch.mesh import make_cpu_mesh
from repro.models import lm
from repro.models.common import ArchConfig
from repro.serving import ServeConfig, ServeEngine

TINY = ArchConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, remat=False, compute_dtype="float32",
)


def test_serve_engine_greedy_deterministic():
    mesh = make_cpu_mesh(1, 1)
    spec = lm.build_spec(TINY)
    params = lm.init_params(spec, jax.random.PRNGKey(0))
    eng = ServeEngine(spec, mesh, params, s_max=24, batch=2,
                      cfg=ServeConfig(max_new_tokens=6))
    prompts = np.random.default_rng(0).integers(0, 256, size=(2, 8)).astype(np.int32)
    a = eng.generate(prompts)
    # rebuild (decode donates its cache) and confirm determinism
    eng2 = ServeEngine(spec, mesh, params, s_max=24, batch=2,
                       cfg=ServeConfig(max_new_tokens=6))
    b = eng2.generate(prompts)
    assert a.shape == (2, 6)
    np.testing.assert_array_equal(a, b)
    assert (a < 256).all()


def test_serve_engine_temperature_sampling():
    mesh = make_cpu_mesh(1, 1)
    spec = lm.build_spec(TINY)
    params = lm.init_params(spec, jax.random.PRNGKey(0))
    eng = ServeEngine(spec, mesh, params, s_max=24, batch=2,
                      cfg=ServeConfig(max_new_tokens=8, temperature=1.0, seed=1))
    prompts = np.random.default_rng(0).integers(0, 256, size=(2, 8)).astype(np.int32)
    out = eng.generate(prompts)
    assert out.shape == (2, 8) and (out < 256).all()


def test_serve_sharded_matches_single(mesh22):
    spec = lm.build_spec(TINY)
    params = lm.init_params(spec, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(1).integers(0, 256, size=(4, 8)).astype(np.int32)
    outs = []
    for mesh in (make_cpu_mesh(1, 1), mesh22):
        eng = ServeEngine(spec, mesh, params, s_max=16, batch=4,
                          cfg=ServeConfig(max_new_tokens=4))
        outs.append(eng.generate(prompts))
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_host_batch_shapes_and_range():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8)
    b = host_batch(cfg, 0)
    assert b["tokens"].shape == (8, 64) and b["labels"].shape == (8, 64)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 1000


def test_global_batch_matches_host(mesh22):
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=8)
    ref = host_batch(cfg, 3)
    with mesh22:
        gb = global_batch_for(cfg, 3, mesh22, P("data", None))
    np.testing.assert_array_equal(np.asarray(gb["tokens"]), ref["tokens"])
    np.testing.assert_array_equal(np.asarray(gb["labels"]), ref["labels"])


def test_batches_differ_across_steps():
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=4)
    assert not np.array_equal(host_batch(cfg, 0)["tokens"], host_batch(cfg, 1)["tokens"])


def test_prefetcher_produces_sequence():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=2)
    pf = Prefetcher(cfg, start_step=0)
    try:
        b0, b1 = pf.next(), pf.next()
        np.testing.assert_array_equal(b0["tokens"], host_batch(cfg, 0)["tokens"])
        np.testing.assert_array_equal(b1["tokens"], host_batch(cfg, 1)["tokens"])
    finally:
        pf.close()


def test_frames_emitted_for_encdec():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=2, frames_dim=32)
    b = host_batch(cfg, 0)
    assert b["frames"].shape == (2, 16, 32)
    assert np.all(np.isfinite(b["frames"]))
