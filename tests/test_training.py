"""Training substrate: optimizers, accumulation, checkpoint/restart,
failure injection, elastic re-mesh, straggler watchdog."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.mesh import make_cpu_mesh
from repro.launch.train import train_loop
from repro.models import lm
from repro.models.common import ArchConfig
from repro.training import (
    FailureInjector,
    InjectedFailure,
    OptConfig,
    StragglerWatchdog,
    latest_step,
    make_train_step,
)
from repro.training import checkpoint as ckpt
from repro.training.optim import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
)
from repro.training.train_step import init_state


TINY = ArchConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, remat=True,
)


def _mesh1():
    return make_cpu_mesh(1, 1)


def _batch(b=4, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    tok = jax.random.randint(key, (b, s), 0, 256)
    return {"tokens": tok, "labels": tok}


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in (0, 9, 10, 50, 99)]
    assert lrs[0] < lrs[1] <= lrs[2]  # warmup ascending
    assert lrs[2] >= lrs[3] >= lrs[4]  # cosine descending
    assert lrs[4] >= 0.1 * 0.99  # floor


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0, "b": jnp.ones((3,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(700), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


@pytest.mark.slow
def test_adamw_and_adafactor_reduce_loss():
    mesh = _mesh1()
    spec = lm.build_spec(TINY)
    batch = _batch()
    for name in ("adamw", "adafactor"):
        ocfg = OptConfig(name=name, lr=1e-2, warmup_steps=1, total_steps=50)
        step, *_ = make_train_step(spec, mesh, ocfg)
        params, opt = init_state(spec, mesh, ocfg)
        with mesh:
            first = None
            for _ in range(8):
                params, opt, m = step(params, opt, batch)
                if first is None:
                    first = float(m["loss"])
        assert float(m["loss"]) < first, f"{name} failed to reduce loss"


def test_adafactor_memory_factored():
    """Adafactor second moments are O(rows + cols), not O(rows * cols)."""
    p = {"w": jnp.zeros((128, 64)), "b": jnp.zeros((64,))}
    st = adafactor_init(p)
    assert st["v"]["w"]["vr"].shape == (128,)
    assert st["v"]["w"]["vc"].shape == (64,)
    assert st["v"]["b"]["v"].shape == (64,)


@pytest.mark.slow
def test_grad_accumulation_matches_full_batch():
    mesh = _mesh1()
    cfg = TINY.replace(remat=False, compute_dtype="float32")
    spec = lm.build_spec(cfg)
    ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step1, *_ = make_train_step(spec, mesh, ocfg, accum=1, donate=False)
    step4, *_ = make_train_step(spec, mesh, ocfg, accum=4, donate=False)
    params, opt = init_state(spec, mesh, ocfg)
    batch = _batch(b=8, s=16)
    with mesh:
        p1, _, m1 = step1(params, opt, batch)
        p4, _, m4 = step4(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# checkpoint / restart / elastic re-mesh
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_restart_recovers_exactly(tmp_path):
    """Crash at step 4 (after the step-3 checkpoint), restart, finish.

    The RESTORE itself is bit-exact (params round-trip through the atomic
    checkpoint unchanged); the post-restore loss trajectory matches the
    straight-through run to fp32-noise tolerance (CPU threadpool reduction
    ordering is not deterministic under load)."""
    mesh = _mesh1()
    cfg = TINY.replace(compute_dtype="float32")
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")

    # run A: straight through, checkpointing every 3
    pa, _, straight = train_loop(cfg, mesh, steps=6, batch=4, seq=32,
                                 ckpt_dir=d1, ckpt_every=3, log_every=100)
    # run B: crash at step 4 (after ckpt at 3), then resume
    with pytest.raises(InjectedFailure):
        train_loop(cfg, mesh, steps=6, batch=4, seq=32,
                   ckpt_dir=d2, ckpt_every=3, fail_at=4, log_every=100)
    assert latest_step(d2) == 3

    # restore fidelity: the step-3 checkpoints of runs A and B are identical
    import jax as _jax
    from repro.models import lm as _lm
    spec = _lm.build_spec(cfg)
    pshape = _jax.eval_shape(lambda k: _lm.init_params(spec, k), _jax.random.PRNGKey(0))
    from repro.training.optim import make_optimizer
    oshape = _jax.eval_shape(make_optimizer(OptConfig())[0], pshape)
    tpl = {"params": pshape, "opt": oshape}
    sa, _, _ = ckpt.restore(d1, 3, tpl)
    sb, _, _ = ckpt.restore(d2, 3, tpl)
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        # cross-run states agree to fp32 thread-order noise (strict bit
        # round-trip of a single checkpoint is test_checkpoint_atomicity)
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-6, atol=1e-7
        )

    _, _, resumed = train_loop(cfg, mesh, steps=6, batch=4, seq=32,
                               ckpt_dir=d2, ckpt_every=3, log_every=100)
    assert len(resumed) == 3
    np.testing.assert_allclose(straight[3:], resumed, rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_elastic_remesh_restore(tmp_path):
    """Checkpoint on a 2x2 mesh, restore onto 1x1 -- loss trajectory equal."""
    cfg = TINY.replace(compute_dtype="float32")
    d = str(tmp_path / "remesh")
    mesh_a = make_cpu_mesh(2, 2)
    _, _, la = train_loop(cfg, mesh_a, steps=4, batch=4, seq=32,
                          ckpt_dir=d, ckpt_every=2, log_every=100)
    # resume the remaining steps on a different mesh
    mesh_b = _mesh1()
    _, _, lb = train_loop(cfg, mesh_b, steps=6, batch=4, seq=32,
                          ckpt_dir=d, ckpt_every=100, log_every=100)
    # lb covers steps 4..5 continuing from the step-4 checkpoint of mesh_a
    assert len(lb) == 2 and all(np.isfinite(lb))


def test_checkpoint_atomicity(tmp_path):
    d = str(tmp_path)
    tree = {"x": jnp.arange(10), "y": {"z": jnp.ones((3, 3))}}
    ckpt.save(d, 1, tree)
    # a stale .tmp from a crashed writer must be invisible
    os.makedirs(os.path.join(d, "step_00000002.tmp"), exist_ok=True)
    assert latest_step(d) == 1
    tpl = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back, _, step = ckpt.restore(d, 1, tpl)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(back["x"]), np.arange(10))


def test_async_checkpointer_surfaces_errors(tmp_path):
    blocker = tmp_path / "file"
    blocker.write_text("not a dir")
    ac = ckpt.AsyncCheckpointer()
    # parent is a FILE -> makedirs inside the worker thread must fail and the
    # error must surface at the next wait()
    ac.save(str(blocker / "x"), 1, {"a": jnp.zeros(1)})
    with pytest.raises(BaseException):
        ac.wait()


# ---------------------------------------------------------------------------
# watchdog / failure injection
# ---------------------------------------------------------------------------


def test_straggler_watchdog_flags_slow_steps():
    dog = StragglerWatchdog(factor=2.0, warmup_steps=2)
    for i in range(5):
        assert not dog.observe(i, 0.1)
    assert dog.observe(5, 0.5)  # 5x EMA
    assert dog.flags and dog.flags[0][0] == 5
    assert not dog.observe(6, 0.1)  # EMA not poisoned by the outlier


def test_failure_injector_fires_once():
    inj = FailureInjector(fail_at_step=3)
    inj.check(2)
    with pytest.raises(InjectedFailure):
        inj.check(3)
    inj.check(3)  # second pass (post-restart) does not re-fire
