"""TileStore + streaming executor: round-trip, resume, streamed == resident."""

import numpy as np
import pytest

from repro.core import (
    CommuteConfig,
    SequenceDetector,
    chain_build_count,
    detect_anomalies,
    detect_sequence_anomalies,
    reset_stream_stats,
    stream_stats,
)
from repro.graphs import gmm_store_sequence, gmm_snapshot_sequence, store_snapshot_sequence
from repro.store import TileStore

# Tiny accuracy knobs: store tests exercise plumbing, not convergence.
CFG = CommuteConfig(eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4)


def _sym(n: int, seed: int) -> np.ndarray:
    a = np.abs(np.random.default_rng(seed).normal(size=(n, n))).astype(np.float32)
    a = (a + a.T) / 2.0
    np.fill_diagonal(a, 0.0)
    return a


@pytest.fixture(params=["ctx1", "ctx22"])
def ctx(request):
    return request.getfixturevalue(request.param)


# ---------------------------------------------------------------------------
# manifest / tile round-trip
# ---------------------------------------------------------------------------


def test_manifest_tile_roundtrip(tmp_path):
    a = _sym(32, 0)
    store = TileStore.create(tmp_path / "s", n=32, grid=4)
    store.put_snapshot("t000", a)

    re = TileStore.open(tmp_path / "s")
    assert (re.n, re.grid, re.dtype) == (32, 4, np.dtype(np.float32))
    assert re.snapshot_ids == ["t000"]
    h = re.snapshot("t000")
    np.testing.assert_array_equal(h.to_numpy(), a)
    # tile-level read sees the exact block
    np.testing.assert_array_equal(np.asarray(re.read_tile("t000", 1, 2)), a[8:16, 16:24])
    # panels are tile-aligned
    np.testing.assert_array_equal(h.read_panel(8, 8), a[8:16])
    with pytest.raises(ValueError):
        h.read_panel(3, 8)


def test_ram_backend_roundtrip():
    a = _sym(16, 1)
    store = TileStore.create(None, n=16, grid=2)
    store.put_snapshot("x", a)
    np.testing.assert_array_equal(store.snapshot("x").to_numpy(), a)


def test_ram_backend_copies_on_put():
    """The store captures put-time values, not a view of the caller's array."""
    a = _sym(16, 1)
    want = a.copy()
    store = TileStore.create(None, n=16, grid=1)  # grid=1: whole-array tile
    store.put_snapshot("x", a)
    a[:] = 0.0
    np.testing.assert_array_equal(store.snapshot("x").to_numpy(), want)


def test_create_rejects_incompatible_geometry(tmp_path):
    TileStore.create(tmp_path / "s", n=32, grid=4)
    with pytest.raises(ValueError, match="incompatible"):
        TileStore.create(tmp_path / "s", n=32, grid=2)


def test_create_rejects_stale_content(tmp_path):
    """Same geometry but different content meta must not silently resume."""
    TileStore.create(tmp_path / "s", n=32, grid=4, meta={"dataset": "gmm", "seed": 0})
    # same meta resumes fine
    TileStore.create(tmp_path / "s", n=32, grid=4, meta={"dataset": "gmm", "seed": 0})
    with pytest.raises(ValueError, match="different content"):
        TileStore.create(tmp_path / "s", n=32, grid=4, meta={"dataset": "climate", "seed": 0})
    # meta survives reopen
    assert TileStore.open(tmp_path / "s").manifest.meta == {"dataset": "gmm", "seed": 0}

    # an unlabeled store WITH committed snapshots must not adopt a new label
    unlabeled = TileStore.create(tmp_path / "u", n=16, grid=2)
    unlabeled.put_snapshot("t000", _sym(16, 9))
    with pytest.raises(ValueError, match="different content"):
        TileStore.create(tmp_path / "u", n=16, grid=2, meta={"dataset": "gmm"})
    # ... but an empty unlabeled store may be stamped and resumed
    TileStore.create(tmp_path / "e", n=16, grid=2)
    TileStore.create(tmp_path / "e", n=16, grid=2, meta={"dataset": "gmm"})
    assert TileStore.open(tmp_path / "e").manifest.meta == {"dataset": "gmm"}


# ---------------------------------------------------------------------------
# resume after partial write
# ---------------------------------------------------------------------------


def test_resume_after_partial_write(tmp_path):
    a = _sym(32, 2)
    store = TileStore.create(tmp_path / "s", n=32, grid=4)

    # simulate a crash: write 5 of 16 tiles, never commit
    w = store.writer("t000")
    for r, c in w.missing_tiles()[:5]:
        w.put_tile(r, c, a[r * 8 : r * 8 + 8, c * 8 : c * 8 + 8])
    with pytest.raises(ValueError, match="incomplete"):
        w.commit()

    # a fresh open sees no committed snapshot, but the tiles survived
    re = TileStore.create(tmp_path / "s", n=32, grid=4)
    assert re.snapshot_ids == []
    w2 = re.writer("t000")
    assert len(w2.missing_tiles()) == 11  # resumes, doesn't rewrite
    with w2:
        for r, c in w2.missing_tiles():
            w2.put_tile(r, c, a[r * 8 : r * 8 + 8, c * 8 : c * 8 + 8])
    assert re.snapshot_ids == ["t000"]
    np.testing.assert_array_equal(re.snapshot("t000").to_numpy(), a)

    # put_snapshot on a committed id is a no-op resume, not a rewrite
    re.put_snapshot("t000", a)
    assert re.snapshot_ids == ["t000"]


def test_store_writer_sequence_resumes(tmp_path, ctx1):
    seq = gmm_snapshot_sequence(ctx1, 32, 3, seed=5, inject_p=0.02)
    store = TileStore.create(tmp_path / "s", n=32, grid=2)
    ids = store_snapshot_sequence(store, seq)
    assert store.snapshot_ids == ids == ["t0000", "t0001", "t0002"]
    # re-running skips everything already committed
    again = store_snapshot_sequence(store, gmm_snapshot_sequence(ctx1, 32, 3, seed=5, inject_p=0.02))
    assert again == ids


# ---------------------------------------------------------------------------
# streamed == resident, bitwise (1x1 and 2x2 meshes)
# ---------------------------------------------------------------------------


def test_streamed_detect_bitwise_equals_resident(ctx, tmp_path):
    n = 32
    a1, a2 = _sym(n, 3), _sym(n, 4)
    store = TileStore.create(tmp_path / "s", n=n, grid=4)
    h1, h2 = store.put_snapshot("t0", a1), store.put_snapshot("t1", a2)

    res_r = detect_anomalies(ctx, ctx.put_matrix(a1), ctx.put_matrix(a2), CFG, top_k=5)
    res_s = detect_anomalies(ctx, h1, h2, CFG, top_k=5)
    np.testing.assert_array_equal(np.asarray(res_s.scores), np.asarray(res_r.scores))
    np.testing.assert_array_equal(np.asarray(res_s.top_idx), np.asarray(res_r.top_idx))

    # mixed resident/store endpoints stream too
    res_m = detect_anomalies(ctx, ctx.put_matrix(a1), h2, CFG, top_k=5)
    np.testing.assert_array_equal(np.asarray(res_m.scores), np.asarray(res_r.scores))


def test_streamed_sequence_bitwise_equals_resident(ctx):
    n, t_steps = 32, 3
    snaps = [_sym(n, 10 + t) for t in range(t_steps)]
    store = TileStore.create(None, n=n, grid=2)  # RAM-backed
    for t, s in enumerate(snaps):
        store.put_snapshot(f"t{t}", s)

    res_r = detect_sequence_anomalies(ctx, (ctx.put_matrix(s) for s in snaps), CFG, top_k=5)
    builds0 = chain_build_count()
    res_s = detect_sequence_anomalies(ctx, store.iter_snapshots(), CFG, top_k=5)
    assert chain_build_count() - builds0 == t_steps  # one chain build per snapshot
    for a, b in zip(res_r.transitions, res_s.transitions):
        np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    np.testing.assert_array_equal(
        np.asarray(res_r.global_top_val), np.asarray(res_s.global_top_val)
    )


def test_streamed_residency_bounded_by_panels(ctx1):
    """The executor holds at most two in-flight panels per streamed operand."""
    n, grid = 64, 8
    snaps = [_sym(n, 20 + t) for t in range(2)]
    store = TileStore.create(None, n=n, grid=grid)
    for t, s in enumerate(snaps):
        store.put_snapshot(f"t{t}", s)
    panel_bytes = (n // grid) * n * 4

    reset_stream_stats()
    detect_anomalies(ctx1, store.snapshot("t0"), store.snapshot("t1"), CFG, top_k=5)
    st = stream_stats()
    assert st.panels > 0
    # scoring streams two operands, double-buffered: <= 4 panels live
    assert st.peak_live_bytes <= 4 * panel_bytes
    assert st.bytes_h2d >= 2 * n * n * 4  # both endpoints streamed at least once


def test_streamed_fuse_l_close_and_counted(ctx1):
    """The streamed fuse_l chain build (per-panel GEMM accumulation) stays
    allclose to the resident fuse_l run and its panels enter stream_stats."""
    n = 32
    a1, a2 = _sym(n, 30), _sym(n, 31)
    store = TileStore.create(None, n=n, grid=4)
    h1, h2 = store.put_snapshot("t0", a1), store.put_snapshot("t1", a2)
    cfg = CommuteConfig(eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4, fuse_l=True)

    res_r = detect_anomalies(ctx1, ctx1.put_matrix(a1), ctx1.put_matrix(a2), cfg, top_k=5)
    reset_stream_stats()
    res_s = detect_anomalies(ctx1, h1, h2, cfg, top_k=5)
    np.testing.assert_allclose(
        np.asarray(res_s.scores), np.asarray(res_r.scores), rtol=1e-4, atol=1e-3
    )
    # 2 embeddings x (degrees + S build + fuse_l GEMM + edge proj) + scorer,
    # each >= grid panels; the fuse_l GEMM's H2D must be accounted too.
    assert stream_stats().panels >= 9 * 4


# ---------------------------------------------------------------------------
# out-of-core chain: allclose scores, panel-bounded residency
# ---------------------------------------------------------------------------


def test_oocore_chain_scores_allclose(ctx, tmp_path):
    """chain_product(oocore=True) end-to-end: scores allclose (rtol<=1e-4) to
    the resident build on 1x1 and 2x2 meshes, adjacency AND chain streamed."""
    n = 32
    a1, a2 = _sym(n, 40), _sym(n, 41)
    store = TileStore.create(tmp_path / "s", n=n, grid=4)
    h1, h2 = store.put_snapshot("t0", a1), store.put_snapshot("t1", a2)
    cfg_oo = CommuteConfig(
        eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4, oocore=True
    )

    res_r = detect_anomalies(ctx, ctx.put_matrix(a1), ctx.put_matrix(a2), CFG, top_k=5)
    res_o = detect_anomalies(ctx, h1, h2, cfg_oo, top_k=5)
    np.testing.assert_allclose(
        np.asarray(res_o.scores), np.asarray(res_r.scores), rtol=1e-4, atol=1e-3
    )

    # resident-adjacency input with an out-of-core chain also works
    res_m = detect_anomalies(ctx, ctx.put_matrix(a1), ctx.put_matrix(a2), cfg_oo, top_k=5)
    np.testing.assert_allclose(
        np.asarray(res_m.scores), np.asarray(res_r.scores), rtol=1e-4, atol=1e-3
    )


def test_oocore_chain_residency_bounded_by_panels(ctx1):
    """During an out-of-core chain build, peak live panel bytes stay under
    2 * panel * n * 4 bytes per GEMM operand (left, right, accumulator) --
    bounded by panels, not by the 5 * n^2 resident working set."""
    from repro.core import chain_product

    n, grid = 64, 8
    store = TileStore.create(None, n=n, grid=grid)
    h = store.put_snapshot("t0", _sym(n, 42))
    work = TileStore.create(None, n=n, grid=grid)
    ph = n // grid

    reset_stream_stats()
    op = chain_product(
        ctx1, h, 3, schedule="xla", oocore=True, oocore_work=work, oocore_panel_rows=ph
    )
    st = stream_stats()
    panel_bytes = ph * n * 4
    assert st.panels > 0
    assert st.peak_live_bytes <= 3 * 2 * panel_bytes  # 2 panels per GEMM operand
    assert st.peak_live_bytes < 5 * n * n * 4  # and far under the resident set
    # the operator itself is store-backed: the solver streams it
    assert hasattr(op.p1, "read_panel") and hasattr(op.p2, "read_panel")
    # intermediates were retired: only P1 and P2 survive in the scratch
    assert len(work.snapshot_ids) == 2


def test_oocore_chain_sequence_retires_scratch(ctx1, tmp_path):
    """Outgoing operators' scratch snapshots are retired as the two-snapshot
    window advances -- with or without donate -- so a disk scratch stays
    bounded by the window, not the sequence length.  The user's input store
    is never touched."""
    n = 32
    scratch = tmp_path / "scratch"
    cfg_oo = CommuteConfig(
        eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4,
        oocore=True, oocore_dir=str(scratch),
    )
    store = TileStore.create(None, n=n, grid=4)
    for t in range(4):
        store.put_snapshot(f"t{t}", _sym(n, 50 + t))
    det = SequenceDetector(ctx1, cfg_oo, top_k=5)  # donate=False
    res = det.run(store.iter_snapshots())
    assert len(res.transitions) == 3
    assert store.snapshot_ids == ["t0", "t1", "t2", "t3"]  # user data untouched
    # only the still-live window's operator (last snapshot: P1 + P2) remains
    assert len(TileStore.open(scratch).snapshot_ids) == 2


# ---------------------------------------------------------------------------
# tile codecs: round-trip, fingerprint, accuracy contracts
# ---------------------------------------------------------------------------


def test_bf16_codec_roundtrip_halves_stored_bytes(tmp_path):
    from repro.store.tilestore import _bf16_u16_to_f32, _f32_to_bf16_u16

    n = 32
    a = _sym(n, 60)
    want = _bf16_u16_to_f32(_f32_to_bf16_u16(a))  # bf16-rounded values
    store = TileStore.create(tmp_path / "s", n=n, grid=1, codec="bf16")
    h = store.put_snapshot("t", a)
    np.testing.assert_array_equal(h.to_numpy(), want)
    # the rounding is the documented contract: relative error <= 2^-8
    np.testing.assert_allclose(want, a, rtol=2 ** -8, atol=1e-7)
    # stored bytes are half the logical bytes (modulo .npy headers)
    _, stored = h.read_panel_info(0, n)
    assert stored < 0.6 * n * n * 4
    # survives reopen (codec comes from the manifest, not the caller)
    np.testing.assert_array_equal(TileStore.open(tmp_path / "s").snapshot("t").to_numpy(), want)


def test_codec_joins_geometry_fingerprint(tmp_path):
    TileStore.create(tmp_path / "s", n=32, grid=4, codec="bf16")
    with pytest.raises(ValueError, match="codec"):
        TileStore.create(tmp_path / "s", n=32, grid=4)  # raw != bf16: loud error
    with pytest.raises(ValueError, match="unknown tile codec"):
        TileStore.create(tmp_path / "x", n=32, grid=4, codec="lz77")
    # bf16 squeezes an 8-bit mantissa: wider store dtypes must error loudly
    with pytest.raises(ValueError, match="float32"):
        TileStore.create(tmp_path / "y", n=32, grid=4, dtype="float64", codec="bf16")


def test_zstd_roundtrip_or_clean_fallback(tmp_path):
    """With a zstd backend: lossless round-trip.  Without: create() falls back
    to raw with a warning and the manifest records what the tiles really are."""
    from repro.store.tilestore import _zstd_backend

    a = _sym(32, 61)
    if _zstd_backend() is None:
        with pytest.warns(UserWarning, match="falling back"):
            store = TileStore.create(tmp_path / "s", n=32, grid=2, codec="zstd")
        assert store.manifest.codec == "raw"
        h = store.put_snapshot("t", a)
        np.testing.assert_array_equal(h.to_numpy(), a)
    else:
        store = TileStore.create(tmp_path / "s", n=32, grid=2, codec="zstd")
        assert store.manifest.codec == "zstd"
        h = store.put_snapshot("t", a)
        np.testing.assert_array_equal(h.to_numpy(), a)  # zstd is lossless
        _, stored = h.read_panel_info(0, 32)
        assert stored != 32 * 32 * 4  # actually compressed


def test_streamed_bf16_scores_bitwise_vs_resident_on_rounded(ctx1):
    """The bf16 codec's accuracy contract: rounding happens once at write
    time, and the streamed run is *bitwise* identical to a resident run on
    the rounded adjacencies -- the codec never adds compute-path error."""
    from repro.store.tilestore import _bf16_u16_to_f32, _f32_to_bf16_u16

    n = 32
    a1, a2 = _sym(n, 62), _sym(n, 63)
    store = TileStore.create(None, n=n, grid=4, codec="bf16")
    h1, h2 = store.put_snapshot("t0", a1), store.put_snapshot("t1", a2)
    r1 = _bf16_u16_to_f32(_f32_to_bf16_u16(a1))
    r2 = _bf16_u16_to_f32(_f32_to_bf16_u16(a2))

    res_s = detect_anomalies(ctx1, h1, h2, CFG, top_k=5)
    res_r = detect_anomalies(ctx1, ctx1.put_matrix(r1), ctx1.put_matrix(r2), CFG, top_k=5)
    np.testing.assert_array_equal(np.asarray(res_s.scores), np.asarray(res_r.scores))


def test_oocore_bf16_scratch_scores_close(ctx1):
    """bf16 *scratch* rounds the working matrices at every level: looser
    contract (documented in the README codec table), still anomaly-ranking
    grade."""
    n = 32
    a1, a2 = _sym(n, 64), _sym(n, 65)
    store = TileStore.create(None, n=n, grid=4)
    h1, h2 = store.put_snapshot("t0", a1), store.put_snapshot("t1", a2)
    cfg = CommuteConfig(
        eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4,
        oocore=True, tile_codec="bf16",
    )
    res_r = detect_anomalies(ctx1, ctx1.put_matrix(a1), ctx1.put_matrix(a2), CFG, top_k=5)
    res_o = detect_anomalies(ctx1, h1, h2, cfg, top_k=5)
    np.testing.assert_allclose(
        np.asarray(res_o.scores), np.asarray(res_r.scores), rtol=5e-2, atol=5e-2
    )


# ---------------------------------------------------------------------------
# iteration-batched Richardson: fewer scratch reads, identical scores
# ---------------------------------------------------------------------------


def test_solver_batch_cuts_scratch_reads_scores_allclose(ctx):
    """Acceptance: solver_batch=4 drops solve-phase scratch reads >= 2x and
    out-of-core scores stay allclose (rtol <= 1e-4) to resident, on the 1x1
    and 2x2 meshes."""
    from repro.core import chain_product, estimate_solution
    from repro.core.embedding import edge_projection

    n, d, q = 32, 3, 9
    a1, a2 = _sym(n, 70), _sym(n, 71)
    store = TileStore.create(None, n=n, grid=4)
    h1, h2 = store.put_snapshot("t0", a1), store.put_snapshot("t1", a2)

    # solve-phase traffic, measured directly on one operator
    op = chain_product(ctx, h1, d, oocore=True)
    y = edge_projection(ctx, h1, 0, 4)
    reads, sols = {}, {}
    for batch in (1, 4):
        reset_stream_stats()
        sols[batch] = np.asarray(estimate_solution(ctx, op, y, q, solver_batch=batch))
        reads[batch] = stream_stats().bytes_read
    op.release_scratch()
    assert reads[1] >= 2 * reads[4]
    # replayed panels are bitwise: batching cannot change the solution
    np.testing.assert_array_equal(sols[1], sols[4])

    # end-to-end: batched oocore detect stays allclose to resident
    cfg_oo = CommuteConfig(
        eps_rp=1e-2, d=3, q=3, schedule="xla", k_override=4,
        oocore=True, solver_batch=4, prefetch_depth=4,
    )
    res_r = detect_anomalies(ctx, ctx.put_matrix(a1), ctx.put_matrix(a2), CFG, top_k=5)
    res_o = detect_anomalies(ctx, h1, h2, cfg_oo, top_k=5)
    np.testing.assert_allclose(
        np.asarray(res_o.scores), np.asarray(res_r.scores), rtol=1e-4, atol=1e-3
    )


def test_stream_stats_byte_counters_track_codec(ctx1):
    """bytes_read (pre-codec) vs bytes_decoded (post-codec): raw moves them
    together; bf16 reads roughly half of what it decodes."""
    n = 32
    a1, a2 = _sym(n, 72), _sym(n, 73)
    ratios = {}
    for codec in ("raw", "bf16"):
        store = TileStore.create(None, n=n, grid=4, codec=codec)
        h1, h2 = store.put_snapshot("t0", a1), store.put_snapshot("t1", a2)
        reset_stream_stats()
        detect_anomalies(ctx1, h1, h2, CFG, top_k=5)
        st = stream_stats()
        assert st.bytes_decoded > 0
        ratios[codec] = st.bytes_read / st.bytes_decoded
    assert ratios["raw"] == pytest.approx(1.0)  # RAM raw backend: no headers
    assert ratios["bf16"] == pytest.approx(0.5)


def test_out_of_core_writer_matches_resident_build(ctx1):
    """gmm_store_sequence (numpy, tile-by-tile) == similarity_graph (sharded)."""
    from repro.graphs import gmm_points, similarity_graph

    n = 32
    store = TileStore.create(None, n=n, grid=4)
    (sid,) = gmm_store_sequence(store, 1, seed=7)
    pts, _ = gmm_points(n, 7)
    resident = np.asarray(similarity_graph(ctx1, pts))
    np.testing.assert_allclose(store.snapshot(sid).to_numpy(), resident, rtol=1e-6, atol=1e-6)
