"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.core import rng as crng
from repro.models import mamba2 as mb
from repro.models import rwkv6 as rk
from repro.training.train_step import dequantize_int8, quantize_int8

_SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# counter RNG invariants
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(0, 2**31 - 1),
    i=st.integers(0, 10_000),
    j=st.integers(0, 10_000),
    c=st.integers(0, 64),
)
@settings(**_SETTINGS)
def test_edge_rademacher_antisymmetric(seed, i, j, c):
    qij = float(np.asarray(crng.edge_rademacher(seed, i, j, c)))
    qji = float(np.asarray(crng.edge_rademacher(seed, j, i, c)))
    if i == j:
        assert qij == 0.0
    else:
        assert qij in (-1.0, 1.0)
        assert qij == -qji


@given(seed=st.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_edge_rademacher_unbiased(seed):
    rows = jnp.arange(64)[:, None]
    cols = jnp.arange(64)[None, :]
    q = np.asarray(crng.edge_rademacher(seed, rows, cols, 0))
    off = q[~np.eye(64, dtype=bool)]
    assert abs(off.mean()) < 0.2  # ~N(0, 1/sqrt(4032))


@given(
    seed=st.integers(0, 2**31 - 1),
    parts=st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=4),
)
@settings(**_SETTINGS)
def test_hash_u32_deterministic(seed, parts):
    a = np.asarray(crng.hash_u32(np.uint32(seed), *[np.uint32(p) for p in parts]))
    b = np.asarray(crng.hash_u32(np.uint32(seed), *[np.uint32(p) for p in parts]))
    assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# int8 error-feedback quantization
# ---------------------------------------------------------------------------


@given(
    scale=st.floats(1e-6, 1e4),
    n=st.integers(4, 256),
    seed=st.integers(0, 1000),
)
@settings(**_SETTINGS)
def test_int8_quant_bounded_error(scale, n, seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n,)).astype(np.float32) * scale)
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    # max error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(deq - x))) <= float(s) * 0.5 + 1e-9


# ---------------------------------------------------------------------------
# chunked recurrences == naive scans for arbitrary chunk splits
# ---------------------------------------------------------------------------


@given(
    s=st.sampled_from([16, 32, 48, 64]),
    chunk=st.sampled_from([4, 8, 16, 64]),
    seed=st.integers(0, 100),
)
@settings(max_examples=10, deadline=None)
def test_ssd_chunk_invariance(s, chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    B, H, P, N = 2, 2, 4, 8
    x = jax.random.normal(ks[0], (B, s, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, s, H)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, H))
    bm = jax.random.normal(ks[2], (B, s, N))
    cm = jax.random.normal(ks[3], (B, s, N))
    d = jnp.ones((H,))
    y1, h1 = mb.ssd_chunked(x, dt, a_log, bm, cm, d, chunk=chunk)
    y2, h2 = mb.ssd_reference(x, dt, a_log, bm, cm, d)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-3, atol=1e-3)


@given(
    s=st.sampled_from([16, 32, 64]),
    chunk=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 100),
)
@settings(max_examples=10, deadline=None)
def test_wkv_chunk_invariance(s, chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, H, K, V = 2, 2, 4, 4
    r = jax.random.normal(ks[0], (B, s, H, K))
    k = jax.random.normal(ks[1], (B, s, H, K))
    v = jax.random.normal(ks[2], (B, s, H, V))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, s, H, K)) * 0.5 - 1.0)
    u = 0.1 * jax.random.normal(ks[4], (H, K))
    y1, s1 = rk.wkv_chunked(r, k, v, lw, u, chunk=chunk)
    y2, s2 = rk.wkv_reference(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# data pipeline determinism / shard-independence
# ---------------------------------------------------------------------------


@given(step=st.integers(0, 1000), seed=st.integers(0, 1000))
@settings(**_SETTINGS)
def test_data_restart_exact(step, seed):
    from repro.data import DataConfig, host_batch

    cfg = DataConfig(vocab=512, seq_len=16, global_batch=4, seed=seed)
    b1 = host_batch(cfg, step)
    b2 = host_batch(cfg, step)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 512).all()
    # labels are the shifted tokens
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
