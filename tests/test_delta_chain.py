"""Incremental delta-chain updates (ISSUE 9): correctness, telemetry, lifecycle.

Covers the acceptance bars end to end:

* combination matrix -- incremental x warm-start x (resident, oocore) x
  (1x1, 2x2 mesh) -- scores allclose to the full-rebuild path within the
  documented tolerance (1e-3 of the commute-distance scale ``V_G E||z||^2``;
  on a quiet drifting sequence the raw scores sit orders of magnitude below
  that scale, so relative-to-score tolerances would be meaningless),
* the >= 3x chain-phase GEMM FLOP / scratch-byte reduction, asserted from the
  registry counters each scored transition records,
* the drift monitor's fallback on an abrupt-change transition,
* the shared-base scratch lifecycle (satellite: no leak, no double-free),
* ``truncate_factors`` optimality (the rank-r recompression the level
  propagation leans on).

The heavy rank x solver x storage sweep rides behind ``-m slow``.
"""

import warnings as _warnings
from dataclasses import replace

import numpy as np
import pytest

from repro.core import (
    CommuteConfig,
    build_base_chain,
    detect_sequence_anomalies,
    full_build_gemm_cost,
    truncate_factors,
    try_delta_update,
)
from repro.core.embedding import commute_time_embedding
from repro.graphs import gmm_snapshot_sequence


@pytest.fixture(params=["ctx1", "ctx22"])
def ctx(request):
    return request.getfixturevalue(request.param)


# Localized drift (3 movers / step) keeps dS near-low-rank -- the regime the
# delta path targets; global point noise would make dS full-rank and the
# drift monitor would (correctly) reject every transition.
_DRIFT_KW = dict(seed=5, noise=0.02, inject_steps=set(), drift_nodes=3)

_BASE_CFG = CommuteConfig(
    eps_rp=1e-2, d=3, q=8, schedule="xla", k_override=4,
    solver="cg", solver_tol=1e-5, warm_start=True,
)
_INC_CFG = replace(_BASE_CFG, incremental_chain=True, delta_rank=6, delta_budget=0.1)


def _drifting_snapshots(ctx, n, t_steps, storage):
    """Slowly-drifting localized-movement GMM sequence; oocore variants are
    served as store-backed handles so the whole transition streams."""
    seq = gmm_snapshot_sequence(ctx, n, t_steps, **_DRIFT_KW)
    if storage == "oocore":
        from repro.store import TileStore

        store = TileStore.create(None, n=n, grid=4)
        for t, a in enumerate(seq.snapshots()):
            store.put_snapshot(f"t{t:03d}", np.asarray(a))
        return store.iter_snapshots()
    return seq.snapshots()


def _commute_scale(ctx, cfg, n, t_steps):
    """The commute-distance scale V_G * E||z_i||^2 -- the natural atol anchor
    (same convention as the warm-start acceptance tests)."""
    seq = gmm_snapshot_sequence(ctx, n, t_steps, **_DRIFT_KW)
    emb = commute_time_embedding(ctx, next(seq.snapshots()), cfg)
    z = np.asarray(emb.z, np.float64)
    return float(emb.vol) * float((z * z).sum(1).mean())


def _counter(metrics: dict, name: str) -> float:
    return float(metrics.get(f"chain.{name}", 0.0))


# ---------------------------------------------------------------------------
# combination matrix: incremental x warm x storage x mesh
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("storage", ["resident", "oocore"])
def test_incremental_scores_allclose_full_rebuild(ctx, storage):
    """Acceptance (1x1 AND 2x2 mesh, resident AND out-of-core, warm-started):
    incremental-chain scores stay allclose (rtol 1e-3, atol 1e-3 of the
    commute-distance scale) to the full-rebuild run, with every transition
    after the first push served by a delta update (no fallbacks)."""
    n, t_steps = 48, 3
    full_cfg = replace(_BASE_CFG, oocore=storage == "oocore")
    inc_cfg = replace(_INC_CFG, oocore=storage == "oocore")
    full = detect_sequence_anomalies(
        ctx, _drifting_snapshots(ctx, n, t_steps, storage), full_cfg, top_k=5
    )
    inc = detect_sequence_anomalies(
        ctx, _drifting_snapshots(ctx, n, t_steps, storage), inc_cfg, top_k=5
    )
    scale = _commute_scale(ctx, replace(full_cfg, oocore=False), n, t_steps)
    for t, (f, i) in enumerate(zip(full.transitions, inc.transitions)):
        np.testing.assert_allclose(
            np.asarray(i.scores), np.asarray(f.scores),
            rtol=1e-3, atol=1e-3 * scale, err_msg=f"transition {t}",
        )
    # the first push was the one full build; everything after was a delta
    assert _counter(inc.warmup_metrics, "full_rebuilds") == 1
    assert sum(_counter(m, "incremental_updates") for m in inc.transition_metrics) == t_steps - 1
    assert sum(_counter(m, "drift_fallbacks") for m in inc.transition_metrics) == 0
    assert sum(_counter(m, "full_rebuilds") for m in inc.transition_metrics) == 0


@pytest.mark.parametrize("method", ["richardson", "chebyshev"])
def test_incremental_all_solver_methods(ctx1, method):
    """The low-rank correction rides inside every solver's mat-vec: the
    non-CG methods match their own full-rebuild runs too (CG is covered by
    the combination matrix above)."""
    n, t_steps = 48, 3
    full_cfg = replace(_BASE_CFG, solver=method, solver_tol=1e-4)
    inc_cfg = replace(full_cfg, incremental_chain=True, delta_rank=6, delta_budget=0.1)
    full = detect_sequence_anomalies(
        ctx1, _drifting_snapshots(ctx1, n, t_steps, "resident"), full_cfg, top_k=5
    )
    inc = detect_sequence_anomalies(
        ctx1, _drifting_snapshots(ctx1, n, t_steps, "resident"), inc_cfg, top_k=5
    )
    scale = _commute_scale(ctx1, full_cfg, n, t_steps)
    for t, (f, i) in enumerate(zip(full.transitions, inc.transitions)):
        np.testing.assert_allclose(
            np.asarray(i.scores), np.asarray(f.scores),
            rtol=1e-3, atol=1e-3 * scale, err_msg=f"{method} transition {t}",
        )
    assert sum(_counter(m, "incremental_updates") for m in inc.transition_metrics) == t_steps - 1


# ---------------------------------------------------------------------------
# the >= 3x FLOP / scratch reduction (registry counters)
# ---------------------------------------------------------------------------


def test_incremental_gemm_flops_and_scratch_at_least_3x_less(ctx1):
    """Acceptance: every incremental transition's chain-phase GEMM FLOPs and
    materialized scratch bytes (registry counters ``chain.gemm_flops`` /
    ``chain.scratch_bytes``) are >= 3x below one full rebuild's cost at the
    benchmark size n=96, d=3, rank 6."""
    n, t_steps = 96, 3
    cfg = replace(_INC_CFG, k_override=6)
    res = detect_sequence_anomalies(
        ctx1, _drifting_snapshots(ctx1, n, t_steps, "resident"), cfg, top_k=5
    )
    full_flops, _, full_scratch = full_build_gemm_cost(n, cfg.d)
    assert sum(_counter(m, "drift_fallbacks") for m in res.transition_metrics) == 0
    for t, m in enumerate(res.transition_metrics):
        assert _counter(m, "incremental_updates") == 1, f"transition {t}"
        flops = _counter(m, "gemm_flops")
        scratch = _counter(m, "scratch_bytes")
        assert 0 < flops <= full_flops / 3.0, (t, flops, full_flops)
        assert 0 < scratch <= full_scratch / 3.0, (t, scratch, full_scratch)


# ---------------------------------------------------------------------------
# drift monitor: abrupt change falls back to a full rebuild
# ---------------------------------------------------------------------------


def test_drift_monitor_falls_back_on_abrupt_change(ctx1):
    """A structurally-different snapshot mid-sequence trips the sketched
    drift monitor: that transition pays one fallback + one full rebuild (and
    becomes the new base), while the quiet transitions stay incremental."""
    n = 48
    quiet = list(gmm_snapshot_sequence(ctx1, n, 3, **_DRIFT_KW).snapshots())
    abrupt = next(
        gmm_snapshot_sequence(
            ctx1, n, 2, seed=99, noise=0.02, inject_steps=set()
        ).snapshots()
    )
    res = detect_sequence_anomalies(ctx1, [*quiet, abrupt], _INC_CFG, top_k=5)
    # pushes: 0 = rebuild (warmup), 1..2 = delta updates, 3 = fallback+rebuild
    assert _counter(res.warmup_metrics, "full_rebuilds") == 1
    per_t = res.transition_metrics
    assert [_counter(m, "incremental_updates") for m in per_t] == [1, 1, 0]
    assert [_counter(m, "drift_fallbacks") for m in per_t] == [0, 0, 1]
    assert [_counter(m, "full_rebuilds") for m in per_t] == [0, 0, 1]
    for t in res.transitions:
        assert np.isfinite(np.asarray(t.scores)).all()


# ---------------------------------------------------------------------------
# shared-base scratch lifecycle (satellite: no leak, no double-free)
# ---------------------------------------------------------------------------


def test_shared_base_scratch_lifecycle_oocore(ctx1):
    """The base chain is the single owner of the out-of-core scratch: a
    corrected operator's ``release_scratch()`` is a no-op (its P1/P2 *are*
    the base's handles), ``BaseChain.release()`` empties the scratch store
    exactly once, and a second release is a clean no-op -- no warning, no
    double-free."""
    n = 48
    cfg = replace(_INC_CFG, oocore=True)
    snaps = list(gmm_snapshot_sequence(ctx1, n, 2, **_DRIFT_KW).snapshots())
    base = build_base_chain(ctx1, snaps[0], cfg)
    store = base.op.p1.store
    live = set(store.snapshot_ids)
    # p1 + p2 + d retained T levels + (d-2) retained P levels
    assert len(live) == 2 + cfg.d + (cfg.d - 2)

    corrected = try_delta_update(ctx1, base, snaps[1], cfg)
    assert corrected is not None and corrected.shared_base
    corrected.release_scratch()  # shares the base: must NOT retire scratch
    assert set(store.snapshot_ids) == live

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        base.release()  # the one real release: every retained handle dies
        assert store.snapshot_ids == []
        base.release()  # idempotent: no second remove, no warning


# ---------------------------------------------------------------------------
# factor truncation: exact best-rank-r recompression
# ---------------------------------------------------------------------------


def test_truncate_factors_is_optimal_rank_r():
    """``truncate_factors(u, v, r)`` matches the optimal (SVD) rank-r
    approximation of u v^T: the residual equals the singular-value tail."""
    rng = np.random.default_rng(0)
    u = rng.normal(size=(40, 6)).astype(np.float32)
    v = rng.normal(size=(40, 6)).astype(np.float32)
    prod = u.astype(np.float64) @ v.astype(np.float64).T
    s = np.linalg.svd(prod, compute_uv=False)
    for r in (2, 4, 6):
        ut, vt = truncate_factors(u, v, r)
        assert ut.shape == (40, r) and vt.shape == (40, r)
        err = np.linalg.norm(prod - ut.astype(np.float64) @ vt.astype(np.float64).T)
        opt = np.linalg.norm(s[r:])
        np.testing.assert_allclose(err, opt, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# heavy sweep: rank x storage x mesh (slow marker)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("storage", ["resident", "oocore"])
@pytest.mark.parametrize("rank", [4, 8])
def test_incremental_sweep_rank_storage_mesh(ctx, rank, storage):
    """Heavy combination sweep: delta rank x storage x mesh at n=96, T=4,
    warm-started CG -- scores allclose to full rebuild, zero fallbacks."""
    n, t_steps = 96, 4
    full_cfg = replace(_BASE_CFG, k_override=6, oocore=storage == "oocore")
    inc_cfg = replace(
        full_cfg, incremental_chain=True, delta_rank=rank, delta_budget=0.1
    )
    full = detect_sequence_anomalies(
        ctx, _drifting_snapshots(ctx, n, t_steps, storage), full_cfg, top_k=5
    )
    inc = detect_sequence_anomalies(
        ctx, _drifting_snapshots(ctx, n, t_steps, storage), inc_cfg, top_k=5
    )
    scale = _commute_scale(ctx, replace(full_cfg, oocore=False), n, t_steps)
    for t, (f, i) in enumerate(zip(full.transitions, inc.transitions)):
        np.testing.assert_allclose(
            np.asarray(i.scores), np.asarray(f.scores),
            rtol=1e-3, atol=1e-3 * scale,
            err_msg=f"rank={rank} {storage} transition {t}",
        )
    assert sum(_counter(m, "incremental_updates") for m in inc.transition_metrics) == t_steps - 1
    assert sum(_counter(m, "drift_fallbacks") for m in inc.transition_metrics) == 0
