"""Observability layer: tracer, metrics registry, facades, run reports.

Covers the ISSUE 7 acceptance surface: Chrome-trace schema validity and span
nesting, cross-thread producer-tid pairing through the panel pipeline,
disabled-tracer no-op guarantees, exact snapshot/delta semantics, the
``StreamStats`` facade contract (in-place reset, live references, the
reset-vs-add race), and a RunReport built from a real tiny sequence run whose
byte totals must equal the legacy ``stream_stats()`` counters.
"""

import json
import threading

import numpy as np
import pytest

from repro.core import (
    CommuteConfig,
    SequenceDetector,
    SolverSpec,
    chain_product,
    reset_stream_stats,
    solve,
    stream_stats,
)
from repro.core.tiles import StreamStats
from repro.graphs import gmm_snapshot_sequence
from repro.obs import metrics as obs_metrics
from repro.obs import phase
from repro.obs import trace as obs_trace
from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.report import (
    RUN_REPORT_KIND,
    build_run_report,
    save_run_report,
    validate_chrome_trace,
    validate_run_report,
)
from repro.store import PanelPipeline


@pytest.fixture(autouse=True)
def _quiet_tracer():
    """Every test starts and ends with tracing disabled and a clean buffer."""
    obs_trace.disable_tracing()
    obs_trace.tracer().clear()
    yield
    obs_trace.disable_tracing()
    obs_trace.tracer().clear()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_snapshot_delta_exact():
    reg = MetricsRegistry()
    reg.add(**{})
    reg.add_named({"a.x": 2.0, "a.y": 3.0})
    snap = reg.snapshot()
    reg.add_named({"a.x": 5.0, "b.z": 1.0})
    d = reg.delta(snap)
    # exact increments; untouched counters (a.y) are omitted entirely
    assert d == {"a.x": 5.0, "b.z": 1.0}
    assert reg.value("a.x") == 7.0
    # a second delta from the same snapshot is cumulative, not consumed
    reg.inc("a.x")
    assert reg.delta(snap)["a.x"] == 6.0


def test_registry_prefix_reset_and_gauges():
    reg = MetricsRegistry()
    reg.add_named({"s.n": 1.0, "t.n": 1.0})
    reg.max_gauge("s.peak", 10)
    reg.max_gauge("s.peak", 4)  # high-water mark keeps the max
    assert reg.gauge("s.peak") == 10
    reg.reset("s.")
    assert reg.value("s.n") == 0.0
    assert reg.gauge("s.peak") == 0.0
    assert reg.value("t.n") == 1.0  # other prefixes untouched


def test_registry_series_bounded():
    reg = MetricsRegistry(series_cap=4)
    snap = reg.snapshot()
    reg.extend("r", [1.0, 2.0])
    assert reg.series_delta("r", snap) == (1.0, 2.0)
    reg.extend("r", [3.0, 4.0, 5.0, 6.0])  # overflow dropped, not resized
    assert reg.series("r") == (1.0, 2.0, 3.0, 4.0)


def test_scoped_measurement():
    reg = MetricsRegistry()
    with obs_metrics.scoped(reg) as sc:
        reg.inc("inner", 3.0)
    reg.inc("inner", 1.0)  # after the scope; delta() still reads live
    assert sc.delta()["inner"] == 4.0


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_disabled_tracer_is_noop():
    assert not obs_trace.tracing_enabled()
    sp = obs_trace.span("nothing", x=1)
    with sp:
        sp.annotate(y=2)
        sp.fence(object())
    h = obs_trace.begin("cross")
    obs_trace.end(h)
    assert h == 0
    assert obs_trace.tracer().events() == []
    # the shared null span means zero allocation on the hot path
    assert obs_trace.span("a") is obs_trace.span("b")


def test_span_nesting_and_chrome_schema():
    obs_trace.enable_tracing()
    with obs_trace.span("outer", level=1):
        with obs_trace.span("inner"):
            pass
    doc = obs_trace.tracer().to_chrome_trace()
    validate_chrome_trace(doc)
    evs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    assert set(evs) == {"outer", "inner"}
    out, inn = evs["outer"], evs["inner"]
    # proper nesting: inner's interval is contained in outer's
    assert out["ts"] <= inn["ts"]
    assert inn["ts"] + inn["dur"] <= out["ts"] + out["dur"] + 1e-6
    assert out["args"] == {"level": 1}
    # thread-name metadata present for the recording thread
    assert any(e["ph"] == "M" and e["name"] == "thread_name"
               for e in doc["traceEvents"])
    # round-trips through JSON
    json.loads(json.dumps(doc))


def test_cross_thread_span_keeps_producer_tid():
    obs_trace.enable_tracing()
    handles = {}

    def producer():
        handles["h"] = obs_trace.begin("xfer", item=7)
        handles["tid"] = threading.get_ident()

    t = threading.Thread(target=producer, name="producer-thread")
    t.start()
    t.join()
    obs_trace.end(handles["h"], staged=True)
    (ev,) = [e for e in obs_trace.tracer().events() if e["ph"] == "X"]
    # the event lands on the PRODUCER's track, with the consumer's tid noted
    assert ev["tid"] == handles["tid"]
    assert ev["args"]["item"] == 7
    assert ev["args"]["staged"] is True
    assert ev["args"]["end_tid"] == threading.get_ident()
    names = obs_trace.tracer().to_chrome_trace()["traceEvents"]
    assert any(e["ph"] == "M" and e["args"]["name"] == "producer-thread"
               for e in names)


def test_trace_save_is_loadable(tmp_path):
    obs_trace.enable_tracing()
    with obs_trace.span("s"):
        pass
    path = tmp_path / "trace.json"
    obs_trace.tracer().save(str(path))
    with open(path) as f:
        validate_chrome_trace(json.load(f))


def test_phase_counters_accumulate_without_tracing():
    snap = REGISTRY.snapshot()
    with phase("solve"):
        pass
    with phase("solve"):
        pass
    d = REGISTRY.delta(snap)
    assert d["phase.solve.calls"] == 2.0
    assert d["phase.solve.seconds"] > 0.0
    # with tracing disabled, no span events were recorded
    assert obs_trace.tracer().events() == []


# ---------------------------------------------------------------------------
# StreamStats facade
# ---------------------------------------------------------------------------


def test_bare_streamstats_is_isolated():
    st = StreamStats()
    st.add(panels=2, bytes_h2d=100)
    assert (st.panels, st.bytes_h2d) == (2, 100)
    assert stream_stats() is not st
    # the process-wide counters did not move
    assert stream_stats()._reg is REGISTRY
    with pytest.raises(AttributeError):
        st.add(nonsense=1)


def test_reset_keeps_references_live():
    st = stream_stats()
    reset_stream_stats()
    st.add(bytes_read=7)
    assert st.bytes_read == 7
    st2 = reset_stream_stats()
    # in-place reset: the same object, zeroed, still wired to the registry
    assert st2 is st
    assert st.bytes_read == 0
    st.add(bytes_read=3)
    assert stream_stats().bytes_read == 3


def test_reset_race_with_concurrent_adds():
    """Regression: reset during an active streamed pass must neither lose the
    object identity nor corrupt counters (the old dataclass-replace reset
    raced ``st.bytes_read += n`` read-modify-writes in the prefetch thread).
    """
    st = stream_stats()
    reset_stream_stats()
    stop = threading.Event()
    errors = []

    def hammer_reset():
        while not stop.is_set():
            reset_stream_stats()

    def hammer_add():
        try:
            for _ in range(4000):
                st.add(bytes_read=1, bytes_decoded=1)
        except Exception as e:  # pragma: no cover - the regression itself
            errors.append(e)

    resetter = threading.Thread(target=hammer_reset)
    adders = [threading.Thread(target=hammer_add) for _ in range(3)]
    resetter.start()
    [t.start() for t in adders]
    [t.join() for t in adders]
    stop.set()
    resetter.join()
    assert errors == []
    # multi-counter add is atomic vs reset: the pair moves together
    assert st.bytes_read == st.bytes_decoded
    reset_stream_stats()


def test_reset_race_during_streamed_pipeline_pass():
    """Hammer reset_stream_stats() while a real PanelPipeline pass is feeding
    the process-wide stats from its prefetch thread; the pass must complete
    with correct panel payloads and non-negative, consistent counters."""

    class Handle:
        def __init__(self, a, ph):
            self.a, self._ph = a, ph

        shape = property(lambda self: self.a.shape)
        dtype = property(lambda self: self.a.dtype)
        panel_rows = property(lambda self: self._ph)

        def read_panel(self, row0, height):
            return self.a[row0:row0 + height]

    n, ph = 256, 8
    a = np.random.default_rng(0).normal(size=(n, n)).astype(np.float32)
    origins = list(range(0, n, ph))
    st = stream_stats()
    reset_stream_stats()
    stop = threading.Event()

    def hammer():
        while not stop.is_set():
            reset_stream_stats()

    t = threading.Thread(target=hammer)
    t.start()
    try:
        with PanelPipeline([Handle(a, ph)], origins, ph, stats=st) as pipe:
            for row0, (panel,) in pipe:
                np.testing.assert_array_equal(panel, a[row0:row0 + ph])
    finally:
        stop.set()
        t.join()
    assert st.bytes_read >= 0 and st.bytes_decoded >= 0
    reset_stream_stats()


# ---------------------------------------------------------------------------
# run reports (real tiny runs)
# ---------------------------------------------------------------------------


def _tiny_sequence(ctx1, *, oocore: bool, t_steps: int = 3, n: int = 32):
    cfg = CommuteConfig(k_override=4, q=3, d=3, oocore=oocore)
    det = SequenceDetector(ctx1, cfg, top_k=5)
    seq = gmm_snapshot_sequence(ctx1, n, t_steps, seed=0, inject_p=0.01)
    return cfg, det.run(seq.snapshots())


def test_run_report_end_to_end_oocore(ctx1, tmp_path):
    obs_trace.enable_tracing(fence=True)
    reset_stream_stats()
    cfg, res = _tiny_sequence(ctx1, oocore=True)
    doc = build_run_report(config={"n": 32}, result=res, n=32, k_rp=4)
    validate_run_report(doc)

    # acceptance: report byte totals equal the legacy stream_stats() counters
    st = stream_stats()
    assert doc["totals"]["bytes"]["bytes_read"] == st.bytes_read
    assert doc["totals"]["bytes"]["bytes_h2d"] == st.bytes_h2d
    assert doc["totals"]["bytes"]["bytes_decoded"] == st.bytes_decoded
    assert doc["totals"]["panels"] == st.panels

    # per-transition structure: all four phases timed, bytes moved, solver
    # telemetry with a residual series of exactly `iterations` entries
    assert len(doc["transitions"]) == 2
    for tr in doc["transitions"]:
        assert tr["phases"]["chain"] > 0
        assert tr["phases"]["solve"] > 0
        assert tr["phases"]["score"] > 0
        assert tr["bytes"]["bytes_read"] > 0
        for s in tr["solves"]:
            assert s["streamed"] is True
            assert len(s["residuals"]) == s["iterations"]
    # per-transition byte deltas sum to the totals (warmup holds the rest)
    read_sum = sum(t["bytes"]["bytes_read"] for t in doc["transitions"])
    warm = doc["warmup"]["bytes"]["bytes_read"]
    assert read_sum + warm == doc["totals"]["bytes"]["bytes_read"]

    # pipeline + cache blocks reflect real activity
    assert doc["pipeline"]["panels_fetched"] > 0
    assert doc["pipeline"]["producer_fetch_seconds"] > 0
    assert doc["cache"]["hits"] > 0
    assert doc["roofline"] is not None and doc["roofline"]["bound_s"] > 0

    # the saved artifact and the trace both validate from disk
    rpath = tmp_path / "report.json"
    save_run_report(doc, str(rpath))
    from repro.obs.report import validate_file

    assert validate_file(str(rpath)) == RUN_REPORT_KIND
    tpath = tmp_path / "trace.json"
    obs_trace.tracer().save(str(tpath))
    assert validate_file(str(tpath)) == "chrome_trace"
    # phase spans made it into the trace with fencing enabled
    names = {e["name"] for e in obs_trace.tracer().events()}
    assert {"phase.chain", "phase.ingest", "phase.solve", "phase.score",
            "prefetch.panel", "solve", "sequence.push"} <= names


def test_run_report_resident_and_residual_series(ctx1):
    reset_stream_stats()
    cfg, res = _tiny_sequence(ctx1, oocore=False)
    doc = build_run_report(config={}, result=res)
    validate_run_report(doc)
    for tr in doc["transitions"]:
        assert tr["bytes"]["bytes_read"] == 0  # nothing streams resident
        for s in tr["solves"]:
            assert s["streamed"] is False
            # resident while_loop carries the residual ring out intact
            assert len(s["residuals"]) == s["iterations"]
            assert s["residuals"][-1] == pytest.approx(s["residual"])
    assert doc["roofline"] is None  # no streamed solves to attribute


def test_run_report_not_converged_warning(ctx1):
    a = np.abs(np.random.default_rng(3).normal(size=(24, 24))).astype(np.float32)
    a = (a + a.T) / 2
    np.fill_diagonal(a, 0)
    op = chain_product(ctx1, a, 3)
    b = np.random.default_rng(4).normal(size=(24, 4)).astype(np.float32)
    # unreachable tolerance under a 1-step cap -> NOT-CONVERGED report
    _, rep = solve(ctx1, op, b, SolverSpec(tolerance=1e-30, max_iters=1))
    assert not rep.converged

    class FakeResult:
        transitions = ()
        transition_seconds = ()
        n_snapshots = 0
        chain_builds = 0

    class FakeTransition:
        def __init__(self, rep):
            self.solve_reports = (rep,)
            self.top_idx = np.asarray([0])
            self.top_val = np.asarray([0.0])

    r = FakeResult()
    r.transitions = [FakeTransition(rep)]
    r.transition_seconds = [0.1]
    doc = build_run_report(config={}, result=r)
    (w,) = doc["warnings"]
    assert w["event"] == "solver_not_converged"
    assert w["level"] == "warning"
    assert w["transition"] == 0
    assert REGISTRY.value("solver.not_converged") >= 1.0


def test_validators_reject_malformed():
    with pytest.raises(ValueError, match="kind"):
        validate_run_report({"schema": 1})
    with pytest.raises(ValueError, match="transitions"):
        validate_run_report({
            "kind": RUN_REPORT_KIND, "schema": 1, "config": {},
            "n_snapshots": 0, "totals": {}, "cache": {}, "pipeline": {},
            "solver": {}, "warnings": [], "transitions": [],
        })
    with pytest.raises(ValueError, match="no complete"):
        validate_chrome_trace({"traceEvents": []})
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "X", "ts": 1.0, "pid": 1, "tid": 1}
        ]})
