"""tile_map: the unified tile-program layer vs dense references, 1x1 and 2x2."""

import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import laplacian as lap
from repro.core.distmatrix import add_scaled_identity, blockwise_unary, build_from_nodes
from repro.core.embedding import edge_projection
from repro.core.tiles import tile_map


@pytest.fixture(params=["ctx1", "ctx22"])
def ctx(request):
    return request.getfixturevalue(request.param)


def test_tile_map_identity_grid(ctx):
    """Direct tile_map use: materialize I from global row/col ids."""
    n = 32
    out = tile_map(
        ctx,
        lambda tile: tile.diag_mask().astype(jnp.float32),
        grid=(n, n),
        in_specs=(),
    )
    np.testing.assert_array_equal(np.asarray(out), np.eye(n, dtype=np.float32))


def test_tile_map_row_reduce(ctx):
    """reduce='cols' psums tile outputs into a row-sharded vector."""
    rng = np.random.default_rng(0)
    x = ctx.put_matrix(rng.normal(size=(32, 32)).astype(np.float32))
    out = tile_map(ctx, lambda tile, blk: blk.sum(axis=1), x, reduce="cols")
    np.testing.assert_allclose(np.asarray(out), np.asarray(x).sum(1), rtol=1e-5, atol=1e-5)


def test_build_from_nodes_matches_dense(ctx):
    rng = np.random.default_rng(1)
    feats = jnp.asarray(rng.normal(size=(32, 3)).astype(np.float32))

    def kern(xi, xj):
        return jnp.sum(xi[:, None, :] * xj[None, :, :], -1)

    out = np.asarray(build_from_nodes(ctx, feats, kern))
    ref = np.asarray(feats) @ np.asarray(feats).T
    np.fill_diagonal(ref, 0.0)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_blockwise_unary_global_ids(ctx):
    """fn sees *global* row/col ids regardless of the shard grid."""
    x = ctx.put_matrix(np.zeros((16, 16), np.float32))
    out = blockwise_unary(
        ctx, lambda blk, r, c: blk + r[:, None] * 100.0 + c[None, :], x
    )
    r, c = np.meshgrid(np.arange(16), np.arange(16), indexing="ij")
    np.testing.assert_allclose(np.asarray(out), r * 100.0 + c)


def test_add_scaled_identity(ctx):
    x = ctx.put_matrix(np.ones((16, 16), np.float32))
    out = np.asarray(add_scaled_identity(ctx, x, 2.5))
    np.testing.assert_allclose(out, np.ones((16, 16)) + 2.5 * np.eye(16))


def test_degrees_matches_dense(ctx):
    rng = np.random.default_rng(2)
    a = np.abs(rng.normal(size=(32, 32))).astype(np.float32)
    out = np.asarray(lap.degrees(ctx, ctx.put_matrix(a)))
    np.testing.assert_allclose(out, a.sum(1), rtol=1e-5, atol=1e-4)


def test_edge_projection_mesh_invariant(ctx1, ctx22):
    """The tile program reproduces the same Y on any shard grid."""
    rng = np.random.default_rng(3)
    a = np.abs(rng.normal(size=(32, 32))).astype(np.float32)
    a = (a + a.T) / 2
    np.fill_diagonal(a, 0.0)
    y1 = np.asarray(edge_projection(ctx1, ctx1.put_matrix(a), seed=7, k=4))
    y2 = np.asarray(edge_projection(ctx22, ctx22.put_matrix(a), seed=7, k=4))
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)


def test_tile_map_rejects_nondivisible(ctx22):
    with pytest.raises(ValueError, match="divide"):
        tile_map(
            ctx22,
            lambda tile: jnp.zeros(tile.block_shape),
            grid=(31, 31),
            in_specs=(),
        )


def test_tile_map_requires_grid_without_matrix_operand(ctx1):
    feats = jnp.zeros((8, 2))
    with pytest.raises(ValueError, match="grid"):
        tile_map(ctx1, lambda tile, f: f, feats, in_specs=(P(None, None),))


def test_axis_index_only_in_tiles():
    """All five former hand-rolled tile programs route through tile_map."""
    import pathlib

    core = pathlib.Path(__file__).parent.parent / "src" / "repro" / "core"
    offenders = [
        p.name
        for p in core.glob("*.py")
        if p.name != "tiles.py" and "axis_index" in p.read_text()
    ]
    assert not offenders, f"hand-rolled axis_index outside tiles.py: {offenders}"
