"""In-process miniature of the multi-pod dry-run: lower+compile a cell on a
small fake mesh and sanity-check the recorded quantities.

(The full 512-device dry-run runs as its own process -- launch/dryrun.py --
because the device count is locked at jax init; here we exercise the same
code path at 8 devices.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import hlo_analysis as ha
from repro.models import common as cm
from repro.models import lm
from repro.serving.engine import make_serve_step
from repro.training.optim import OptConfig, make_optimizer
from repro.training.train_step import _named, make_train_step


@pytest.fixture(scope="module")
def mesh():
    return Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))


def _sds(shapes, shardings):
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s), shapes, shardings
    )


def test_train_cell_lowers_and_compiles(mesh):
    cfg = configs.get_smoke("granite-3-2b")
    spec = lm.build_spec(cfg)
    rules = cm.DEFAULT_RULES
    step_fn, pspecs, ospecs, bspec = make_train_step(spec, mesh, OptConfig(), rules=dict(rules))
    pshape = jax.eval_shape(lambda k: lm.init_params(spec, k), jax.random.PRNGKey(0))
    opt_init, _ = make_optimizer(OptConfig())
    oshape = jax.eval_shape(opt_init, pshape)
    b, s = 4, 32
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32,
                                       sharding=NamedSharding(mesh, P("data", None))),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32,
                                       sharding=NamedSharding(mesh, P("data", None))),
    }
    lowered = step_fn.lower(_sds(pshape, _named(mesh, pspecs)),
                            _sds(oshape, _named(mesh, ospecs)), batch)
    compiled = lowered.compile()
    ana = ha.analyze(compiled.as_text())
    assert ana["dot_flops"] > 0
    # scanned 2-layer model: flops must reflect BOTH layers (trip correction)
    ca = compiled.cost_analysis()
    ca = ca if isinstance(ca, dict) else ca[0]
    assert ana["dot_flops"] >= ca["flops"] * 0.9  # corrected >= raw


def test_decode_cell_lowers_and_compiles(mesh):
    cfg = configs.get_smoke("granite-moe-3b-a800m")
    spec = lm.build_spec(cfg)
    step_fn, cache_shapes, cache_shardings, pspecs = make_serve_step(
        spec, mesh, batch=4, s_max=64, donate_cache=False
    )
    pshape = jax.eval_shape(lambda k: lm.init_params(spec, k), jax.random.PRNGKey(0))
    tok = jax.ShapeDtypeStruct((4,), jnp.int32,
                               sharding=NamedSharding(mesh, P(("data",))))
    lowered = step_fn.lower(_sds(pshape, _named(mesh, pspecs)), tok,
                            _sds(cache_shapes, cache_shardings))
    compiled = lowered.compile()
    assert ha.analyze(compiled.as_text())["dot_flops"] > 0


def test_all_cells_well_defined():
    """Every assigned cell resolves to config + input specs without error."""
    for aid, shape in configs.all_cells():
        cfg = configs.get_config(aid)
        specs = configs.input_specs(cfg, shape)
        assert specs, (aid, shape.name)
        for v in jax.tree.leaves(specs):
            assert all(d > 0 for d in v.shape)
