"""Chain-invariant property tests: the telescoping identity

    (I - S) @ P == I - S^{2^d}

(the defining property of the Peng-Spielman product, see chain.py's
docstring) must hold for every way we build the chain -- resident,
streamed-adjacency, and out-of-core -- on 1x1 and 2x2 meshes.  The operator
returns P1 = D^{-1/2} P D^{-1/2}, so P is reconstructed by undoing the
sandwich against an independent numpy model of S.
"""

import numpy as np
import pytest

from repro.core import CommuteConfig, chain_product
from repro.store import TileStore

DS = [1, 2, 3, 4]


def _sym(n: int, seed: int) -> np.ndarray:
    a = np.abs(np.random.default_rng(seed).normal(size=(n, n))).astype(np.float32)
    a = (a + a.T) / 2.0
    np.fill_diagonal(a, 0.0)
    return a


def _numpy_s(a: np.ndarray, deflate: bool) -> np.ndarray:
    """Independent float64 model of the (deflated) normalized adjacency."""
    a = a.astype(np.float64)
    deg = a.sum(1)
    inv_sqrt = np.where(deg > 0, 1.0 / np.sqrt(np.maximum(deg, 1e-30)), 0.0)
    s = a * inv_sqrt[:, None] * inv_sqrt[None, :]
    if deflate:
        u = np.sqrt(np.maximum(deg, 0.0) / deg.sum())
        s = s - np.outer(u, u)
    return s


def _reconstruct_p(op) -> np.ndarray:
    """P = D^{1/2} P1 D^{1/2} (undo the operator's sandwich)."""
    p1 = op.p1.to_numpy() if hasattr(op.p1, "to_numpy") else np.asarray(op.p1)
    sq = np.sqrt(np.asarray(op.deg, dtype=np.float64))
    return sq[:, None] * p1.astype(np.float64) * sq[None, :]


def _check_telescoping(ctx, a: np.ndarray, d: int, mode: str) -> None:
    n = a.shape[0]
    if mode == "resident":
        operand, kwargs = ctx.put_matrix(a), {}
    else:
        store = TileStore.create(None, n=n, grid=4)
        operand = store.put_snapshot("t0", a)
        kwargs = {"oocore": True} if mode == "oocore" else {}
    op = chain_product(ctx, operand, d, schedule="xla", **kwargs)

    s = _numpy_s(a, deflate=True)
    p = _reconstruct_p(op)
    lhs = (np.eye(n) - s) @ p
    rhs = np.eye(n) - np.linalg.matrix_power(s, 2**d)
    # fp32 chain vs float64 model: error grows with the 2(d-1) GEMM depth.
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=5e-3)


@pytest.fixture(params=["ctx1", "ctx22"])
def ctx(request):
    return request.getfixturevalue(request.param)


@pytest.mark.parametrize("d", DS)
@pytest.mark.parametrize("mode", ["resident", "streamed", "oocore"])
def test_telescoping_identity(ctx, d, mode):
    _check_telescoping(ctx, _sym(32, 40 + d), d, mode)


def test_telescoping_identity_undeflated(ctx1):
    """Same identity without deflation (the paper-faithful fp64-style S)."""
    n, d = 32, 3
    a = _sym(n, 50)
    op = chain_product(ctx1, ctx1.put_matrix(a), d, schedule="xla", deflate=False)
    s = _numpy_s(a, deflate=False)
    lhs = (np.eye(n) - s) @ _reconstruct_p(op)
    rhs = np.eye(n) - np.linalg.matrix_power(s, 2**d)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=5e-3)


try:
    import hypothesis  # noqa: F401

    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 2**16), d=st.integers(1, 3))
    @settings(max_examples=10, deadline=None)
    def test_telescoping_identity_random(seed, d):
        """Hypothesis sweep over graphs/depths (1x1 mesh, resident build)."""
        from repro.core import trivial_context

        _check_telescoping(trivial_context(), _sym(16, seed), d, "resident")
