"""Per-kernel allclose vs the pure-jnp oracle: shape/dtype sweeps.

All kernels run in interpret mode on CPU (the kernel body executes in
Python), so these validate the actual Pallas kernel logic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=np.float32, positive=False):
    x = RNG.normal(size=shape).astype(np.float32)
    if positive:
        x = np.abs(x)
    return jnp.asarray(x.astype(dtype))


# ---------------------------------------------------------------------------
# block_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128), (64, 512, 256), (120, 72, 40)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_matmul_shapes_dtypes(m, k, n, dtype):
    a, b = _arr((m, k), dtype), _arr((k, n), dtype)
    out = ops.block_matmul(a, b, bm=128, bk=128, bn=128, out_dtype=jnp.float32)
    expect = ref.block_matmul(a, b, out_dtype=jnp.float32)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("tiles", [(64, 64, 64), (128, 256, 128), (32, 32, 32)])
def test_block_matmul_tile_invariance(tiles):
    a, b = _arr((256, 256)), _arr((256, 256))
    bm, bk, bn = tiles
    out = ops.block_matmul(a, b, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.block_matmul(a, b)), rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# edge_projection
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k", [(128, 4), (256, 8), (192, 15)])
def test_edge_projection(n, k):
    a = _arr((n, n), positive=True)
    out = ops.edge_projection(a, seed=3, k=k, bm=64, bn=64)
    expect = ref.edge_projection(a, seed=3, k=k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-4)


def test_edge_projection_tile_invariance():
    a = _arr((256, 256), positive=True)
    o1 = ops.edge_projection(a, seed=1, k=4, bm=64, bn=64)
    o2 = ops.edge_projection(a, seed=1, k=4, bm=128, bn=256)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# cad_scores
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k", [(128, 8), (256, 16)])
def test_cad_scores(n, k):
    a1, a2 = _arr((n, n), positive=True), _arr((n, n), positive=True)
    z1, z2 = _arr((n, k)), _arr((n, k))
    v1, v2 = jnp.float32(10.0), jnp.float32(12.5)
    out = ops.cad_scores(a1, a2, z1, z2, v1, v2, bm=64, bn=64)
    expect = ref.cad_scores(a1, a2, z1, z2, v1, v2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-2)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,d", [(128, 64), (256, 128), (64, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(s, d, causal):
    q, k, v = _arr((2, s, d)), _arr((2, s, d)), _arr((2, s, d))
    out = ops.flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    expect = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4)


def test_flash_attention_matches_model_chunked():
    """Pallas flash == the model's pure-JAX chunked flash (same math)."""
    from repro.models.attention import _chunked_flash
    from repro.models.common import ArchConfig

    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=64, n_heads=2,
                     n_kv_heads=2, d_ff=64, vocab=16, attn_chunk=64,
                     compute_dtype="float32")
    b, s, h, hd = 2, 128, 2, 32
    q, k, v = _arr((b, s, h, hd)), _arr((b, s, h, hd)), _arr((b, s, h, hd))
    out_model = _chunked_flash(cfg, q, k, v, causal=True, rules={})
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, s, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, s, hd)
    out_pallas = ops.flash_attention(qf, kf, vf, causal=True, bq=64, bk=64)
    out_pallas = jnp.moveaxis(out_pallas.reshape(b, h, s, hd), 1, 2)
    np.testing.assert_allclose(np.asarray(out_model), np.asarray(out_pallas), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# wkv (RWKV6 recurrence)
# ---------------------------------------------------------------------------


# NOTE: chunk sizes stay <= ~32 under strong decay -- the factorized
# exp(cum_t - cum_i) form loses precision when per-chunk cumulative decay
# exceeds ~e^30 (documented in kernels/wkv.py); production chunk is 128 with
# the much gentler decays of trained RWKV models.
@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 32), (96, 24)])
def test_wkv_kernel(s, chunk):
    BH, dk, dv = 3, 16, 16
    r = _arr((BH, s, dk))
    k = _arr((BH, s, dk))
    v = _arr((BH, s, dv))
    lw = -jnp.exp(_arr((BH, s, dk)) * 0.5 - 1.0)
    u = 0.1 * _arr((BH, dk))
    out = ops.wkv(r, k, v, lw, u, chunk=chunk)
    expect = ref.wkv(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-3, atol=1e-3)


def test_wkv_kernel_state_carries_across_chunks():
    """Same inputs, different chunking -> identical output (state flows)."""
    BH, s, dk = 2, 64, 8
    r, k, v = _arr((BH, s, dk)), _arr((BH, s, dk)), _arr((BH, s, dk))
    lw = -jnp.exp(_arr((BH, s, dk)) * 0.3 - 1.0)
    u = 0.1 * _arr((BH, dk))
    o1 = ops.wkv(r, k, v, lw, u, chunk=8)
    o2 = ops.wkv(r, k, v, lw, u, chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-4)
