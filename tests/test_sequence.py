"""SequenceDetector: amortized sequence scoring == fresh pairwise scoring."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CommuteConfig,
    SequenceDetector,
    chain_build_count,
    detect_anomalies,
    detect_sequence_anomalies,
)
from repro.graphs import climate_snapshot_sequence, gmm_snapshot_sequence

CFG = CommuteConfig(eps_rp=1e-2, d=6, q=8, schedule="xla")


def test_sequence_matches_pairwise_and_builds_once(ctx1):
    """T=4: transition scores == three fresh detect_anomalies calls, with
    exactly 4 chain builds (vs 6 for the pairwise path)."""
    t_steps = 4

    def seq():
        return gmm_snapshot_sequence(ctx1, 64, t_steps, seed=1, inject_p=0.02)

    builds0 = chain_build_count()
    res = detect_sequence_anomalies(ctx1, seq().snapshots(), CFG, top_k=5)
    assert chain_build_count() - builds0 == t_steps
    assert res.chain_builds == t_steps
    assert len(res.transitions) == t_steps - 1

    snaps = list(seq().snapshots())
    for t in range(t_steps - 1):
        fresh = detect_anomalies(ctx1, snaps[t], snaps[t + 1], CFG, top_k=5)
        np.testing.assert_array_equal(
            np.asarray(res.transitions[t].scores), np.asarray(fresh.scores)
        )


def test_sequence_global_topk(ctx1):
    """Streaming global top-k == top-k over the concatenated score matrix."""
    res = detect_sequence_anomalies(
        ctx1, gmm_snapshot_sequence(ctx1, 64, 3, seed=2).snapshots(), CFG, top_k=7
    )
    allsc = np.stack([np.asarray(r.scores) for r in res.transitions])
    order = np.argsort(allsc.ravel())[::-1][:7]
    want_step, want_idx = np.unravel_index(order, allsc.shape)
    got = sorted(zip(np.asarray(res.global_top_step), np.asarray(res.global_top_idx)))
    assert got == sorted(zip(want_step.tolist(), want_idx.tolist()))
    np.testing.assert_allclose(
        np.sort(np.asarray(res.global_top_val))[::-1],
        np.sort(allsc.ravel())[::-1][:7],
        rtol=1e-6,
    )


def test_global_topk_merge_partially_replicated(ctx22):
    """Regression (jax 0.4.x partial-replication bug, ROADMAP): the streaming
    top-k merge must be correct even when the per-transition candidates are
    sharded P(row_axes) -- *partially replicated* over the column mesh axes.
    The former eager jnp.concatenate merge SUMMED the replicas on such inputs
    (every candidate doubled on a 2x2 mesh); the host-side merge cannot."""
    import jax

    det = SequenceDetector(ctx22, CFG, top_k=4)
    sh = ctx22.sharding(ctx22.vector_spec)

    def put(vals, dtype):
        return jax.device_put(np.asarray(vals, dtype), sh)

    det._merge_topk(put([0, 1, 2, 3], np.int32), put([4.0, 3.0, 2.0, 1.0], np.float32), 0)
    det._merge_topk(put([7, 8, 9, 10], np.int32), put([5.0, 3.0, 0.5, 0.25], np.float32), 1)
    np.testing.assert_array_equal(np.asarray(det._g_val), [5.0, 4.0, 3.0, 3.0])
    np.testing.assert_array_equal(np.asarray(det._g_idx), [7, 0, 1, 8])
    # lax.top_k tie semantics: equal values keep candidate order (step 0 first)
    np.testing.assert_array_equal(np.asarray(det._g_step), [1, 0, 0, 1])


def test_global_topk_sharded_matches_host(ctx22):
    """End-to-end on the multi-axis mesh: the merged global top-k equals a
    host-side top-k over all transition scores."""
    res = detect_sequence_anomalies(
        ctx22, gmm_snapshot_sequence(ctx22, 64, 3, seed=6).snapshots(), CFG, top_k=6
    )
    allsc = np.stack([np.asarray(r.scores) for r in res.transitions])
    want = np.sort(allsc.ravel())[::-1][:6]
    np.testing.assert_array_equal(np.sort(np.asarray(res.global_top_val))[::-1], want)


def test_sequence_sharded_matches_single(ctx1, ctx22):
    r1 = detect_sequence_anomalies(
        ctx1, gmm_snapshot_sequence(ctx1, 64, 3, seed=3).snapshots(), CFG, top_k=5
    )
    r2 = detect_sequence_anomalies(
        ctx22, gmm_snapshot_sequence(ctx22, 64, 3, seed=3).snapshots(), CFG, top_k=5
    )
    for a, b in zip(r1.transitions, r2.transitions):
        np.testing.assert_allclose(
            np.asarray(a.scores), np.asarray(b.scores), rtol=1e-3, atol=1e-2
        )


def test_sequence_donate_frees_previous(ctx1):
    seq = gmm_snapshot_sequence(ctx1, 64, 3, seed=4)
    det = SequenceDetector(ctx1, CFG, top_k=5, donate=True)
    snaps = list(seq.snapshots())
    det.push(snaps[0])
    det.push(snaps[1])  # scores 0->1, then donates snapshot 0's buffers
    assert snaps[0].is_deleted()
    assert not snaps[1].is_deleted()
    res = det.finalize()
    assert len(res.transitions) == 1


def test_sequence_requires_two_snapshots(ctx1):
    det = SequenceDetector(ctx1, CFG)
    with pytest.raises(ValueError):
        det.finalize()


def test_climate_sequence_truth_at_event(ctx1):
    """The event transition carries truth; quiet transitions don't."""
    seq = climate_snapshot_sequence(ctx1, 8, 8, 4, seed=0, event_frac=0.05)
    assert seq.t_steps == 4
    # event at t=2: transitions 1->2 (appears) and 2->3 (disappears) have truth
    assert len(seq.truth[0]) == 0
    assert len(seq.truth[1]) > 0
    assert len(seq.truth[2]) > 0
    snaps = list(seq.snapshots())
    assert all(s.shape == (64, 64) for s in snaps)


def test_deflate_constant_preserves_sharding(ctx22):
    """Satellite: deflate_constant constrains output to the rowblock layout."""
    from repro.core.solver import deflate_constant

    y = ctx22.put_rowblock(np.random.default_rng(0).normal(size=(32, 4)).astype(np.float32))
    out = deflate_constant(ctx22, y)
    assert float(jnp.max(jnp.abs(jnp.mean(out, axis=0)))) < 1e-5
    assert out.sharding.spec == ctx22.rowblock_spec
