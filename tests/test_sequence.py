"""SequenceDetector: amortized sequence scoring == fresh pairwise scoring."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CommuteConfig,
    SequenceDetector,
    chain_build_count,
    detect_anomalies,
    detect_sequence_anomalies,
)
from repro.graphs import climate_snapshot_sequence, gmm_snapshot_sequence

CFG = CommuteConfig(eps_rp=1e-2, d=6, q=8, schedule="xla")


@pytest.fixture(params=["ctx1", "ctx22"])
def ctx(request):
    return request.getfixturevalue(request.param)


def test_sequence_matches_pairwise_and_builds_once(ctx1):
    """T=4: transition scores == three fresh detect_anomalies calls, with
    exactly 4 chain builds (vs 6 for the pairwise path)."""
    t_steps = 4

    def seq():
        return gmm_snapshot_sequence(ctx1, 64, t_steps, seed=1, inject_p=0.02)

    builds0 = chain_build_count()
    res = detect_sequence_anomalies(ctx1, seq().snapshots(), CFG, top_k=5)
    assert chain_build_count() - builds0 == t_steps
    assert res.chain_builds == t_steps
    assert len(res.transitions) == t_steps - 1

    snaps = list(seq().snapshots())
    for t in range(t_steps - 1):
        fresh = detect_anomalies(ctx1, snaps[t], snaps[t + 1], CFG, top_k=5)
        np.testing.assert_array_equal(
            np.asarray(res.transitions[t].scores), np.asarray(fresh.scores)
        )


def test_sequence_global_topk(ctx1):
    """Streaming global top-k == top-k over the concatenated score matrix."""
    res = detect_sequence_anomalies(
        ctx1, gmm_snapshot_sequence(ctx1, 64, 3, seed=2).snapshots(), CFG, top_k=7
    )
    allsc = np.stack([np.asarray(r.scores) for r in res.transitions])
    order = np.argsort(allsc.ravel())[::-1][:7]
    want_step, want_idx = np.unravel_index(order, allsc.shape)
    got = sorted(zip(np.asarray(res.global_top_step), np.asarray(res.global_top_idx)))
    assert got == sorted(zip(want_step.tolist(), want_idx.tolist()))
    np.testing.assert_allclose(
        np.sort(np.asarray(res.global_top_val))[::-1],
        np.sort(allsc.ravel())[::-1][:7],
        rtol=1e-6,
    )


def test_global_topk_merge_partially_replicated(ctx22):
    """Regression (jax 0.4.x partial-replication bug, ROADMAP): the streaming
    top-k merge must be correct even when the per-transition candidates are
    sharded P(row_axes) -- *partially replicated* over the column mesh axes.
    The former eager jnp.concatenate merge SUMMED the replicas on such inputs
    (every candidate doubled on a 2x2 mesh); the host-side merge cannot."""
    import jax

    det = SequenceDetector(ctx22, CFG, top_k=4)
    sh = ctx22.sharding(ctx22.vector_spec)

    def put(vals, dtype):
        return jax.device_put(np.asarray(vals, dtype), sh)

    det._merge_topk(put([0, 1, 2, 3], np.int32), put([4.0, 3.0, 2.0, 1.0], np.float32), 0)
    det._merge_topk(put([7, 8, 9, 10], np.int32), put([5.0, 3.0, 0.5, 0.25], np.float32), 1)
    np.testing.assert_array_equal(np.asarray(det._g_val), [5.0, 4.0, 3.0, 3.0])
    np.testing.assert_array_equal(np.asarray(det._g_idx), [7, 0, 1, 8])
    # lax.top_k tie semantics: equal values keep candidate order (step 0 first)
    np.testing.assert_array_equal(np.asarray(det._g_step), [1, 0, 0, 1])


def test_global_topk_sharded_matches_host(ctx22):
    """End-to-end on the multi-axis mesh: the merged global top-k equals a
    host-side top-k over all transition scores."""
    res = detect_sequence_anomalies(
        ctx22, gmm_snapshot_sequence(ctx22, 64, 3, seed=6).snapshots(), CFG, top_k=6
    )
    allsc = np.stack([np.asarray(r.scores) for r in res.transitions])
    want = np.sort(allsc.ravel())[::-1][:6]
    np.testing.assert_array_equal(np.sort(np.asarray(res.global_top_val))[::-1], want)


def test_sequence_sharded_matches_single(ctx1, ctx22):
    r1 = detect_sequence_anomalies(
        ctx1, gmm_snapshot_sequence(ctx1, 64, 3, seed=3).snapshots(), CFG, top_k=5
    )
    r2 = detect_sequence_anomalies(
        ctx22, gmm_snapshot_sequence(ctx22, 64, 3, seed=3).snapshots(), CFG, top_k=5
    )
    for a, b in zip(r1.transitions, r2.transitions):
        np.testing.assert_allclose(
            np.asarray(a.scores), np.asarray(b.scores), rtol=1e-3, atol=1e-2
        )


def test_sequence_donate_frees_previous(ctx1):
    seq = gmm_snapshot_sequence(ctx1, 64, 3, seed=4)
    det = SequenceDetector(ctx1, CFG, top_k=5, donate=True)
    snaps = list(seq.snapshots())
    det.push(snaps[0])
    det.push(snaps[1])  # scores 0->1, then donates snapshot 0's buffers
    assert snaps[0].is_deleted()
    assert not snaps[1].is_deleted()
    res = det.finalize()
    assert len(res.transitions) == 1


def test_sequence_requires_two_snapshots(ctx1):
    det = SequenceDetector(ctx1, CFG)
    with pytest.raises(ValueError, match="0 snapshots"):
        det.finalize()


def test_single_snapshot_finalizes_to_empty_result(ctx1):
    """T=1 has zero transitions by definition: finalize() returns an empty
    SequenceResult (not an exception -- only T=0 is a caller bug)."""
    from repro.graphs import gmm_graph_sequence

    det = SequenceDetector(ctx1, CFG, top_k=5)
    assert det.push(gmm_graph_sequence(ctx1, n=32, seed=0).a1) is None
    res = det.finalize()
    assert res.transitions == [] and res.n_snapshots == 1
    assert res.global_top_idx.shape == (0,)
    assert res.global_top_val.shape == (0,)
    assert res.global_top_step.shape == (0,)
    assert res.chain_builds == 1
    assert res.warmup_metrics is not None


# ---------------------------------------------------------------------------
# _release diagnosability (donate path)
# ---------------------------------------------------------------------------


class _FailingBuf:
    """Device-buffer stand-in whose delete fails like an already-donated
    buffer does."""

    def __init__(self, exc):
        self.exc = exc
        self.calls = 0

    def delete(self):
        self.calls += 1
        raise self.exc


def test_release_warns_and_continues_on_delete_failure(ctx1):
    """Expected delete failures (the double-buffering race) warn instead of
    vanishing, and the release keeps going past the first failure."""
    from repro.core.embedding import Embedding

    det = SequenceDetector(ctx1, CFG, donate=True)
    a = _FailingBuf(RuntimeError("buffer already donated"))
    z = _FailingBuf(OSError("device gone"))
    with pytest.warns(RuntimeWarning, match="delete failed") as rec:
        det._release(a, Embedding(z=z, vol=1.0, op=None))
    assert a.calls == 1 and z.calls == 1
    assert len(rec) == 2


def test_release_propagates_unexpected_errors(ctx1):
    """Only the expected buffer errors are downgraded to warnings -- a
    genuine programming error must surface (the former bare `except
    Exception` ate everything)."""
    from repro.core.embedding import Embedding

    det = SequenceDetector(ctx1, CFG, donate=True)
    bad = _FailingBuf(TypeError("programming error"))
    with pytest.raises(TypeError, match="programming error"):
        det._release(bad, Embedding(z=bad, vol=1.0, op=None))


def test_release_skips_handles_without_delete(ctx1):
    """Store-backed snapshot handles (no .delete) are the user's data: the
    donate path skips them silently, no warning, no error."""
    import warnings as _warnings

    from repro.core.embedding import Embedding

    class Plain:
        pass

    det = SequenceDetector(ctx1, CFG, donate=True)
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        det._release(Plain(), Embedding(z=Plain(), vol=1.0, op=None))


# ---------------------------------------------------------------------------
# warm-started sequences: same scores, far fewer iterations
# ---------------------------------------------------------------------------


def _drifting_snapshots(ctx, n, t_steps, storage):
    """A slowly-drifting GMM sequence (no injections); oocore variants are
    served as store-backed handles so the whole transition streams."""
    seq = gmm_snapshot_sequence(
        ctx, n, t_steps, seed=5, noise=1e-4, inject_steps=set()
    )
    if storage == "oocore":
        from repro.store import TileStore

        store = TileStore.create(None, n=n, grid=4)
        for t, a in enumerate(seq.snapshots()):
            store.put_snapshot(f"t{t:03d}", np.asarray(a))
        return store.iter_snapshots()
    return seq.snapshots()


def _commute_scale(ctx, cfg, n, t_steps):
    """The commute-distance scale V_G * E||z_i||^2 the anomaly scores are
    measured in -- the natural atol anchor for warm-vs-cold comparisons (on
    a quiet sequence the scores themselves sit orders of magnitude below
    it)."""
    from repro.core.embedding import commute_time_embedding

    seq = gmm_snapshot_sequence(
        ctx, n, t_steps, seed=5, noise=1e-4, inject_steps=set()
    )
    emb = commute_time_embedding(ctx, next(seq.snapshots()), cfg)
    z = np.asarray(emb.z, np.float64)
    return float(emb.vol) * float((z * z).sum(1).mean())


@pytest.mark.parametrize("storage", ["resident", "oocore"])
def test_warm_start_scores_allclose_cold(ctx, storage):
    """Acceptance (1x1 AND 2x2 mesh, resident AND out-of-core): warm-started
    sequence scores stay allclose (rtol 1e-4, atol 1e-4 of the
    commute-distance scale) to the cold run, every right-endpoint report is
    flagged warm, and warm iterations never exceed cold."""
    from dataclasses import replace

    n, t_steps = 48, 3
    cold_cfg = CommuteConfig(
        eps_rp=1e-2, d=3, q=8, schedule="xla", k_override=4,
        solver="richardson", solver_tol=1e-4, oocore=storage == "oocore",
    )
    warm_cfg = replace(cold_cfg, warm_start=True)
    cold = detect_sequence_anomalies(
        ctx, _drifting_snapshots(ctx, n, t_steps, storage), cold_cfg, top_k=5
    )
    warm = detect_sequence_anomalies(
        ctx, _drifting_snapshots(ctx, n, t_steps, storage), warm_cfg, top_k=5
    )
    scale = _commute_scale(ctx, replace(cold_cfg, oocore=False), n, t_steps)
    for t, (c, w) in enumerate(zip(cold.transitions, warm.transitions)):
        np.testing.assert_allclose(
            np.asarray(w.scores), np.asarray(c.scores),
            rtol=1e-4, atol=1e-4 * scale, err_msg=f"transition {t}",
        )
        assert w.solve_reports[1].warm_start
        assert not c.solve_reports[1].warm_start
        assert w.solve_reports[1].iterations <= c.solve_reports[1].iterations


@pytest.mark.slow
def test_warm_start_halves_iterations_on_drifting_sequence(ctx1):
    """ISSUE 8 acceptance: on a slowly-drifting sequence, warm-started
    tolerance-targeted solves (all three methods) take >= 2x fewer
    iterations than cold from transition 2 onward, with scores allclose."""
    from dataclasses import replace

    n, t_steps = 96, 4
    base = CommuteConfig(
        eps_rp=1e-2, d=3, q=8, schedule="xla", k_override=6, solver_tol=1e-5
    )
    scale = _commute_scale(ctx1, replace(base, solver="cg"), n, t_steps)
    for method in ("richardson", "chebyshev", "cg"):
        cold_cfg = replace(base, solver=method)
        warm_cfg = replace(cold_cfg, warm_start=True)
        cold = detect_sequence_anomalies(
            ctx1, _drifting_snapshots(ctx1, n, t_steps, "resident"),
            cold_cfg, top_k=5,
        )
        warm = detect_sequence_anomalies(
            ctx1, _drifting_snapshots(ctx1, n, t_steps, "resident"),
            warm_cfg, top_k=5,
        )
        cold_its = [r.solve_reports[1].iterations for r in cold.transitions]
        warm_its = [r.solve_reports[1].iterations for r in warm.transitions]
        for t in range(1, t_steps - 1):  # transition 2 onward (1-based)
            assert warm.transitions[t].solve_reports[1].converged
            assert cold.transitions[t].solve_reports[1].converged
            assert cold_its[t] >= 2 * warm_its[t], (method, cold_its, warm_its)
        for t, (c, w) in enumerate(zip(cold.transitions, warm.transitions)):
            np.testing.assert_allclose(
                np.asarray(w.scores), np.asarray(c.scores),
                rtol=1e-4, atol=1e-4 * scale,
                err_msg=f"{method} transition {t}",
            )


def test_climate_sequence_truth_at_event(ctx1):
    """The event transition carries truth; quiet transitions don't."""
    seq = climate_snapshot_sequence(ctx1, 8, 8, 4, seed=0, event_frac=0.05)
    assert seq.t_steps == 4
    # event at t=2: transitions 1->2 (appears) and 2->3 (disappears) have truth
    assert len(seq.truth[0]) == 0
    assert len(seq.truth[1]) > 0
    assert len(seq.truth[2]) > 0
    snaps = list(seq.snapshots())
    assert all(s.shape == (64, 64) for s in snaps)


def test_deflate_constant_preserves_sharding(ctx22):
    """Satellite: deflate_constant constrains output to the rowblock layout."""
    from repro.core.solver import deflate_constant

    y = ctx22.put_rowblock(np.random.default_rng(0).normal(size=(32, 4)).astype(np.float32))
    out = deflate_constant(ctx22, y)
    assert float(jnp.max(jnp.abs(jnp.mean(out, axis=0)))) < 1e-5
    assert out.sharding.spec == ctx22.rowblock_spec
