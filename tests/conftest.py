import os

# Tests see the real single CPU device by default; individual tests that need
# a small multi-device mesh spawn with XLA_FLAGS via the sharded fixtures
# below (which require this env var to be set BEFORE jax initializes, so we
# set a modest 8 here -- small enough not to slow single-device tests).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
import pytest

from repro.core.distmatrix import DistContext, make_context
from jax.sharding import Mesh


@pytest.fixture(scope="session")
def ctx1() -> DistContext:
    """1x1 mesh context."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return make_context(Mesh(dev, ("data", "model")))


@pytest.fixture(scope="session")
def ctx22() -> DistContext:
    """2x2 mesh context (4 fake CPU devices)."""
    dev = np.array(jax.devices()[:4]).reshape(2, 2)
    return make_context(Mesh(dev, ("data", "model")))


@pytest.fixture(scope="session")
def mesh22() -> Mesh:
    return Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))


@pytest.fixture(scope="session")
def mesh_pod() -> Mesh:
    """(2, 2, 2) pod/data/model mesh -- multi-pod code paths."""
    return Mesh(np.array(jax.devices()[:8]).reshape(2, 2, 2), ("pod", "data", "model"))
