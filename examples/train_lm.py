"""End-to-end LM training driver demo: ~100M-param model, few hundred steps.

Uses the framework's full path -- deterministic data pipeline, jit'd
FSDP/TP train step, checkpointing, watchdog -- on a CPU-sized model.  With
--steps 300 on this container it demonstrably learns the synthetic data's
deterministic next-token structure (loss drops well below ln(vocab)).

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""

import argparse

from repro.launch.mesh import make_cpu_mesh
from repro.launch.train import train_loop
from repro.models.common import ArchConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: a granite-family dense decoder
    cfg = ArchConfig(
        name="demo-100m", family="dense", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, d_ff=2048, vocab=8192, tie_embeddings=True, remat=False,
    )
    mesh = make_cpu_mesh(1, 1)
    _, _, losses = train_loop(
        cfg, mesh, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 1), log_every=10,
    )
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
