"""CADDeLaG watching its own run: RunReport telemetry as the anomaly input.

The paper's technique is graph analytics over *relationships*, not a model
layer -- so the framework turns it on the richest relational stream it owns:
its own observability layer.  A small sequence run produces a structured
RunReport (``repro.obs.report``), whose per-transition telemetry channels --
phase seconds (ingest/chain/solve/score), bytes moved, panels staged, solver
iterations and residuals -- are correlated in a healthy run (more panels means
more read bytes means more solve seconds, in proportion).  A performance fault
breaks those *pairwise relationships* even when every individual channel stays
in range: exactly the "changes in pairwise relationships, not in individual
tuples" story of the paper, applied to run telemetry.

Pipeline:

1. run a short GMM snapshot sequence out-of-core and write a real RunReport
   (the same document ``caddelag-run --run-report`` emits);
2. load the report back and inject a deterministic fault into one
   transition's record -- a scratch-read stall (bytes_read and solve seconds
   inflate, everything else stays put), the signature of a failing disk;
3. per sliding window of transitions, build a fully-connected similarity
   graph over the telemetry channels (nodes = channels, edges = correlation
   kernel over the window's z-scored values) and CADDeLaG-score consecutive
   windows, flagging the window where the fault enters and the channels that
   moved.

    PYTHONPATH=src python examples/training_telemetry_anomaly.py
"""

import argparse
import json
import os
import tempfile

import numpy as np

from repro.core import (
    CommuteConfig,
    SequenceDetector,
    detect_anomalies,
    trivial_context,
)
from repro.graphs import gmm_snapshot_sequence, similarity_graph
from repro.obs.report import build_run_report, save_run_report, validate_run_report


def make_run_report(ctx, *, n: int, t_steps: int, path: str) -> dict:
    """Run a short out-of-core sequence and round-trip its RunReport JSON."""
    cfg = CommuteConfig(eps_rp=1e-2, d=3, q=4, k_override=6, oocore=True)
    det = SequenceDetector(ctx, cfg, top_k=5)
    seq = gmm_snapshot_sequence(ctx, n, t_steps, seed=0, inject_p=0.01)
    res = det.run(seq.snapshots())
    doc = build_run_report(
        config={"example": "training_telemetry_anomaly", "n": n, "t_steps": t_steps},
        result=res, n=n, k_rp=cfg.k_rp(n),
    )
    save_run_report(doc, path)
    with open(path) as f:
        doc = json.load(f)
    validate_run_report(doc)
    return doc


def telemetry_channels(doc: dict) -> tuple[list[str], np.ndarray]:
    """(channel names, (channels, transitions) value matrix) from a report."""
    names, rows = [], []

    def channel(name, values):
        names.append(name)
        rows.append(np.asarray(values, np.float64))

    trs = doc["transitions"]
    for ph in ("ingest", "chain", "solve", "score"):
        channel(f"phase.{ph}.seconds", [t["phases"][ph] for t in trs])
    for b in ("bytes_read", "bytes_decoded", "bytes_h2d"):
        channel(f"stream.{b}", [t["bytes"][b] for t in trs])
    channel("stream.panels", [t["panels"] for t in trs])
    channel("solver.iterations", [sum(s["iterations"] for s in t["solves"]) for t in trs])
    channel("solver.residual", [max((s["residual"] for s in t["solves"]), default=0.0)
                                for t in trs])
    channel("seconds", [t["seconds"] or 0.0 for t in trs])
    return names, np.stack(rows)


def inject_fault(doc: dict, at: int, factor: float = 25.0) -> dict:
    """Scratch-read stall at transition ``at``: reads and solve wall inflate,
    the correlated channels (panels, H2D, iterations) do not follow."""
    tr = doc["transitions"][at]
    tr["bytes"]["bytes_read"] = int(tr["bytes"]["bytes_read"] * factor)
    tr["phases"]["solve"] *= factor
    tr["seconds"] = (tr["seconds"] or 0.0) + tr["phases"]["solve"]
    return doc


def normalize_channels(values: np.ndarray) -> np.ndarray:
    """Per-channel robust scaling across ALL transitions: log1p, then
    (v - median) / MAD, clipped to +-8.

    Median/MAD -- not mean/std -- on purpose: a fault must not set its own
    channel's scale.  Deterministic channels (bytes, panels, iterations) have
    ~zero healthy MAD, so a faulted value lands tens of MADs out, while host
    timing jitter stays at a few; the clip keeps the similarity graph's
    kernel edges finite.  Global -- not per-window -- so a faulted window
    keeps its magnitude instead of being re-normalized away."""
    v = np.log1p(np.maximum(values, 0.0))
    med = np.median(v, axis=1, keepdims=True)
    mad = np.median(np.abs(v - med), axis=1, keepdims=True)
    floor = np.maximum(1e-3 * np.maximum(np.abs(med), 1.0), 1e-9)
    return np.clip((v - med) / np.maximum(mad, floor), -8.0, 8.0)


def window_graph(ctx, z: np.ndarray, lo: int, hi: int):
    """Similarity graph over channels from their normalized window values."""
    return similarity_graph(ctx, np.asarray(z[:, lo:hi], np.float32), bandwidth=1.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48, help="graph nodes in the source run")
    ap.add_argument("--t-steps", type=int, default=10, help="snapshots in the source run")
    ap.add_argument("--fault-at", type=int, default=6,
                    help="transition index that gets the injected stall")
    ap.add_argument("--window", type=int, default=3,
                    help="transitions per telemetry window")
    ap.add_argument("--report", default=None,
                    help="reuse an existing RunReport JSON instead of running")
    args = ap.parse_args()

    ctx = trivial_context()
    if args.report is not None:
        with open(args.report) as f:
            doc = json.load(f)
        validate_run_report(doc)
    else:
        path = os.path.join(tempfile.mkdtemp(prefix="caddelag_obs_"), "report.json")
        print(f"[telemetry] generating RunReport from a {args.t_steps}-snapshot run...")
        doc = make_run_report(ctx, n=args.n, t_steps=args.t_steps, path=path)
        print(f"[telemetry] report -> {path}")

    n_tr = len(doc["transitions"])
    fault_at = min(args.fault_at, n_tr - 1)
    doc = inject_fault(doc, fault_at)
    names, values = telemetry_channels(doc)
    # The first transitions' timings include jit compilation (every phase
    # program traces on first use, stragglers land in the second transition)
    # -- a known, one-off structural break.  Drop them so the detector sees
    # only steady-state telemetry (same reason benchmarks discard warm-up
    # reps).
    skip = 2 if n_tr > args.window + 2 else 0
    values = values[:, skip:]
    n_tr -= skip
    fault_at -= skip
    print(f"[telemetry] {len(names)} channels x {n_tr} steady-state "
          f"transitions (warm-up dropped); stall injected at transition "
          f"{fault_at + skip}")

    w = min(args.window, n_tr)
    z = normalize_channels(values)
    ccfg = CommuteConfig(eps_rp=1e-2, d=4, q=6, schedule="xla",
                         k_override=min(6, len(names)))
    prev, scored = None, []
    for lo in range(0, n_tr - w + 1):
        graph = window_graph(ctx, z, lo, lo + w)
        if prev is not None:
            res = detect_anomalies(ctx, prev[1], graph, ccfg, top_k=3)
            scores = np.asarray(res.scores)
            scored.append((lo, float(scores.max()), int(scores.argmax())))
        prev = (lo, graph)

    flagged_lo, _, flagged_ch = max(scored, key=lambda t: t[1])
    for lo, v, ch in scored:
        entered = lo + w - 1  # the transition this window newly covers
        mark = "  <-- fault enters window" if entered == fault_at else ""
        print(f"window [{lo},{lo + w}): max score {v:10.4f} "
              f"(channel {names[ch]}){mark}")
    hit = flagged_lo + w - 1 == fault_at
    print(f"\nflagged window [{flagged_lo},{flagged_lo + w}), "
          f"top channel {names[flagged_ch]} "
          f"({'CORRECT' if hit else 'expected window ending at ' + str(fault_at)})")


if __name__ == "__main__":
    main()
