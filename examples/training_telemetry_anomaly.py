"""CADDeLaG as a first-class training-monitoring feature.

The paper's technique is graph analytics, not a transformer layer -- so the
framework integrates it where it IS applicable: watching a training run.
Each logging window builds a fully-connected similarity graph over per-layer
gradient statistics (nodes = layers x metric, edges = correlation kernel);
CADDeLaG scores consecutive windows and flags the layers whose relational
structure changed anomalously -- exactly the "changes in pairwise
relationships, not in individual tuples" story of the paper, applied to
training telemetry.  A loss-spike injection (LR x100 for one step)
demonstrates localization.

    PYTHONPATH=src python examples/training_telemetry_anomaly.py
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CommuteConfig, detect_anomalies, trivial_context
from repro.graphs import similarity_graph
from repro.launch.mesh import make_cpu_mesh
from repro.models import lm
from repro.models.common import ArchConfig
from repro.training import OptConfig, make_train_step
from repro.training.train_step import init_state
from repro.data import DataConfig, host_batch


def grad_features(grads, n_buckets: int = 8) -> np.ndarray:
    """Per-layer-stack gradient signature: (nodes, features)."""
    feats = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(grads)[0]:
        a = np.asarray(leaf, np.float32).ravel()
        if a.size < 4:
            continue
        q = np.quantile(np.abs(a), np.linspace(0.1, 0.99, n_buckets))
        feats.append(np.log1p(q))
    return np.stack(feats)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--spike-at", type=int, default=8)
    args = ap.parse_args()

    cfg = ArchConfig(name="mon", family="dense", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=2, d_ff=128, vocab=512, remat=False)
    spec = lm.build_spec(cfg)
    mesh = make_cpu_mesh(1, 1)
    ocfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=args.steps)
    params, opt = init_state(spec, mesh, ocfg)
    dcfg = DataConfig(vocab=512, seq_len=64, global_batch=8)

    grad_fn = jax.jit(jax.grad(lambda p, b: lm.loss_fn(spec, p, b)[0]))
    step_fn, *_ = make_train_step(spec, mesh, ocfg)

    ctx = trivial_context()
    ccfg = CommuteConfig(eps_rp=1e-2, d=5, q=6, schedule="xla", k_override=8)
    prev_graph, scores_per_step = None, []

    with mesh:
        for step in range(args.steps):
            b = {k: jnp.asarray(v) for k, v in host_batch(dcfg, step).items()}
            g = grad_fn(params, b)
            if step == args.spike_at:  # inject a pathological step
                g = jax.tree.map(lambda x: x * 100.0, g)
            feats = grad_features(g)
            graph = similarity_graph(ctx, jnp.asarray(feats), bandwidth=1.0)
            if prev_graph is not None:
                res = detect_anomalies(ctx, prev_graph, graph, ccfg, top_k=3)
                top = float(np.max(np.asarray(res.scores)))
                scores_per_step.append((step, top))
            prev_graph = graph
            params, opt, m = step_fn(params, opt, b)

    flagged = max(scores_per_step, key=lambda t: t[1])[0]
    for s, v in scores_per_step:
        mark = "  <-- spike injected" if s == args.spike_at else ""
        print(f"step {s:3d}: max CADDeLaG score {v:10.4f}{mark}")
    print(f"\nanomaly flagged at step {flagged} "
          f"({'CORRECT' if flagged == args.spike_at else 'expected ' + str(args.spike_at)})")


if __name__ == "__main__":
    main()
