"""Paper section 5.2: donation-shift anomalies in a bipartite-affinity graph.

The FEC donor data is not shipped; this synthesizes the paper's setting:
donors give to parties in two phases; the graph connects donors supporting
the same party with weight = min(donation) (the paper's first setting, or
log-scale for the second).  Injected anomaly: a block of donors shifts
support between phases -- CADDeLaG should rank exactly those donors highest,
which tuple-level analysis (total amounts barely change) cannot see.

    PYTHONPATH=src python examples/election_anomaly.py [--n 192]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import CommuteConfig, detect_anomalies, trivial_context
from repro.core.distmatrix import build_from_nodes


def donation_graph(ctx, party, amount, *, log_scale=True):
    """A[i,j] = min(a_i, a_j) if same party else 0 (paper's edge rule)."""
    feats = jnp.stack([party.astype(np.float32), amount.astype(np.float32)], 1)

    def kern(xi, xj):
        same = (xi[:, None, 0] == xj[None, :, 0]).astype(jnp.float32)
        m = jnp.minimum(xi[:, None, 1], xj[None, :, 1])
        w = jnp.log1p(m) if log_scale else m
        return same * w

    return build_from_nodes(ctx, feats, kern)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=192)
    ap.add_argument("--shift-frac", type=float, default=0.08)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    n = args.n
    party1 = rng.integers(0, 3, n)  # D / R / other
    amount1 = np.exp(rng.normal(5, 1.5, n))  # log-normal donations
    # phase 2: a small block of donors flips party; amounts drift a little
    n_shift = max(1, int(args.shift_frac * n))
    shifters = rng.choice(n, n_shift, replace=False)
    party2 = party1.copy()
    party2[shifters] = (party1[shifters] + 1 + rng.integers(0, 2, n_shift)) % 3
    amount2 = amount1 * np.exp(rng.normal(0, 0.1, n))

    ctx = trivial_context()
    a1 = donation_graph(ctx, party1, amount1)
    a2 = donation_graph(ctx, party2, amount2)

    cfg = CommuteConfig(eps_rp=1e-3, d=8, q=10, schedule="xla")
    res = detect_anomalies(ctx, a1, a2, cfg, top_k=n_shift)

    found = set(np.asarray(res.top_idx).tolist())
    hits = len(found & set(shifters.tolist()))
    print(f"{n} donors, {n_shift} shifted support between phases")
    print(f"CADDeLaG top-{n_shift}: {sorted(found)}")
    print(f"recovered shifters: {hits}/{n_shift}")
    # the tuple-level baseline the paper calls out: amount deltas alone
    amt_delta = np.abs(amount2 - amount1)
    baseline = set(np.argsort(-amt_delta)[:n_shift].tolist())
    print(f"amount-only baseline recovers: {len(baseline & set(shifters.tolist()))}/{n_shift}")


if __name__ == "__main__":
    main()
