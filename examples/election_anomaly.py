"""Paper section 5.2: donation-shift anomalies in a bipartite-affinity graph.

The FEC donor data is not shipped; this synthesizes the paper's setting over
a sequence of election phases: donors give to parties phase after phase; the
graph connects donors supporting the same party with weight = min(donation)
(log-scale, the paper's second setting).  Injected anomaly: in each phase a
fresh small block of donors shifts support -- the sequence engine embeds each
phase's graph once and scores every consecutive pair, and should rank exactly
the shifting donors highest per transition, which tuple-level analysis (total
amounts barely change) cannot see.

    PYTHONPATH=src python examples/election_anomaly.py [--n 192 --t-steps 3]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import CommuteConfig, SequenceDetector, trivial_context
from repro.core.distmatrix import build_from_nodes


def donation_graph(ctx, party, amount, *, log_scale=True):
    """A[i,j] = min(a_i, a_j) if same party else 0 (paper's edge rule)."""
    feats = jnp.stack([party.astype(np.float32), amount.astype(np.float32)], 1)

    def kern(xi, xj):
        same = (xi[:, None, 0] == xj[None, :, 0]).astype(jnp.float32)
        m = jnp.minimum(xi[:, None, 1], xj[None, :, 1])
        w = jnp.log1p(m) if log_scale else m
        return same * w

    return build_from_nodes(ctx, feats, kern)


def donation_phases(n, t_steps, shift_frac, seed=0):
    """Per-phase (party, amount) plus the set of donors who shifted each phase."""
    rng = np.random.default_rng(seed)
    party = rng.integers(0, 3, n)  # D / R / other
    amount = np.exp(rng.normal(5, 1.5, n))  # log-normal donations
    phases = [(party.copy(), amount.copy())]
    shifters_per_phase = []
    n_shift = max(1, int(shift_frac * n))
    for _ in range(1, t_steps):
        shifters = rng.choice(n, n_shift, replace=False)
        party = party.copy()
        party[shifters] = (party[shifters] + 1 + rng.integers(0, 2, n_shift)) % 3
        amount = amount * np.exp(rng.normal(0, 0.1, n))
        phases.append((party, amount))
        shifters_per_phase.append(set(shifters.tolist()))
    return phases, shifters_per_phase, n_shift


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=192)
    ap.add_argument("--t-steps", type=int, default=3)
    ap.add_argument("--shift-frac", type=float, default=0.08)
    args = ap.parse_args()

    phases, shifters_per_phase, n_shift = donation_phases(
        args.n, args.t_steps, args.shift_frac
    )
    ctx = trivial_context()
    cfg = CommuteConfig(eps_rp=1e-3, d=8, q=10, schedule="xla")
    det = SequenceDetector(ctx, cfg, top_k=n_shift)
    res = det.run(donation_graph(ctx, p, a) for p, a in phases)

    print(f"{args.n} donors, {args.t_steps} phases, {n_shift} shift per phase; "
          f"{res.chain_builds} graph embeddings for {len(res.transitions)} transitions")
    for t, r in enumerate(res.transitions):
        found = set(np.asarray(r.top_idx).tolist())
        truth = shifters_per_phase[t]
        print(f"phase {t}->{t + 1}: CADDeLaG top-{n_shift} recovers "
              f"{len(found & truth)}/{n_shift} shifters")
        # the tuple-level baseline the paper calls out: amount deltas alone
        amt_delta = np.abs(phases[t + 1][1] - phases[t][1])
        baseline = set(np.argsort(-amt_delta)[:n_shift].tolist())
        print(f"phase {t}->{t + 1}: amount-only baseline recovers "
              f"{len(baseline & truth)}/{n_shift}")


if __name__ == "__main__":
    main()
