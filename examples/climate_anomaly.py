"""Paper section 5.1: precipitation-anomaly detection on a climate-like grid.

The real NCEP data (259,200 geolocations) is not shipped; this generates a
smooth random precipitation field on a lat/lon grid with a localized event
(a "1995-California-flood" stand-in), builds the same fully-connected
Gaussian-kernel graph the paper uses (sigma tuned like their 388), and runs
CADDeLaG on the two snapshots.  The event region should dominate the top
anomalies -- the paper's point being that sparsified (10-NN) graphs MISS
such events while the dense pipeline finds them.

    PYTHONPATH=src python examples/climate_anomaly.py [--lat 16 --lon 16]
"""

import argparse

import numpy as np

from repro.core import CommuteConfig, detect_anomalies, trivial_context
from repro.graphs import climate_like_sequence


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lat", type=int, default=16)
    ap.add_argument("--lon", type=int, default=16)
    ap.add_argument("--top-k", type=int, default=12)
    args = ap.parse_args()

    ctx = trivial_context()
    a1, a2, event_nodes = climate_like_sequence(
        ctx, args.lat, args.lon, seed=3, sigma=1.0, event_frac=0.04, event_strength=8.0
    )
    cfg = CommuteConfig(eps_rp=1e-3, d=8, q=10, schedule="xla")
    res = detect_anomalies(ctx, a1, a2, cfg, top_k=args.top_k)

    found = np.asarray(res.top_idx).tolist()
    event = set(np.asarray(event_nodes).tolist())
    hits = sum(1 for f in found if f in event)
    print(f"grid {args.lat}x{args.lon}; event region {len(event)} nodes")
    print(f"top-{args.top_k} anomalous locations: {found}")
    print(f"in event region: {hits}/{args.top_k}")
    # lat/lon of the top anomaly
    r, c = divmod(found[0], args.lon)
    print(f"top anomaly at grid ({r}, {c})")


if __name__ == "__main__":
    main()
