"""Paper section 5.1: precipitation-anomaly detection on a climate-like grid.

The real NCEP data (259,200 geolocations) is not shipped; this generates a
T-month sequence of smooth random precipitation fields on a lat/lon grid with
a localized event (a "1995-California-flood" stand-in) appearing mid-sequence,
builds the same fully-connected Gaussian-kernel graph the paper uses (sigma
tuned like their 388), and streams the snapshots through the sequence engine.
The transitions where the event appears and disappears should dominate the
sequence-wide top anomalies -- the paper's point being that sparsified (10-NN)
graphs MISS such events while the dense pipeline finds them.

    PYTHONPATH=src python examples/climate_anomaly.py [--lat 16 --lon 16 --t-steps 4]
"""

import argparse

import numpy as np

from repro.core import CommuteConfig, SequenceDetector, trivial_context
from repro.graphs import climate_snapshot_sequence


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lat", type=int, default=16)
    ap.add_argument("--lon", type=int, default=16)
    ap.add_argument("--t-steps", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=12)
    args = ap.parse_args()

    ctx = trivial_context()
    seq = climate_snapshot_sequence(
        ctx,
        args.lat,
        args.lon,
        args.t_steps,
        seed=3,
        sigma=1.0,
        event_frac=0.04,
        event_strength=8.0,
    )
    cfg = CommuteConfig(eps_rp=1e-3, d=8, q=10, schedule="xla")
    det = SequenceDetector(ctx, cfg, top_k=args.top_k)
    res = det.run(seq.snapshots())

    print(f"grid {args.lat}x{args.lon}, {args.t_steps} months; "
          f"{res.chain_builds} chain builds for {len(res.transitions)} transitions")
    for t, r in enumerate(res.transitions):
        found = np.asarray(r.top_idx).tolist()
        event = set(np.asarray(seq.truth[t]).tolist())
        hits = sum(1 for f in found if f in event)
        label = f"event region ({len(event)} nodes)" if event else "quiet"
        print(f"month {t}->{t + 1} [{label}]: in-region hits {hits}/{args.top_k}")

    top = int(np.asarray(res.global_top_idx)[0])
    step = int(np.asarray(res.global_top_step)[0])
    r, c = divmod(top, args.lon)
    print(f"strongest anomaly across the sequence: grid ({r}, {c}) "
          f"at transition {step}->{step + 1}")


if __name__ == "__main__":
    main()
