"""Quickstart: CADDeLaG anomaly detection in ~20 lines.

Builds the paper's synthetic GMM graph sequence (section 4.2.1), runs the
full Algorithm-4 pipeline (commute-time embeddings via the distributed
inverse-chain SDD solver, fused anomaly scoring), and prints the top
anomalous nodes against the injected ground truth.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import CommuteConfig, detect_anomalies, trivial_context
from repro.graphs import gmm_graph_sequence

# 1. a 1x1 mesh context (swap in make_context(jax.make_mesh(...)) on a pod)
ctx = trivial_context()

# 2. the paper's synthetic benchmark: two graph snapshots, anomalies = the
#    injected inter-cluster edges of the second snapshot
seq = gmm_graph_sequence(ctx, n=256, seed=0, inject_p=0.02)

# 3. accuracy knobs, named as in the paper: eps_RP (embedding dim),
#    d (inverse-chain length), q (Richardson iterations)
cfg = CommuteConfig(eps_rp=1e-3, d=8, q=10, schedule="xla")

# 4. Algorithm 4 end-to-end
res = detect_anomalies(ctx, seq.a1, seq.a2, cfg, top_k=15)

truth = set(seq.anomalous_nodes.tolist())
found = np.asarray(res.top_idx).tolist()
hits = sum(1 for f in found if f in truth)
print(f"top-15 anomalies: {found}")
print(f"precision@15 vs injected ground truth: {hits}/15")
